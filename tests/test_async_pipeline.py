"""Async host/device dispatch pipeline (``stage_dispatch="async"``, the
DEFAULT): greedy tokens are byte-identical to the ``"sync"`` oracle loop
across arch families, under 1-block-LRU eviction pressure, on both the
split staged-decode path and the mixed hybrid plane, and 8-way sharded —
while the contract-backed async invariants hold: np.asarray(selected ids)
is the ONLY per-layer blocking sync (``host_syncs`` counter vs
``plane_contract.staged_host_syncs_per_iteration``), the FlashD2H
readback stays stripe-sized (never pool-sized), and pool-updating stages
declare buffer donation per ``STAGED_DONATED_STAGES``."""

import jax
import numpy as np
import pytest

from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request

import planeasserts as pa

N_DEV = len(jax.devices())
needs_multi = pytest.mark.skipif(
    N_DEV < 8, reason="needs 8 forced host devices (CI multi-device job: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

ARCHS = ["qwen2-0.5b", "minicpm3-4b", "jamba-v0.1-52b", "whisper-small",
         "kimi-k2-1t-a32b"]


def _run(cfg, params, prompts, gen=3, seed=7, arrivals=None, enc_lens=None,
         **kw):
    kw.setdefault("r_max", 4)
    kw.setdefault("chunk_size", 64)
    eng = ServingEngine(params, cfg, EngineConfig(**kw))
    rng = np.random.default_rng(seed)
    order = []
    for i, p in enumerate(prompts):
        extra = {}
        if cfg.is_encoder_decoder:
            S_enc = enc_lens[i] if enc_lens else 16
            extra["frames"] = np.ones((1, S_enc, cfg.d_model),
                                      np.float32) * .01
        if cfg.frontend == "vit_patch_stub":
            extra["patch_embeds"] = np.ones(
                (1, cfg.num_patches, cfg.d_model), np.float32) * .01
        toks = rng.integers(4, cfg.vocab_size, p).astype(np.int32)
        r = Request(prompt_len=p, max_new_tokens=gen,
                    arrival_time=(arrivals[i] if arrivals else 0.0))
        eng.submit(r, tokens=toks, **extra)
        order.append(r.req_id)
    eng.run()
    return eng, [eng.states[rid].out_tokens for rid in order]


# ---------------------------------------------------------------------------
# Default + oracle knob
# ---------------------------------------------------------------------------

def test_async_is_default_and_validated(smoke_setup):
    cfg, params = smoke_setup("qwen2-0.5b")
    assert EngineConfig().stage_dispatch == "async"
    assert ServingEngine(params, cfg, EngineConfig())._stage_async
    assert not ServingEngine(params, cfg,
                             EngineConfig(stage_dispatch="sync"))._stage_async
    with pytest.raises(ValueError):
        ServingEngine(params, cfg, EngineConfig(stage_dispatch="eager"))


# ---------------------------------------------------------------------------
# Token identity vs the sync oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_async_equals_sync_across_archs_under_pressure(arch, smoke_setup):
    """Acceptance: >=4 smoke archs (GQA, MLA, hybrid mamba, enc-dec, MoE),
    mixed iterations, 1-block LRU forcing evictions, staggered arrivals —
    async greedy tokens byte-identical to the sync loop."""
    cfg, params = smoke_setup(arch)
    kw = dict(gen=3, arrivals=(0.0, 1e-4, 3e-3), hbm_blocks_per_request=1)
    e_a, toks_a = _run(cfg, params, (48, 64, 72), **kw)
    _, toks_s = _run(cfg, params, (48, 64, 72), stage_dispatch="sync", **kw)
    assert toks_a == toks_s
    assert all(len(t) == 3 for t in toks_a)
    assert e_a._worker is None        # run() released the host worker


def test_async_equals_sync_split_staged_with_invariants(smoke_setup):
    """Split staged-decode path under a 1-block LRU (every layer misses,
    so every layer crosses the write-back fence): tokens identical, and
    the async plane's measured counters hit the contract formulas exactly
    — one blocking sync per attention layer per iteration (the driver's
    np.asarray of the selection tensor), a stripe-sized FlashD2H readback
    (never a pool-sized copy), and the donation table honoured."""
    cfg, params = smoke_setup("qwen2-0.5b")
    kw = dict(gen=6, hybrid_plane="split", hbm_blocks_per_request=1)
    e_a, toks_a = _run(cfg, params, (64, 64, 64), **kw)
    e_s, toks_s = _run(cfg, params, (64, 64, 64), stage_dispatch="sync",
                       **kw)
    assert toks_a == toks_s
    assert all(len(t) == 6 for t in toks_a)

    [plane] = e_a.planes.values()
    pa.assert_host_sync_invariant(plane, e_a.decode_step_calls, cfg)
    # rows vary per iteration (working-set admission staggers decode
    # entry), but the readback total is exactly one stripe per decoded
    # token per attention layer
    pa.assert_stripe_readback_invariant(plane, 1, rows=e_a.decode_tokens)
    pa.assert_donation_contract(plane.staged_fns)
    # the sync oracle never touches the async counter
    [plane_s] = e_s.planes.values()
    assert plane_s.host_syncs == 0


def test_async_mixed_host_sync_invariant(smoke_setup):
    """Mixed iterations: the ONE-sync-per-attention-layer pin holds with
    prefill segments riding the same layer walk (chunked segments,
    staggered arrivals)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    e_a, toks = _run(cfg, params, (48, 96, 72, 64), gen=4,
                     arrivals=(0.0, 0.0, 1e-4, 3e-3),
                     prefill_max_tokens_per_step=32)
    assert all(len(t) == 4 for t in toks)
    [plane] = e_a.planes.values()
    decode_iters = sum(1 for e in e_a.mixed_iter_log if e["decode_planes"])
    pa.assert_host_sync_invariant(plane, decode_iters, cfg)
    pa.assert_mixed_launch_invariant(e_a)      # async changes no launches


@needs_multi
def test_async_equals_sync_sharded_model8(smoke_setup):
    """Acceptance (multi-device CI): 8-way tensor-sharded mixed iteration
    under eviction pressure — async == sync."""
    cfg, params = smoke_setup("qwen2-0.5b")
    kw = dict(gen=3, arrivals=(0.0, 1e-4, 3e-3), mesh_spec="model=8",
              hbm_blocks_per_request=1)
    e_a, toks_a = _run(cfg, params, (48, 64, 72), **kw)
    _, toks_s = _run(cfg, params, (48, 64, 72), stage_dispatch="sync", **kw)
    assert toks_a == toks_s
    [plane] = e_a.planes.values()
    decode_iters = sum(1 for e in e_a.mixed_iter_log if e["decode_planes"])
    pa.assert_host_sync_invariant(plane, decode_iters, cfg)


# ---------------------------------------------------------------------------
# Overlap bookkeeping
# ---------------------------------------------------------------------------

def test_stage_timeline_recorded_per_layer(smoke_setup):
    """step_staged/run_iteration record a per-attention-layer (layer,
    sync_s, host_stage_s) wall-clock timeline each iteration — the raw
    series bench_overlap aggregates into the achieved-overlap section."""
    cfg, params = smoke_setup("qwen2-0.5b")
    e_a, _ = _run(cfg, params, (64, 64), gen=3, hybrid_plane="split")
    [plane] = e_a.planes.values()
    n_attn = cfg.num_attention_layers()
    assert len(plane.stage_timeline) == n_attn
    layers = [lay for lay, _, _ in plane.stage_timeline]
    assert layers == sorted(layers)
    assert all(s >= 0 and h >= 0 for _, s, h in plane.stage_timeline)

    e_m, _ = _run(cfg, params, (64, 64), gen=3)
    assert e_m.hybrid is not None
    assert len(e_m.hybrid.stage_timeline) == n_attn
