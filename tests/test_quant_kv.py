"""Quantized DRAM offload tier (``EngineConfig.offload_quant="int8"``).

Four layers of coverage: (1) interpret-mode parity of the
``kernels/quant_blocks.py`` Pallas kernels against the pure-jnp
``ref.py`` oracles, (2) the quantize->dequantize error bound per input
dtype (symmetric per-(head, block) scales: |err| <= scale/2), (3)
``HostPool`` quantized-mode byte accounting — every counter at STORED
(wire) size, fp mode byte-identical to before — and (4) an engine-level
fidelity bound: int8 decode under 1-block-LRU eviction pressure (blocks
round-trip through DRAM every iteration) stays greedy-identical with
final-logits cosine >= 0.99 vs the fp tier."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kv_cache import (
    QUANT_SCALE_BYTES, HostPool, KVCacheManager, KVGeometry)
from repro.kernels import ops, ref


def key(i):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# (1) kernel vs ref parity (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,k,bs,d", [(2, 3, 8, 16), (4, 1, 32, 64),
                                      (1, 7, 16, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_blocks_parity(h, k, bs, d, dtype):
    blocks = (jax.random.normal(key(0), (h, k, bs, d), jnp.float32)
              * 3.0).astype(dtype)
    q, s = ops.quantize_blocks(blocks)
    qr, sr = ref.quantize_blocks(blocks)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert q.shape == (h, k, bs, d) and s.shape == (h, k)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("h,k,bs,d", [(2, 3, 8, 16), (1, 5, 16, 32)])
def test_dequantize_blocks_parity(h, k, bs, d):
    blocks = jax.random.normal(key(1), (h, k, bs, d), jnp.float32) * 2.0
    q, s = ref.quantize_blocks(blocks)
    out = ops.dequantize_blocks(q, s)
    want = ref.dequantize_blocks(q, s)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_dequantize_scatter_blocks_parity():
    h, nb, k, bs, d = 2, 12, 4, 8, 16
    pool = jax.random.normal(key(2), (h, nb, bs, d), jnp.float32)
    blocks = jax.random.normal(key(3), (h, k, bs, d), jnp.float32) * 4.0
    q, s = ref.quantize_blocks(blocks)
    dest = jnp.array([0, 5, 11, 7], jnp.int32)
    out = ops.dequantize_scatter_blocks(pool, q, s, dest)
    want = ref.dequantize_scatter_blocks(pool, q, s, dest)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)
    # untouched blocks preserved (input_output_aliases semantics)
    untouched = [b for b in range(nb) if b not in (0, 5, 11, 7)]
    np.testing.assert_array_equal(np.asarray(out[:, untouched]),
                                  np.asarray(pool[:, untouched]))


def test_quantize_all_zero_block():
    z = jnp.zeros((1, 2, 8, 16))
    q, s = ops.quantize_blocks(z)
    assert np.all(np.asarray(q) == 0) and np.all(np.asarray(s) == 0.0)
    np.testing.assert_array_equal(np.asarray(ops.dequantize_blocks(q, s)),
                                  np.zeros((1, 2, 8, 16), np.float32))


# ---------------------------------------------------------------------------
# (2) round-trip error bound per dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_roundtrip_error_bound(dtype):
    """Symmetric int8 with per-(head, block) scale = amax/127: round-to-
    nearest error is at most scale/2 per element (modulo one f32 ulp from
    the reciprocal-multiply scaling)."""
    blocks = (jax.random.normal(key(4), (3, 4, 16, 32), jnp.float32)
              * 5.0).astype(dtype)
    q, s = ops.quantize_blocks(blocks)
    deq = np.asarray(ops.dequantize_blocks(q, s))
    x = np.asarray(blocks, np.float32)
    bound = np.asarray(s)[..., None, None] * (0.5 + 1e-5) + 1e-7
    assert np.all(np.abs(deq - x) <= bound)
    # and the bound is tight enough to be meaningful: < 0.5% of amax
    amax = np.abs(x).max()
    assert np.abs(deq - x).max() <= amax / 127


# ---------------------------------------------------------------------------
# (3) HostPool quantized-mode byte accounting
# ---------------------------------------------------------------------------

GEOM = KVGeometry(num_layers=2, num_kv_heads=2, block_size=4, head_dim=8)


def _stripe(rng, t):
    return (rng.standard_normal((GEOM.num_kv_heads, t, GEOM.head_dim))
            .astype(np.float32) * 2.0)


def test_wire_bytes_fp_vs_int8():
    fp = HostPool(GEOM, 6)
    q8 = HostPool(GEOM, 6, quant="int8")
    elems = GEOM.block_size * GEOM.head_dim          # per head per tensor
    assert fp.wire_bytes(3) == 3 * GEOM.num_kv_heads * elems * 4 * 2
    assert q8.wire_bytes(3) == 3 * GEOM.num_kv_heads \
        * (elems + QUANT_SCALE_BYTES) * 2
    # int8 stores ~4x smaller than the f32 numpy pools (scales amortized)
    assert fp.wire_bytes(8) / q8.wire_bytes(8) > 3.5


def test_stage_returns_wire_bytes():
    rng = np.random.default_rng(0)
    k, v = _stripe(rng, 6), _stripe(rng, 6)
    fp = HostPool(GEOM, 6)
    q8 = HostPool(GEOM, 6, quant="int8")
    got_fp = fp.stage(0, 0, k, v)
    assert got_fp == k.nbytes * 2                    # unchanged fp contract
    got_q = q8.stage(0, 0, k, v)
    # 6 tokens from position 0 touch blocks 0 and 1 (bs=4): int8 payload
    # elements + one f32 scale per (head, touched block) per tensor
    elems = 6 * GEOM.num_kv_heads * GEOM.head_dim
    assert got_q == (elems + 2 * GEOM.num_kv_heads * QUANT_SCALE_BYTES) * 2
    assert got_q < got_fp / 3       # tiny geom: scale overhead is ~7%
    # mid-block stripe: tokens [3, 5) touch blocks 0 and 1
    got_mid = q8.stage(1, 3, _stripe(rng, 2), _stripe(rng, 2))
    elems_mid = 2 * GEOM.num_kv_heads * GEOM.head_dim
    assert got_mid == (elems_mid
                       + 2 * GEOM.num_kv_heads * QUANT_SCALE_BYTES) * 2


def test_load_blocks_books_stored_size():
    rng = np.random.default_rng(1)
    q8 = HostPool(GEOM, 4, quant="int8")
    k, v = _stripe(rng, 8), _stripe(rng, 8)
    q8.stage(0, 0, k, v)
    q8.flush()
    got_k, got_v = q8.load_blocks(0, [0, 1])
    assert got_k.dtype == np.float32                 # dequantized payload
    assert q8.stats.h2d_calls == 1
    assert q8.stats.h2d_blocks == 2 * GEOM.num_kv_heads
    assert q8.stats.h2d_bytes == q8.wire_bytes(2)
    assert q8.stats.h2d_bytes < got_k.nbytes * 2     # < logical fp size


def test_pool_roundtrip_within_bound():
    rng = np.random.default_rng(2)
    q8 = HostPool(GEOM, 4, quant="int8")
    k, v = _stripe(rng, 8), _stripe(rng, 8)
    q8.stage(0, 0, k, v)
    q8.flush()
    got_k, got_v = q8.gather(0, [0, 1])
    want_k = k.reshape(GEOM.num_kv_heads, 2, GEOM.block_size, GEOM.head_dim)
    amax = np.abs(want_k).max()
    assert np.abs(got_k - want_k).max() <= amax / 127
    # matches the kernel oracle bit-for-bit (np.rint == jnp.rint)
    qk, sk = ref.quantize_blocks(jnp.asarray(want_k))
    np.testing.assert_array_equal(q8.k[0, :, :2], np.asarray(qk))
    np.testing.assert_allclose(q8.k_scale[0, :, :2], np.asarray(sk),
                               rtol=1e-6)


def test_partial_block_requantize_drift_bounded():
    """Appending token-by-token requantizes the partial block each flush;
    the accumulated drift stays within a small multiple of the one-shot
    quantization error."""
    rng = np.random.default_rng(3)
    q8 = HostPool(GEOM, 2, quant="int8")
    full = _stripe(rng, GEOM.block_size)
    for t in range(GEOM.block_size):
        q8.stage(0, t, full[:, t:t + 1], full[:, t:t + 1])
        q8.flush()
    got_k, _ = q8.gather(0, [0])
    err = np.abs(got_k[:, 0] - full).max()
    assert err <= 3 * np.abs(full).max() / 127


def test_manager_int8_plumbing_and_fused_accounting():
    mgr = KVCacheManager(GEOM, 1 << 20, offload_quant="int8")
    mgr.register("r0", max_tokens=16, hbm_blocks_per_request=1)
    pool = mgr.pools["r0"]
    assert pool.quant == "int8" and pool.k.dtype == np.int8
    rng = np.random.default_rng(4)
    k, v = _stripe(rng, 8), _stripe(rng, 8)
    mgr.save_new_tokens_fused(0, {"r0": (0, k, v)})
    assert mgr.fused_stats.d2h_calls == 1
    elems = 8 * GEOM.num_kv_heads * GEOM.head_dim    # 8 tokens -> 2 blocks
    assert mgr.fused_stats.d2h_bytes == \
        (elems + 2 * GEOM.num_kv_heads * QUANT_SCALE_BYTES) * 2
    pool.flush()
    out = mgr.load_blocks_fused(0, {"r0": [0, 1]})
    assert mgr.fused_stats.h2d_bytes == pool.wire_bytes(2)
    assert out["r0"][0].dtype == np.float32


def test_manager_rejects_unknown_quant():
    with pytest.raises(ValueError):
        KVCacheManager(GEOM, 1 << 20, offload_quant="int4")
    with pytest.raises(ValueError):
        HostPool(GEOM, 4, quant="fp8")


# ---------------------------------------------------------------------------
# (4) engine-level fidelity under eviction pressure
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, quant, prompts=(48, 48), gen=6):
    """Drive the engine step by step, recording the logits that produced
    each output token — so fidelity is comparable per token position even
    after a greedy divergence (logits at the FIRST divergent position
    come from identical contexts: only quant noise separates them)."""
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request
    eng = ServingEngine(params, cfg, EngineConfig(
        chunk_size=64, r_max=4, hbm_blocks_per_request=1,
        offload_quant=quant))
    rng = np.random.default_rng(7)
    order = []
    for p in prompts:
        r = Request(prompt_len=p, max_new_tokens=gen)
        eng.submit(r, tokens=rng.integers(4, cfg.vocab_size,
                                          p).astype(np.int32))
        order.append(r.req_id)
    logits = {rid: {} for rid in order}
    while eng.step() is not None:
        for rid in order:
            st = eng.states.get(rid)
            if st is None or st.last_logits is None or not st.out_tokens:
                continue
            i = len(st.out_tokens) - 1
            if i not in logits[rid]:
                logits[rid][i] = np.asarray(st.last_logits,
                                            np.float32).ravel()
    toks = [eng.states[rid].out_tokens for rid in order]
    return eng, toks, [logits[rid] for rid in order]


def _cosine(a, b):
    return float(np.dot(a, b)
                 / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-12))


def test_engine_int8_decode_fidelity(smoke_setup):
    """offload_quant="int8" under 1-block LRU: every selected block
    round-trips DRAM (quantize on save, dequantize on restore) each
    iteration, yet decode stays within the bench_accuracy bound."""
    cfg, params = smoke_setup("qwen2-0.5b")
    eng_fp, toks_fp, log_fp = _run_engine(cfg, params, "none")
    eng_q8, toks_q8, log_q8 = _run_engine(cfg, params, "int8")
    for tf, tq, lf, lq in zip(toks_fp, toks_q8, log_fp, log_q8):
        # compare logits per position while the contexts are identical:
        # up to and INCLUDING the first greedy divergence (at that
        # position both runs consumed the same tokens)
        div = next((i for i, (a, b) in enumerate(zip(tf, tq)) if a != b),
                   len(tf) - 1)
        assert div >= 1              # quant noise never flips token 0
        for i in range(div + 1):
            assert _cosine(lf[i], lq[i]) >= 0.99, (i, div)
    # the int8 run really moved bytes through the quantized tier...
    ts_fp, ts_q8 = eng_fp.kv_mgr.total_stats(), eng_q8.kv_mgr.total_stats()
    assert ts_q8.h2d_bytes > 0 and ts_q8.d2h_bytes > 0
    # ...and booked them at stored size: >= 1.8x fewer wire bytes at equal
    # blocks moved (the ISSUE acceptance bar; ~3.9x vs these f32 pools)
    assert ts_q8.h2d_blocks == ts_fp.h2d_blocks
    assert ts_q8.d2h_blocks == ts_fp.d2h_blocks
    wire_fp = ts_fp.h2d_bytes + ts_fp.d2h_bytes
    wire_q8 = ts_q8.h2d_bytes + ts_q8.d2h_bytes
    assert wire_fp / wire_q8 >= 1.8
    # the cost model sees the shrink too
    assert eng_q8._offload_block_bytes < eng_fp._offload_block_bytes / 1.8


def test_engine_rejects_unknown_offload_quant(smoke_setup):
    from repro.serving.engine import EngineConfig, ServingEngine
    cfg, params = smoke_setup("qwen2-0.5b")
    with pytest.raises(ValueError, match="offload_quant"):
        ServingEngine(params, cfg, EngineConfig(offload_quant="int4"))
