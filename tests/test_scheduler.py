"""Scheduler (Algorithm 1) + working-set estimator property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.kv_cache import KVGeometry
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.working_set import (DecodeWorkingSet, estimate_decode_ws_bytes,
                                    estimate_prefill_ws_bytes)
from repro.serving.request import Phase, Request

SET = dict(max_examples=30, deadline=None)


def geom():
    return KVGeometry(num_layers=4, num_kv_heads=2, block_size=8, head_dim=16)


def mk_sched(m_avl=0, ws=True, prefill_mode="layer_segmented", r_max=8,
             t_max=4096, chunk=256):
    return Scheduler(SchedulerConfig(
        r_max=r_max, t_max=t_max, m_avl_bytes=m_avl,
        prefill_mode=prefill_mode, chunk_size=chunk,
        max_inject_tokens=chunk * 4, ws_control=ws), geom(), 4, 8)


# ---------------------------------------------------------------------------
# Working set
# ---------------------------------------------------------------------------

@given(sels=st.lists(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 30)),
                              max_size=10), min_size=1, max_size=30),
       window=st.integers(1, 12))
@settings(**SET)
def test_ws_window_union(sels, window):
    ws = DecodeWorkingSet(geom(), window=window)
    for s in sels:
        ws.observe(s)
    expect = set()
    for s in sels[-window:]:
        expect |= set(s)
    assert ws.union() == expect
    assert ws.size_blocks() == len(expect)


def test_ws_estimates():
    g = geom()
    ws = DecodeWorkingSet(g, window=4)
    # cold estimate = worst case top_k * layers
    cold = estimate_decode_ws_bytes(ws, g, top_k_blocks=8, num_layers=4)
    assert cold == 8 * 4 * g.block_bytes_per_head * g.num_kv_heads
    ws.observe([(0, 1), (1, 2)])
    warm = estimate_decode_ws_bytes(ws, g, 8, 4)
    assert warm == 2 * g.block_bytes_per_head * g.num_kv_heads
    # layer-segmented prefill WS is 1/num_layers of chunked
    ch = estimate_prefill_ws_bytes(g, 1024, "chunked")
    ls = estimate_prefill_ws_bytes(g, 1024, "layer_segmented")
    assert ch == ls * g.num_layers


# ---------------------------------------------------------------------------
# Algorithm 1: admitted working sets never exceed M_avl
# ---------------------------------------------------------------------------

@given(n_dec=st.integers(0, 10), n_wait=st.integers(0, 6),
       m_avl_blocks=st.integers(1, 200), seed=st.integers(0, 99))
@settings(**SET)
def test_admission_bounded_by_m_avl(n_dec, n_wait, m_avl_blocks, seed):
    g = geom()
    per_lb = g.block_bytes_per_head * g.num_kv_heads
    m_avl = m_avl_blocks * per_lb
    s = mk_sched(m_avl=m_avl)
    rng = np.random.default_rng(seed)
    for i in range(n_dec):
        r = Request(prompt_len=64, max_new_tokens=32)
        r.phase = Phase.DECODE
        s.running.append(r)
        sel = [(l, int(b)) for l in range(4)
               for b in rng.integers(0, 8, size=rng.integers(1, 6))]
        s.observe_selection(r, sel)
    for i in range(n_wait):
        s.add_request(Request(prompt_len=128, max_new_tokens=8))
    plan = s.schedule()
    used = sum(s._estimate_ws(r) for r in plan.decode_reqs)
    used += sum(s._estimate_ws(r) for r, _ in plan.prefill_reqs)
    assert used <= m_avl


@given(n_dec=st.integers(0, 10), n_wait=st.integers(0, 6),
       m_avl_blocks=st.integers(1, 200), seed=st.integers(0, 99))
@settings(**SET)
def test_mixed_plan_arbitration_record_is_exact(n_dec, n_wait, m_avl_blocks,
                                                seed):
    """The mixed iteration's arbitration record: ws_decode_bytes /
    ws_prefill_bytes are exactly the admitted rows' estimate_*_ws_bytes
    sums, and their total is what Algorithm 1 held under M_avl."""
    g = geom()
    per_lb = g.block_bytes_per_head * g.num_kv_heads
    s = mk_sched(m_avl=m_avl_blocks * per_lb)
    rng = np.random.default_rng(seed)
    for _ in range(n_dec):
        r = Request(prompt_len=64, max_new_tokens=32)
        r.phase = Phase.DECODE
        s.running.append(r)
        sel = [(l, int(b)) for l in range(4)
               for b in rng.integers(0, 8, size=rng.integers(1, 6))]
        s.observe_selection(r, sel)
    for _ in range(n_wait):
        s.add_request(Request(prompt_len=128, max_new_tokens=8))
    plan = s.schedule()
    assert plan.ws_decode_bytes == sum(s._estimate_ws(r)
                                       for r in plan.decode_reqs)
    assert plan.ws_prefill_bytes == sum(s._estimate_ws(r)
                                        for r, _ in plan.prefill_reqs)
    assert (plan.ws_decode_bytes + plan.ws_prefill_bytes
            <= s.cfg.m_avl_bytes)
    if plan.rejected == 0 and not s.waiting:
        # nothing was cut: the record covers the whole candidate batch
        assert len(plan.decode_reqs) == min(n_dec, s.cfg.r_max)


def test_ws_control_off_admits_everything_within_rmax():
    s = mk_sched(m_avl=0, ws=False, r_max=4)
    for _ in range(6):
        r = Request(prompt_len=32, max_new_tokens=4)
        r.phase = Phase.DECODE
        s.running.append(r)
    plan = s.schedule()
    assert len(plan.decode_reqs) == 4              # r_max enforced


def test_fcfs_order_preserved():
    s = mk_sched(m_avl=1 << 30)
    reqs = [Request(prompt_len=64, max_new_tokens=4) for _ in range(3)]
    for r in reqs:
        s.add_request(r)
    plan = s.schedule()
    got = [r.req_id for r, _ in plan.prefill_reqs]
    assert got == [r.req_id for r in reqs][:len(got)]
    assert got  # at least one admitted


def test_rejected_request_stays_schedulable():
    """Algorithm 1 line 14: rejected request is reset, not dropped."""
    g = geom()
    per_lb = g.block_bytes_per_head * g.num_kv_heads
    s = mk_sched(m_avl=9 * 4 * per_lb)   # fits ~1 cold decode WS (8*4 + eps)
    r1 = Request(prompt_len=64, max_new_tokens=4)
    r2 = Request(prompt_len=64, max_new_tokens=4)
    for r in (r1, r2):
        r.phase = Phase.DECODE
        s.running.append(r)
    plan = s.schedule()
    assert len(plan.decode_reqs) == 1 and plan.rejected == 1
    # next iteration it can still be scheduled
    plan2 = s.schedule()
    assert len(plan2.decode_reqs) == 1


def test_chunked_prefill_respects_t_max():
    s = mk_sched(m_avl=0, ws=False, prefill_mode="chunked", t_max=300,
                 chunk=256)
    s.add_request(Request(prompt_len=1000, max_new_tokens=4))
    s.add_request(Request(prompt_len=1000, max_new_tokens=4))
    plan = s.schedule()
    assert plan.total_tokens <= 300
    assert plan.prefill_reqs[0][1] == 256          # one chunk admitted
