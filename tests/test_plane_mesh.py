"""Context-parallel plane tests: PlaneMesh resolution + greedy-equivalence
of the SHARDED staged decode plane and SHARDED prefill plane against their
single-device defaults (and the sequential / legacy oracles).

Multi-device cases run IN-PROCESS (no subprocess spawn): they need the
interpreter to have been started with forced host devices, e.g.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest tests/test_plane_mesh.py

which is exactly what the per-PR CI ``multi-device`` job does.  Under the
plain tier-1 run (1 device) those cases skip; the ``model=1`` cases still
execute the full sharded code path (shard_map over a 1-way axis) so it
cannot rot between multi-device CI runs.  Fast cases are unmarked; the
wide arch sweep is ``slow``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device_pool import staged_fns_for
from repro.launch.plane_mesh import PlaneMesh
from repro.models import model as M
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request

import planeasserts as pa

N_DEV = len(jax.devices())
needs_multi = pytest.mark.skipif(
    N_DEV < 8, reason="needs 8 forced host devices (CI multi-device job: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _run_engine(cfg, params, prompts, gen=4, seed=7, **kw):
    eng = ServingEngine(params, cfg, EngineConfig(
        chunk_size=64, r_max=4, **kw))
    rng = np.random.default_rng(seed)
    order = []
    for p in prompts:
        extra = {}
        if cfg.is_encoder_decoder:
            extra["frames"] = np.ones((1, 16, cfg.d_model), np.float32) * .01
        if cfg.frontend == "vit_patch_stub":
            extra["patch_embeds"] = np.ones(
                (1, cfg.num_patches, cfg.d_model), np.float32) * .01
        toks = rng.integers(4, cfg.vocab_size, p).astype(np.int32)
        r = Request(prompt_len=p, max_new_tokens=gen)
        eng.submit(r, tokens=toks, **extra)
        order.append(r.req_id)
    eng.run()
    return eng, [eng.states[rid].out_tokens for rid in order]


# ---------------------------------------------------------------------------
# PlaneMesh resolution / layout rules (no multi-device requirement)
# ---------------------------------------------------------------------------

def test_plane_mesh_resolve_specs():
    assert PlaneMesh.resolve(None) is None
    pm = PlaneMesh.resolve("model=1")
    assert pm.model_size == 1
    assert PlaneMesh.resolve(pm) is pm
    assert PlaneMesh.resolve(1).model_size == 1
    assert PlaneMesh.resolve(pm.mesh).model_axis == "model"
    with pytest.raises(ValueError):
        PlaneMesh.resolve("rings=3")
    with pytest.raises(ValueError):
        PlaneMesh.resolve(N_DEV + 7)          # does not divide the devices


def test_pool_shard_mode_rules(smoke_setup):
    """Head mode needs a dividing KV-head axis; MLA (one latent head) and
    non-dividing GQA head counts fall back to block mode, where the block
    capacity must round up to the model axis."""
    cfg_q, _ = smoke_setup("qwen2-0.5b")        # Hkv=1
    cfg_j, _ = smoke_setup("jamba-v0.1-52b")    # Hkv=2
    cfg_m, _ = smoke_setup("minicpm3-4b")       # MLA
    pm1 = PlaneMesh.resolve("model=1")
    assert pm1.pool_shard_mode(cfg_q) == "heads"     # 1 % 1 == 0
    assert pm1.round_blocks(cfg_m, 5) == 5
    if N_DEV >= 2:
        pm2 = PlaneMesh.resolve("model=2")
        assert pm2.pool_shard_mode(cfg_q) == "blocks"
        assert pm2.pool_shard_mode(cfg_j) == "heads"
        assert pm2.pool_shard_mode(cfg_m) == "blocks"
        assert pm2.round_blocks(cfg_m, 5) == 6


def test_mesh_spec_requires_staged_plane_and_dsa(smoke_setup):
    cfg, params = smoke_setup("qwen2-0.5b")
    with pytest.raises(ValueError, match="staged"):
        ServingEngine(params, cfg, EngineConfig(
            mesh_spec="model=1", decode_plane="persistent"))
    import dataclasses
    cfg_off = dataclasses.replace(
        cfg, dsa=dataclasses.replace(cfg.dsa, enabled=False))
    with pytest.raises(ValueError, match="DSA"):
        ServingEngine(params, cfg_off, EngineConfig(mesh_spec="model=1"))


def test_sharded_code_path_on_one_device(smoke_setup):
    """mesh_spec='model=1' runs the full sharded code path (shard_map over
    a 1-way axis) on any machine — the tier-1 guard that keeps the CP
    plane importable/runnable between multi-device CI runs."""
    cfg, params = smoke_setup("qwen2-0.5b")
    e0, t0 = _run_engine(cfg, params, (48, 72))
    e1, t1 = _run_engine(cfg, params, (48, 72), mesh_spec="model=1")
    assert t1 == t0
    assert e1.plane_mesh is not None and e1.plane_mesh.model_size == 1
    [plane] = e1.planes.values()
    assert plane.plane_mesh is e1.plane_mesh
    pa.assert_cache_hit_invariant(plane.staged_fns)


# ---------------------------------------------------------------------------
# Sharded staged decode == staged == sequential (forced multi-device CPU)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sharded_runs(smoke_setup):
    """qwen2 smoke (Hkv=1 -> BLOCK-sharded pool) on model=2 and model=8,
    plus the single-device staged default and the sequential oracle."""
    cfg, params = smoke_setup("qwen2-0.5b")
    return {
        "staged": _run_engine(cfg, params, (48, 96, 72), gen=5),
        "cp2": _run_engine(cfg, params, (48, 96, 72), gen=5,
                           mesh_spec="model=2"),
        "cp8": _run_engine(cfg, params, (48, 96, 72), gen=5,
                           mesh_spec="model=8"),
        "sequential": _run_engine(cfg, params, (48, 96, 72), gen=5,
                                  batched_decode=False),
    }


@needs_multi
def test_sharded_staged_matches_default_and_sequential(sharded_runs):
    """Acceptance bar: sharded-staged greedy tokens are identical to the
    single-device staged plane AND the sequential oracle on a forced
    multi-device CPU mesh."""
    _, toks = sharded_runs["staged"]
    for mode in ("cp2", "cp8", "sequential"):
        assert sharded_runs[mode][1] == toks, mode


@needs_multi
def test_sharded_staged_launches_o_num_layers_traces_bounded(sharded_runs):
    """Per-iteration jitted launches stay O(num_layers) on the sharded
    plane (same stage structure), and traces == shape signatures."""
    e, _ = sharded_runs["cp8"]
    cfg = e.cfg
    [plane] = e.planes.values()
    fns = plane.staged_fns
    pa.assert_cache_hit_invariant(fns)
    per_iter = pa.staged_launches_per_iteration(cfg)
    assert fns.calls == per_iter * e.decode_step_calls
    # pool block capacity divides the 8-way model axis (block mode)
    assert plane.nb_cap % 8 == 0


@needs_multi
def test_sharded_staged_transfer_accounting_matches(sharded_runs):
    """Blocks/bytes moved by the hierarchy must not depend on the mesh."""
    (e_s, _), (e_c, _) = sharded_runs["staged"], sharded_runs["cp8"]
    s_s, s_c = e_s.transfer_stats(), e_c.transfer_stats()
    assert s_c.h2d_blocks == s_s.h2d_blocks
    assert s_c.h2d_bytes == s_s.h2d_bytes
    assert s_c.misses == s_s.misses


@needs_multi
def test_sharded_staged_eviction_pressure_oracle_exact(smoke_setup):
    """1-block LRU: >=1 eviction per iteration, physical device drops every
    round, restores landing in the select->attend window of the SHARDED
    pool — greedy tokens still identical to the sequential oracle."""
    cfg, params = smoke_setup("qwen2-0.5b")
    kw = dict(gen=8, hbm_blocks_per_request=1)
    e_c, t_c = _run_engine(cfg, params, (64, 64, 64),
                           mesh_spec="model=4", **kw)
    _, t_s = _run_engine(cfg, params, (64, 64, 64), batched_decode=False,
                         **kw)
    assert t_c == t_s
    assert e_c.eng.drop_evicted_device_blocks      # auto-resolved ON
    s = e_c.transfer_stats()
    assert s.evictions >= e_c.decode_step_calls
    [plane] = e_c.planes.values()
    assert plane.blocks_dropped > 0
    assert plane.blocks_restored > 0
    assert plane.blocks_restored_before_use == plane.blocks_restored


# ---------------------------------------------------------------------------
# Sharded prefill plane == plane == legacy
# ---------------------------------------------------------------------------

@needs_multi
def test_sharded_prefill_plane_matches_plane_and_legacy(smoke_setup):
    """Sequence-sharded prefill launches (incl. intra-layer CHUNKED
    segments, whose windows need not divide the axis) produce greedy
    tokens identical to the single-device plane and the legacy
    per-request executor."""
    cfg, params = smoke_setup("qwen2-0.5b")
    kw = dict(gen=3, prefill_max_tokens_per_step=48)
    e_p, t_p = _run_engine(cfg, params, (48, 96, 80), **kw)
    e_c, t_c = _run_engine(cfg, params, (48, 96, 80), mesh_spec=8, **kw)
    _, t_l = _run_engine(cfg, params, (48, 96, 80), gen=3,
                         prefill_exec="legacy")
    assert t_c == t_p == t_l
    # still one launch per (layer, chunk) group, sharded or not
    for plane_c, plane_p in zip(e_c.prefill_planes.values(),
                                e_p.prefill_planes.values()):
        assert plane_c.launches == plane_p.launches
        assert plane_c.chunk_launches == plane_p.chunk_launches > 0
        pa.assert_cache_hit_invariant(plane_c.fns)


@needs_multi
def test_sharded_prefill_attn_layer_pads_nondividing_window(smoke_setup):
    """Unit check of the sequence-sharded layer body on a window that does
    NOT divide the model axis (36 tokens, 8 shards): outputs match the
    replicated path to numerical tolerance."""
    cfg, params = smoke_setup("qwen2-0.5b")
    p0 = M.get_layer(params, 0)
    pm = PlaneMesh.resolve("model=8")
    B, T = 2, 36
    h = jax.random.normal(jax.random.PRNGKey(3), (B, T, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    tmask = jnp.ones((B, T), bool)
    smask = jnp.ones((B,), bool)
    x_ref, (k_ref, v_ref) = M.prefill_attn_layer_batched(
        p0, cfg, h, pos, tmask, smask)
    x_cp, (k_cp, v_cp) = M.prefill_attn_layer_batched(
        p0, cfg, h, pos, tmask, smask, plane_mesh=pm)
    np.testing.assert_allclose(np.asarray(x_cp), np.asarray(x_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(k_cp), np.asarray(k_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(v_cp), np.asarray(v_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Hybrid (jamba): HEAD-sharded pools + sequence-sharded prefill smoke
# ---------------------------------------------------------------------------

@needs_multi
def test_jamba_hybrid_sharded_smoke(smoke_setup):
    """jamba smoke (Hkv=2, model=2 -> HEAD-sharded decode pool; mamba
    stages replicated): sharded staged decode + sharded prefill plane
    match the single-device default end to end."""
    cfg, params = smoke_setup("jamba-v0.1-52b")
    pm = PlaneMesh.resolve("model=2")
    assert pm.pool_shard_mode(cfg) == "heads"
    _, t0 = _run_engine(cfg, params, (48, 64))
    e2, t2 = _run_engine(cfg, params, (48, 64), mesh_spec="model=2")
    assert t2 == t0
    [plane] = e2.planes.values()
    assert plane.staged_fns is staged_fns_for(cfg, "ref", pm)
    pa.assert_cache_hit_invariant(plane.staged_fns)


# ---------------------------------------------------------------------------
# Wide sweep (slow)
# ---------------------------------------------------------------------------

@needs_multi
@pytest.mark.slow
@pytest.mark.parametrize("arch,mesh", [
    ("qwen2-0.5b", "model=2"),          # GQA, block mode
    ("jamba-v0.1-52b", "model=2"),      # hybrid, head mode
    ("minicpm3-4b", "model=2"),         # MLA latent pool, block mode
    ("kimi-k2-1t-a32b", "model=2"),     # MoE epilogue under sharded attn
    ("whisper-small", "model=2"),       # enc-dec cross-attn in the window
    ("qwen2-0.5b", "model=8"),
])
def test_sharded_planes_greedy_sweep(smoke_setup, arch, mesh):
    cfg, params = smoke_setup(arch)
    _, t0 = _run_engine(cfg, params, (48, 64, 72), gen=5)
    _, tc = _run_engine(cfg, params, (48, 64, 72), gen=5, mesh_spec=mesh)
    _, ts = _run_engine(cfg, params, (48, 64, 72), gen=5,
                        batched_decode=False)
    assert tc == t0 == ts
