"""KV-cache data-plane regression tests (no hypothesis): HostPool bounds
checking and the count-each-transfer-exactly-once h2d invariant."""
import numpy as np
import pytest

from repro.core.kv_cache import (HBMCache, HostPool, KVCacheManager,
                                 KVGeometry)


def geom(layers=2, heads=2, bs=8, hd=16):
    return KVGeometry(num_layers=layers, num_kv_heads=heads, block_size=bs,
                      head_dim=hd)


# ---------------------------------------------------------------------------
# HostPool bounds (regression: silent out-of-range scatter)
# ---------------------------------------------------------------------------

def test_save_contiguous_beyond_capacity_raises():
    g = geom(layers=1, heads=2, bs=8, hd=4)
    pool = HostPool(g, num_blocks=2)                  # 16 tokens max
    k = np.zeros((2, 8, 4), np.float32)
    pool.save_contiguous(0, 8, k, k)                  # tokens [8, 16) ok
    pool.flush()
    with pytest.raises(ValueError, match="exceed the registered pool"):
        pool.save_contiguous(0, 9, k, k)              # tokens [9, 17) overflow
    with pytest.raises(ValueError, match="exceed the registered pool"):
        pool.save_contiguous(0, 16, k, k)             # entirely past the end


def test_flush_rejects_stale_overflow_staging():
    """Even if staging is corrupted directly, flush fails loudly instead of
    scattering into a neighbouring block."""
    g = geom(layers=1, heads=1, bs=8, hd=4)
    pool = HostPool(g, num_blocks=2)
    pool._staging.append((0, 12, np.zeros((1, 8, 4), np.float32), None))
    with pytest.raises(ValueError, match="only has 2 blocks"):
        pool.flush()


def test_gather_out_of_range_raises():
    g = geom(layers=1, heads=1, bs=8, hd=4)
    pool = HostPool(g, num_blocks=4)
    with pytest.raises(ValueError, match="out of range"):
        pool.gather(0, [0, 4])
    with pytest.raises(ValueError, match="out of range"):
        pool.gather(0, [-1])                  # numpy would silently wrap


# ---------------------------------------------------------------------------
# Exactly-once h2d accounting across control plane + data plane
# ---------------------------------------------------------------------------

def test_access_books_residency_only():
    c = HBMCache(geom(), capacity_blocks=8)
    missing = c.access(0, [0, 1, 2])
    assert missing == [0, 1, 2]
    assert c.stats.misses == 3 and c.stats.h2d_calls == 0
    assert c.stats.h2d_blocks == 0 and c.stats.h2d_bytes == 0


def test_total_stats_counts_each_transfer_once():
    """Decode path: access (residency) + load_blocks_fused (data plane)
    must yield h2d_blocks == misses * kv_heads, not double."""
    g = geom()
    mgr = KVCacheManager(g, hbm_budget_bytes=1 << 20)
    mgr.register("r1", max_tokens=64, hbm_blocks_per_request=8)
    mgr.register("r2", max_tokens=64, hbm_blocks_per_request=8)
    missing = {}
    for rid in ("r1", "r2"):
        missing[rid] = mgr.caches[rid].access(0, [0, 1, 2])
    out = mgr.load_blocks_fused(0, missing)
    s = mgr.total_stats()
    assert s.misses == 6
    assert s.h2d_blocks == 6 * g.num_kv_heads        # exactly once
    assert s.h2d_calls == 1                          # ONE fused launch
    expect_bytes = 6 * g.block_bytes_per_head * 2 * g.num_kv_heads
    # gather returns float32 host arrays (4B) vs geometry's bf16 accounting;
    # assert against the actual array sizes instead
    total = sum(k.nbytes * (1 if v is None else 2)
                for k, v in out.values())
    assert s.h2d_bytes == total
    assert expect_bytes > 0                          # geometry sanity


def test_fused_load_one_call_per_layer():
    g = geom(layers=3)
    mgr = KVCacheManager(g, hbm_budget_bytes=1 << 20)
    for rid in ("a", "b", "c"):
        mgr.register(rid, max_tokens=64, hbm_blocks_per_request=4)
    for layer in range(3):
        mgr.load_blocks_fused(layer, {"a": [0], "b": [1], "c": [0, 1]})
    s = mgr.total_stats()
    assert s.h2d_calls == 3                          # one per layer
    assert s.h2d_blocks == 3 * 4 * g.num_kv_heads


def test_fused_load_empty_is_free():
    g = geom()
    mgr = KVCacheManager(g, hbm_budget_bytes=1 << 20)
    mgr.register("r1", max_tokens=64, hbm_blocks_per_request=4)
    assert mgr.load_blocks_fused(0, {}) == {}
    assert mgr.load_blocks_fused(0, {"r1": []}) == {}
    assert mgr.total_stats().h2d_calls == 0


def test_load_blocks_still_accounts_for_single_request_use():
    g = geom(layers=1, heads=2, bs=8, hd=4)
    pool = HostPool(g, num_blocks=4)
    k, v = pool.load_blocks(0, [0, 2])
    assert k.shape == (2, 2, 8, 4)
    assert pool.stats.h2d_calls == 1
    assert pool.stats.h2d_blocks == 2 * g.num_kv_heads


def test_access_layer_books_residency_and_drains_evictions():
    """The per-layer control-plane call the decode planes share: misses per
    request, no transfer accounting, optional eviction drain."""
    g = geom()
    mgr = KVCacheManager(g, hbm_budget_bytes=1 << 20)
    mgr.register("r1", max_tokens=64, hbm_blocks_per_request=2)
    mgr.caches["r1"].track_evictions = True
    missing, evicted = mgr.access_layer(0, {"r1": [0, 1], "gone": [5]},
                                        drain_evicted=True)
    assert missing == {"r1": [0, 1]}           # unknown request skipped
    assert evicted == {"r1": []}
    missing, evicted = mgr.access_layer(0, {"r1": [2, 3]},
                                        drain_evicted=True)
    assert missing == {"r1": [2, 3]}
    assert set(evicted["r1"]) == {(0, 0), (0, 1)}     # 2-block LRU overflow
    s = mgr.total_stats()
    assert s.h2d_calls == 0 and s.h2d_bytes == 0      # residency only
    assert s.misses == 4 and s.evictions == 2


# ---------------------------------------------------------------------------
# Hybrid working-set estimation: recurrent layers hold no paged KV
# ---------------------------------------------------------------------------

def test_hybrid_ws_estimates_count_attention_layers_only():
    """Jamba-style configs: the geometry tracks the 1 attention layer of a
    2-layer model; Algorithm 1's estimates must scale by THAT count, not the
    model depth, or hybrid batches get over-throttled."""
    from repro.core.scheduler import Scheduler, SchedulerConfig
    from repro.core.working_set import (DecodeWorkingSet,
                                        estimate_decode_ws_bytes,
                                        estimate_prefill_ws_bytes)
    from repro.serving.request import Phase, Request

    g_attn = geom(layers=1)                  # attention-only geometry
    per_lb = g_attn.block_bytes_per_head * g_attn.num_kv_heads
    sched = Scheduler(SchedulerConfig(), g_attn, num_layers=2,
                      top_k_blocks=8)
    assert sched.num_attn_layers == 1        # defaults to geom.num_layers
    req = Request(prompt_len=64, max_new_tokens=4)
    req.phase = Phase.DECODE
    # cold-start worst case: top-k blocks per ATTENTION layer (x1, not x2)
    assert sched._estimate_ws(req) == 8 * 1 * per_lb
    assert estimate_decode_ws_bytes(DecodeWorkingSet(g_attn), g_attn,
                                    8, 1) == 8 * per_lb
    # chunked prefill WS likewise scales by the attention-layer count; a
    # full-model geometry can override explicitly
    g_full = geom(layers=2)
    assert estimate_prefill_ws_bytes(g_full, 128, "chunked",
                                     num_attn_layers=1) == \
        estimate_prefill_ws_bytes(g_full, 128, "layer_segmented")
    assert estimate_prefill_ws_bytes(g_full, 128, "chunked") == \
        2 * estimate_prefill_ws_bytes(g_full, 128, "layer_segmented")


# ---------------------------------------------------------------------------
# Working-set arbitration for the MIXED iteration (Algorithm 1 over both
# phases of one hybrid batch): decode rows claim HBM first, the prefill
# watermark takes what remains — both sides from estimate_*_ws_bytes
# ---------------------------------------------------------------------------

def _mk_mixed_sched(m_avl, g, num_attn_layers=None, r_max=8):
    from repro.core.scheduler import Scheduler, SchedulerConfig
    return Scheduler(SchedulerConfig(
        r_max=r_max, m_avl_bytes=m_avl, max_inject_tokens=1024,
        ws_control=True), g, num_layers=g.num_layers, top_k_blocks=8,
        num_attn_layers=num_attn_layers)


def test_mixed_plan_reports_both_ws_claims():
    """A mixed BatchPlan carries the arbitration record: ws_decode_bytes
    is exactly the admitted decode rows' estimates, ws_prefill_bytes the
    admitted prefill rows' watermark estimates, and their sum held under
    m_avl (what the hybrid plane's controller arbitrated)."""
    from repro.serving.request import Phase, Request

    g = geom(layers=2)
    per_lb = g.block_bytes_per_head * g.num_kv_heads
    cold = 8 * 2 * per_lb                    # cold decode WS (top-k x layers)
    s = _mk_mixed_sched(m_avl=1 << 30, g=g)
    dec = Request(prompt_len=64, max_new_tokens=8)
    dec.phase = Phase.DECODE
    s.running.append(dec)
    pre = Request(prompt_len=128, max_new_tokens=8)
    s.add_request(pre)
    plan = s.schedule()
    assert [r.req_id for r in plan.decode_reqs] == [dec.req_id]
    assert [r.req_id for r, _ in plan.prefill_reqs] == [pre.req_id]
    assert plan.ws_decode_bytes == s._estimate_ws(dec) == cold
    assert plan.ws_prefill_bytes == s._estimate_ws(pre)
    assert (plan.ws_decode_bytes + plan.ws_prefill_bytes
            <= s.cfg.m_avl_bytes)


def test_mixed_arbitration_decode_first_prefill_takes_rest():
    """With m_avl sized for the decode row plus ONE layer-segmented
    prefill watermark, decode is admitted first and exactly one of two
    waiting prefills fits; halving m_avl below the decode claim empties
    the whole mixed batch (batch-size control, Fig. 1)."""
    from repro.core.working_set import estimate_prefill_ws_bytes
    from repro.serving.request import Phase, Request

    g = geom(layers=2)
    per_lb = g.block_bytes_per_head * g.num_kv_heads
    cold = 8 * 2 * per_lb
    pre_ws = estimate_prefill_ws_bytes(g, 128, "layer_segmented")

    def build(m_avl):
        s = _mk_mixed_sched(m_avl=m_avl, g=g)
        dec = Request(prompt_len=64, max_new_tokens=8)
        dec.phase = Phase.DECODE
        s.running.append(dec)
        for _ in range(2):
            s.add_request(Request(prompt_len=128, max_new_tokens=8))
        return s, s.schedule()

    s, plan = build(cold + pre_ws)
    assert len(plan.decode_reqs) == 1
    assert len(plan.prefill_reqs) == 1       # second prefill rejected
    assert plan.rejected == 1
    assert plan.ws_decode_bytes == cold
    assert plan.ws_prefill_bytes == pre_ws
    _, starved = build(cold - 1)             # decode WS alone doesn't fit
    assert not starved.decode_reqs
    assert starved.ws_decode_bytes == 0


def test_mixed_arbitration_hybrid_attn_layer_scaling():
    """The same mixed workload admits MORE under a hybrid (jamba-style)
    attention-layer count: halving num_attn_layers halves the decode
    cold-start claim, so a prefill that was rejected now fits (the PR 3
    scaling, now visible through the plan's arbitration record)."""
    from repro.serving.request import Phase, Request

    g = geom(layers=2)
    per_lb = g.block_bytes_per_head * g.num_kv_heads

    from repro.core.working_set import estimate_prefill_ws_bytes
    pre_ws = estimate_prefill_ws_bytes(g, 4, "layer_segmented",
                                       num_attn_layers=1)

    def build(num_attn_layers):
        # fits the 1-attn-layer cold decode claim + the small prefill, but
        # NOT the full-depth cold claim
        s = _mk_mixed_sched(m_avl=8 * 1 * per_lb + pre_ws, g=g,
                            num_attn_layers=num_attn_layers)
        dec = Request(prompt_len=64, max_new_tokens=8)
        dec.phase = Phase.DECODE
        s.running.append(dec)
        s.add_request(Request(prompt_len=4, max_new_tokens=8))
        return s.schedule()

    full = build(2)                          # cold claim 8*2 blocks > m_avl
    assert not full.decode_reqs and full.rejected >= 1
    hybrid = build(1)                        # cold claim 8*1 blocks fits
    assert len(hybrid.decode_reqs) == 1
    assert hybrid.ws_decode_bytes == 8 * 1 * per_lb
    assert hybrid.ws_prefill_bytes > 0       # leftover admits the prefill
