"""Unified hybrid-batching plane (core/hybrid_plane.py): the MIXED
iteration — decode rows and same-(layer, chunk) prefill segments riding
ONE layer walk with ONE per-layer host stage — is proven byte-identical
to the split two-plane path ("split" oracle knob) and to the sequential
decode loop, across arch families, under 1-block-LRU eviction pressure,
sharded and unsharded, and across randomized interleaved arrival
schedules.  Launch counts per iteration are contract-backed
(planeasserts.assert_mixed_launch_invariant <->
plane_contract.mixed_launches_per_iteration)."""

import jax
import numpy as np
import pytest

from repro.core.hybrid_plane import hybrid_fns_for
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Phase, Request

import planeasserts as pa

N_DEV = len(jax.devices())
needs_multi = pytest.mark.skipif(
    N_DEV < 8, reason="needs 8 forced host devices (CI multi-device job: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                      # container without hypothesis:
    HAVE_HYPOTHESIS = False              # the seeded harness below still runs


def _run(cfg, params, prompts, gen=3, seed=7, arrivals=None, enc_lens=None,
         **kw):
    kw.setdefault("r_max", 4)
    kw.setdefault("chunk_size", 64)
    eng = ServingEngine(params, cfg, EngineConfig(**kw))
    rng = np.random.default_rng(seed)
    order = []
    for i, p in enumerate(prompts):
        extra = {}
        if cfg.is_encoder_decoder:
            S_enc = enc_lens[i] if enc_lens else 16
            extra["frames"] = np.ones((1, S_enc, cfg.d_model),
                                      np.float32) * .01
        if cfg.frontend == "vit_patch_stub":
            extra["patch_embeds"] = np.ones(
                (1, cfg.num_patches, cfg.d_model), np.float32) * .01
        toks = rng.integers(4, cfg.vocab_size, p).astype(np.int32)
        r = Request(prompt_len=p, max_new_tokens=gen,
                    arrival_time=(arrivals[i] if arrivals else 0.0))
        eng.submit(r, tokens=toks, **extra)
        order.append(r.req_id)
    eng.run()
    return eng, [eng.states[rid].out_tokens for rid in order]


PROMPTS = (48, 96, 72, 64)
# later arrivals land mid-decode of the first two rows -> truly mixed
# iterations (decode rows AND prefill segments in one layer walk)
STAGGER = (0.0, 0.0, 1e-4, 3e-3)


# ---------------------------------------------------------------------------
# Default + "split" oracle knob
# ---------------------------------------------------------------------------

def test_mixed_is_default_and_resolution(smoke_setup):
    """hybrid_plane defaults to "mixed" and auto-resolves to "split"
    whenever any required sub-plane (staged decode, plane prefill,
    batched decode, layer-segmented mode) is disabled."""
    cfg, params = smoke_setup("qwen2-0.5b")
    assert EngineConfig().hybrid_plane == "mixed"
    eng = ServingEngine(params, cfg, EngineConfig())
    assert eng.hybrid is not None and eng.eng.hybrid_plane == "mixed"
    for kw in (dict(batched_decode=False),
               dict(decode_plane="persistent"),
               dict(prefill_exec="legacy"),
               dict(prefill_mode="chunked")):
        e = ServingEngine(params, cfg, EngineConfig(**kw))
        assert e.eng.hybrid_plane == "split" and e.hybrid is None, kw
    with pytest.raises(ValueError):
        ServingEngine(params, cfg, EngineConfig(hybrid_plane="bogus"))


@pytest.fixture(scope="module")
def qwen_runs(smoke_setup):
    """Mixed (default) / split oracle / sequential oracle over the same
    staggered-arrival 4-request workload, with chunked segments."""
    cfg, params = smoke_setup("qwen2-0.5b")
    kw = dict(gen=4, arrivals=STAGGER, prefill_max_tokens_per_step=32)
    return {
        "mixed": _run(cfg, params, PROMPTS, **kw),
        "split": _run(cfg, params, PROMPTS, hybrid_plane="split", **kw),
        "sequential": _run(cfg, params, PROMPTS, batched_decode=False, **kw),
    }


def test_mixed_matches_split_and_sequential(qwen_runs):
    """Acceptance: mixed greedy tokens are byte-identical to the split
    two-plane path AND the sequential decode loop."""
    e_m, toks_m = qwen_runs["mixed"]
    _, toks_s = qwen_runs["split"]
    _, toks_q = qwen_runs["sequential"]
    assert toks_m == toks_s == toks_q
    assert all(len(t) == 4 for t in toks_m)
    assert e_m.hybrid.iterations == len(e_m.mixed_iter_log) > 0


def test_iterations_are_truly_mixed_and_launch_invariant(qwen_runs):
    """The staggered arrivals produce at least one iteration carrying
    decode rows AND prefill rows together, and every iteration obeys the
    contract-backed fused-transfer/launch budget."""
    e_m, _ = qwen_runs["mixed"]
    assert any(e["decode_rows"] > 0 and e["prefill_rows"] > 0
               for e in e_m.mixed_iter_log), \
        [(e["decode_rows"], e["prefill_rows"]) for e in e_m.mixed_iter_log]
    pa.assert_mixed_launch_invariant(e_m)


def test_split_oracle_keeps_two_plane_path(qwen_runs):
    """The "split" knob really runs the legacy two-plane step: no hybrid
    driver, no mixed log — a live oracle, not a renamed alias."""
    e_s, toks_s = qwen_runs["split"]
    assert e_s.hybrid is None
    assert e_s.mixed_iter_log == []
    assert all(len(t) == 4 for t in toks_s)


def test_hybrid_registry_composes_existing_jits(qwen_runs):
    """_HybridFns adds ZERO new traces: it composes the staged decode and
    prefill registries, so its counters are exactly their sums and both
    underlying caches keep the one-trace-per-shape-bucket invariant."""
    e_m, _ = qwen_runs["mixed"]
    fns = hybrid_fns_for(e_m.cfg, e_m.eng.attn_impl, e_m.plane_mesh)
    assert fns.contract_protocol == "hybrid-plane"
    [plane] = e_m.planes.values()
    assert fns.decode is plane.staged_fns          # composition, not a copy
    assert fns.calls == fns.decode.calls + fns.prefill.calls > 0
    assert fns.trace_count == (fns.decode.trace_count
                               + fns.prefill.trace_count)
    pa.assert_cache_hit_invariant(fns.decode)
    pa.assert_cache_hit_invariant(fns.prefill)
    # same key -> same composed object (registry cache hit)
    assert hybrid_fns_for(e_m.cfg, e_m.eng.attn_impl, e_m.plane_mesh) is fns


# ---------------------------------------------------------------------------
# Arch families x eviction pressure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "minicpm3-4b",
                                  "jamba-v0.1-52b", "whisper-small",
                                  "kimi-k2-1t-a32b"])
def test_mixed_equals_split_across_archs_under_pressure(arch, smoke_setup):
    """Acceptance: >=4 smoke archs (GQA, MLA, hybrid mamba, enc-dec, MoE),
    each under a 1-block LRU budget that forces evictions, staggered so
    prefill rides decode iterations — mixed == split, launch invariant
    holds."""
    cfg, params = smoke_setup(arch)
    kw = dict(gen=3, arrivals=(0.0, 1e-4, 3e-3), hbm_blocks_per_request=1)
    e_m, toks_m = _run(cfg, params, (48, 64, 72), **kw)
    _, toks_s = _run(cfg, params, (48, 64, 72), hybrid_plane="split", **kw)
    assert toks_m == toks_s
    assert all(len(t) == 3 for t in toks_m)
    pa.assert_mixed_launch_invariant(e_m)


def test_mixed_under_pressure_really_evicts(smoke_setup):
    """The pressure runs exercise the LRU: evictions and H2D reload misses
    happen inside mixed iterations, and generation still completes."""
    cfg, params = smoke_setup("qwen2-0.5b")
    e_m, toks = _run(cfg, params, (64, 64, 64), gen=8,
                     hbm_blocks_per_request=1)
    assert all(len(t) == 8 for t in toks)
    s = e_m.transfer_stats()
    assert s.evictions > 0 and s.misses > 0 and s.h2d_calls > 0
    assert any(e["layers"] for e in e_m.mixed_iter_log)
    pa.assert_mixed_launch_invariant(e_m)


def test_whisper_two_decode_groups_share_one_walk(smoke_setup):
    """Unequal encoder KV shapes split decode into two planes; the mixed
    iteration carries BOTH through one layer walk (decode_planes == 2 in
    the log) and still matches split."""
    cfg, params = smoke_setup("whisper-small")
    kw = dict(prompts=(48, 48, 64), gen=3, enc_lens=(16, 16, 24),
              max_inject_tokens=4096)
    e_m, toks_m = _run(cfg, params, **kw)
    _, toks_s = _run(cfg, params, hybrid_plane="split", **kw)
    assert toks_m == toks_s
    assert max(e["decode_planes"] for e in e_m.mixed_iter_log) == 2
    pa.assert_mixed_launch_invariant(e_m)


# ---------------------------------------------------------------------------
# Sharded (PlaneMesh) — tier-1 model=1, CI multi-device model=8
# ---------------------------------------------------------------------------

def test_mixed_equals_split_sharded_model1(smoke_setup):
    """Tier-1 sharded variant: a 1-way PlaneMesh goes through the sharded
    code path on the single CPU device."""
    cfg, params = smoke_setup("qwen2-0.5b")
    kw = dict(gen=3, arrivals=(0.0, 1e-4, 3e-3), mesh_spec="model=1")
    e_m, toks_m = _run(cfg, params, (48, 64, 72), **kw)
    _, toks_s = _run(cfg, params, (48, 64, 72), hybrid_plane="split", **kw)
    assert toks_m == toks_s
    pa.assert_mixed_launch_invariant(e_m)


@needs_multi
def test_mixed_equals_split_sharded_model8(smoke_setup):
    """Acceptance (multi-device CI): 8-way tensor-sharded mixed iteration
    under eviction pressure still matches split exactly."""
    cfg, params = smoke_setup("qwen2-0.5b")
    kw = dict(gen=3, arrivals=(0.0, 1e-4, 3e-3), mesh_spec="model=8",
              hbm_blocks_per_request=1)
    e_m, toks_m = _run(cfg, params, (48, 64, 72), **kw)
    _, toks_s = _run(cfg, params, (48, 64, 72), hybrid_plane="split", **kw)
    assert toks_m == toks_s
    pa.assert_mixed_launch_invariant(e_m)


# ---------------------------------------------------------------------------
# Launches stay O(L), independent of rows
# ---------------------------------------------------------------------------

def test_launches_independent_of_row_count(smoke_setup):
    """Acceptance: per-iteration jitted-launch totals are identical for 1
    and 4 requests on the same plan — bucketed batching, not per-row
    loops (the invariant fixture's formula, measured end to end)."""
    cfg, params = smoke_setup("qwen2-0.5b")

    def launch_seq(n):
        eng, toks = _run(cfg, params, (64,) * n,
                         prefill_max_tokens_per_step=32,
                         max_inject_tokens=4096)
        assert all(len(t) == 3 for t in toks)
        pa.assert_mixed_launch_invariant(eng)
        return [e["launches"] for e in eng.mixed_iter_log]

    seq4, seq1 = launch_seq(4), launch_seq(1)
    assert seq4 == seq1


# ---------------------------------------------------------------------------
# Randomized interleaved arrival schedules (>= 25 in tier-1)
# ---------------------------------------------------------------------------

PROMPT_CHOICES = (24, 48, 64)
ARRIVAL_CHOICES = (0.0, 1e-6, 1e-4, 3e-3)
CAP_CHOICES = (1, 96)                   # eviction pressure | roomy pool


def _schedule_equiv(cfg, params, schedule):
    """mixed == split == sequential over one randomized schedule, plus the
    launch invariant on the mixed run."""
    prompts, gen, arrivals, cap = schedule
    kw = dict(gen=gen, arrivals=arrivals, hbm_blocks_per_request=cap,
              prefill_max_tokens_per_step=32)
    e_m, t_m = _run(cfg, params, prompts, **kw)
    assert e_m.eng.hybrid_plane == "mixed"
    _, t_s = _run(cfg, params, prompts, hybrid_plane="split", **kw)
    _, t_q = _run(cfg, params, prompts, batched_decode=False, **kw)
    assert t_m == t_s == t_q, schedule
    # engine floor: the prefill-sampled token plus >= 1 decode step
    assert all(len(t) == max(gen, 2) for t in t_m), schedule
    assert all(st.req.phase == Phase.FINISHED
               for st in e_m.states.values()), schedule
    pa.assert_mixed_launch_invariant(e_m)


def _draw_schedule(rng):
    """Mixed prompt lengths, staggered admissions mid-decode, finishes
    mid-prefill (short gens + late arrivals), eviction-pressure caps."""
    n = int(rng.integers(1, 4))
    prompts = tuple(int(rng.choice(PROMPT_CHOICES)) for _ in range(n))
    gen = int(rng.integers(1, 4))
    arrivals = tuple(float(rng.choice(ARRIVAL_CHOICES)) for _ in range(n))
    cap = int(rng.choice(CAP_CHOICES))
    return prompts, gen, arrivals, cap


def test_randomized_schedules_seeded(smoke_setup):
    """Acceptance: >= 25 randomized interleaved schedules inside the
    tier-1 budget.  Seeded np.random harness so it ALWAYS runs; the
    hypothesis property below shrinks failures where hypothesis is
    installed."""
    cfg, params = smoke_setup("qwen2-0.5b")
    rng = np.random.default_rng(2026)
    for _ in range(25):
        _schedule_equiv(cfg, params, _draw_schedule(rng))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(data=hst.data())
    def test_randomized_schedules_hypothesis(data, smoke_setup):
        cfg, params = smoke_setup("qwen2-0.5b")
        n = data.draw(hst.integers(1, 3))
        schedule = (
            tuple(data.draw(hst.sampled_from(PROMPT_CHOICES))
                  for _ in range(n)),
            data.draw(hst.integers(1, 3)),
            tuple(data.draw(hst.sampled_from(ARRIVAL_CHOICES))
                  for _ in range(n)),
            data.draw(hst.sampled_from(CAP_CHOICES)),
        )
        _schedule_equiv(cfg, params, schedule)
