"""Hierarchical KV cache manager property tests (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.kv_cache import (HBMCache, HostPool, KVCacheManager,
                                 KVGeometry, TransferStats)

SET = dict(max_examples=40, deadline=None)


def geom(layers=2, heads=2, bs=8, hd=16):
    return KVGeometry(num_layers=layers, num_kv_heads=heads, block_size=bs,
                      head_dim=hd)


# ---------------------------------------------------------------------------
# HBMCache (LRU)
# ---------------------------------------------------------------------------

@given(cap=st.integers(1, 20),
       accesses=st.lists(st.tuples(st.integers(0, 3), st.lists(
           st.integers(0, 30), min_size=1, max_size=8)), max_size=30))
@settings(**SET)
def test_lru_capacity_never_exceeded(cap, accesses):
    c = HBMCache(geom(), cap)
    for layer, blocks in accesses:
        c.access(layer, blocks)
        assert c.num_resident <= cap


@given(cap=st.integers(4, 32), blocks=st.lists(st.integers(0, 10),
                                               min_size=1, max_size=4))
@settings(**SET)
def test_lru_repeat_access_hits(cap, blocks):
    c = HBMCache(geom(), cap)
    missing1 = c.access(0, blocks)
    assert set(missing1) == set(blocks)           # cold cache: all miss
    missing2 = c.access(0, blocks)
    assert missing2 == []                          # warm: all hit
    assert c.stats.hits == len(blocks)


@given(seq=st.lists(st.integers(0, 50), min_size=1, max_size=100))
@settings(**SET)
def test_lru_hit_miss_accounting(seq):
    c = HBMCache(geom(), 16)
    for b in seq:
        c.access(0, [b])
    assert c.stats.hits + c.stats.misses == len(seq)
    # access books residency only; h2d stats belong to the data plane
    # (HostPool.load_blocks / KVCacheManager.load_blocks_fused)
    assert c.stats.h2d_blocks == 0 and c.stats.h2d_calls == 0


def test_lru_eviction_order():
    c = HBMCache(geom(), 2)
    c.access(0, [1])
    c.access(0, [2])
    c.access(0, [1])      # touch 1 -> 2 becomes LRU
    c.access(0, [3])      # evicts 2
    assert c.resident(0, 1) and c.resident(0, 3) and not c.resident(0, 2)


def test_drop_layer():
    c = HBMCache(geom(layers=3), 100)
    c.access(0, [1, 2, 3])
    c.access(1, [1, 2])
    n = c.drop_layer(0)
    assert n == 3 and c.num_resident == 2
    assert not c.resident(0, 1) and c.resident(1, 1)


# ---------------------------------------------------------------------------
# HostPool (FlashD2H two-phase save)
# ---------------------------------------------------------------------------

@given(start=st.integers(0, 40), T=st.integers(1, 60), seed=st.integers(0, 99))
@settings(**SET)
def test_hostpool_save_flush_roundtrip(start, T, seed):
    g = geom(layers=1, heads=2, bs=8, hd=4)
    pool = HostPool(g, num_blocks=16)
    rng = np.random.default_rng(seed)
    T = min(T, 16 * 8 - start)
    if T <= 0:
        return
    k_new = rng.normal(size=(2, T, 4)).astype(np.float32)
    v_new = rng.normal(size=(2, T, 4)).astype(np.float32)
    pool.save_contiguous(0, start, k_new, v_new)
    pool.flush()
    # read back token-by-token
    for t in range(T):
        blk, off = (start + t) // 8, (start + t) % 8
        np.testing.assert_array_equal(pool.k[0, :, blk, off], k_new[:, t])
        np.testing.assert_array_equal(pool.v[0, :, blk, off], v_new[:, t])


def test_hostpool_transfer_accounting():
    g = geom(layers=1, heads=2, bs=8, hd=4)
    pool = HostPool(g, num_blocks=4)
    k = np.zeros((2, 16, 4), np.float32)
    pool.save_contiguous(0, 0, k, k)
    assert pool.stats.d2h_calls == 1              # ONE contiguous memcpy
    assert pool.stats.d2h_bytes == k.nbytes * 2
    pool.flush()
    assert pool.stats.d2h_blocks == 2             # scattered into 2 blocks
    k2, v2 = pool.load_blocks(0, [0, 1])
    assert pool.stats.h2d_calls == 1              # ONE fused gather
    assert k2.shape == (2, 2, 8, 4)


# ---------------------------------------------------------------------------
# KVCacheManager
# ---------------------------------------------------------------------------

def test_manager_lifecycle_and_stats_retention():
    g = geom()
    mgr = KVCacheManager(g, hbm_budget_bytes=1 << 20)
    mgr.register("r1", max_tokens=64, hbm_blocks_per_request=4)
    mgr.caches["r1"].access(0, [0, 1, 2])
    used = mgr.hbm_used_bytes()
    assert used == 3 * g.block_bytes_per_head * g.num_kv_heads
    mgr.release("r1")
    assert mgr.hbm_used_bytes() == 0
    # stats survive release
    assert mgr.total_stats().misses == 3


@given(bs=st.integers(1, 64), hd=st.integers(1, 256), heads=st.integers(1, 16),
       layers=st.integers(1, 80))
@settings(**SET)
def test_geometry_byte_math(bs, hd, heads, layers):
    g = KVGeometry(num_layers=layers, num_kv_heads=heads, block_size=bs,
                   head_dim=hd)
    assert g.block_bytes_per_head == bs * hd * 2 * 2
    assert g.block_bytes == g.block_bytes_per_head * heads * layers
    assert g.tokens_bytes(bs) == g.block_bytes
