"""Batched jitted prefill plane (core/prefill_plane.py): greedy equivalence
with the legacy per-request layer-segmented executor AND the chunked-prefill
baseline, chunked-segment execution (the (layer, chunk) steps plan_segments
emits are now honored — the former dead code), launch/trace bounds, fused
FlashD2H accounting, slot reuse, and the batched prefill HBM watermark."""

import numpy as np
import pytest

from repro.core.layer_prefill import plan_segments
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Phase, Request

import planeasserts as pa


def _run_engine(cfg, params, prompts, gen=4, seed=7, enc_lens=None, **kw):
    kw.setdefault("r_max", 4)
    kw.setdefault("chunk_size", 64)
    eng = ServingEngine(params, cfg, EngineConfig(**kw))
    rng = np.random.default_rng(seed)
    order = []
    for i, p in enumerate(prompts):
        extra = {}
        if cfg.is_encoder_decoder:
            S_enc = enc_lens[i] if enc_lens else 16
            extra["frames"] = np.ones((1, S_enc, cfg.d_model),
                                      np.float32) * .01
        if cfg.frontend == "vit_patch_stub":
            extra["patch_embeds"] = np.ones(
                (1, cfg.num_patches, cfg.d_model), np.float32) * .01
        toks = rng.integers(4, cfg.vocab_size, p).astype(np.int32)
        r = Request(prompt_len=p, max_new_tokens=gen)
        eng.submit(r, tokens=toks, **extra)
        order.append(r.req_id)
    eng.run()
    return eng, [eng.states[rid].out_tokens for rid in order]


PROMPTS = (48, 96, 72, 64)          # >= 4 concurrent requests (acceptance)


@pytest.fixture(scope="module")
def gqa_runs(smoke_setup):
    """Plane (default) / chunked-segment plane / legacy executor / chunked
    prefill baseline over the same 4-request mixed-length workload."""
    cfg, params = smoke_setup("qwen2-0.5b")
    return {
        "plane": _run_engine(cfg, params, PROMPTS),
        "plane_chunked": _run_engine(cfg, params, PROMPTS,
                                     prefill_max_tokens_per_step=32),
        "legacy": _run_engine(cfg, params, PROMPTS, prefill_exec="legacy"),
        "chunked_mode": _run_engine(cfg, params, PROMPTS,
                                    prefill_mode="chunked", chunk_size=32),
    }


def test_plane_is_default_and_matches_legacy_oracle(gqa_runs):
    """Acceptance: with >= 4 concurrent requests the plane's greedy outputs
    are token-identical to the legacy per-request layer-segmented oracle."""
    e_p, toks_p = gqa_runs["plane"]
    e_l, toks_l = gqa_runs["legacy"]
    assert e_p.eng.prefill_exec == "plane"          # the default
    assert toks_p == toks_l
    assert all(len(t) == 4 for t in toks_p)
    assert e_p.prefill_launches > 0
    assert e_l.prefill_launches == 0                # legacy never launches


def test_plane_matches_chunked_prefill_baseline(gqa_runs):
    """Acceptance: plane outputs are also token-identical to chunked
    prefill (the paper's baseline mode)."""
    _, toks_p = gqa_runs["plane"]
    _, toks_c = gqa_runs["chunked_mode"]
    assert toks_p == toks_c


def test_chunked_segments_executed_and_equivalent(gqa_runs):
    """Satellite regression (the former dead code): plan_segments' intra-
    layer (layer, chunk) steps are EXECUTED by the plane — launches with
    chunk_start > 0 happen — and chunked-segment outputs equal whole-layer
    and legacy outputs."""
    e_c, toks_c = gqa_runs["plane_chunked"]
    _, toks_p = gqa_runs["plane"]
    _, toks_l = gqa_runs["legacy"]
    assert toks_c == toks_p == toks_l
    planes = list(e_c.prefill_planes.values())
    assert sum(p.chunk_launches for p in planes) > 0
    # the plan really contains chunks (96-token prompt, 32-token steps)
    segs = plan_segments(96, e_c.cfg.num_layers, 32)
    assert any(s.chunk_start > 0 for s in segs)
    # while the plain plan does not
    assert all(s.chunk_start == 0
               for s in plan_segments(96, e_c.cfg.num_layers, 96))


def test_one_launch_per_layer_chunk_group_per_iteration(smoke_setup):
    """Acceptance: concurrent same-plan requests BATCH — the plane issues
    ONE jitted launch per (layer, chunk-bucket) per iteration, independent
    of the batch size."""
    cfg, params = smoke_setup("qwen2-0.5b")

    def launches(n_reqs):
        # inject budget large enough that the scheduler admits EVERY
        # request's full prefill in one hybrid iteration
        eng, _ = _run_engine(cfg, params, (64,) * n_reqs,
                             prefill_max_tokens_per_step=32,
                             max_inject_tokens=4096)
        [plane] = eng.prefill_planes.values()
        return eng, plane

    e4, p4 = launches(4)
    e1, p1 = launches(1)
    n_chunks = 2                        # 64-token prompt / 32-token steps
    expected = cfg.num_layers * n_chunks
    # all 4 requests prefill together in ONE iteration: exactly one launch
    # per (layer, chunk) group, NOT per request
    assert p4.launches == expected == p1.launches
    assert p4.iterations == 1
    assert p4.admits == 4 and p4.b_cap >= 4
    assert e4.prefill_launches == expected


def test_plane_retraces_bounded_by_shape_signatures(gqa_runs):
    """The decode plane's cache-hit invariant, for prefill: one XLA trace
    per distinct (stage, shape signature); launches at policy bucket
    shapes only."""
    for key in ("plane", "plane_chunked"):
        e, _ = gqa_runs[key]
        for plane in e.prefill_planes.values():
            fns = plane.fns
            pa.assert_cache_hit_invariant(fns)
            pol = e.eng.bucketing
            assert plane.buckets_seen
            for b_cap, t_cap in plane.buckets_seen:
                assert b_cap == pol.bucket_batch(b_cap)
            # many launches share few compiled shapes
            assert len(plane.buckets_seen) < plane.launches


def test_prefill_hbm_watermark_one_layer_for_whole_batch(gqa_runs):
    """Acceptance: the measured prefill HBM watermark (batched, per
    iteration) stays bounded by ONE layer of KV for the whole batch, while
    chunked prefill's grows with all layers of every processed token."""
    e_p, _ = gqa_runs["plane"]
    e_c, _ = gqa_runs["plane_chunked"]
    e_m, _ = gqa_runs["chunked_mode"]
    bound = sum(PROMPTS)                  # one layer of the whole batch
    assert 0 < e_p.prefill_hbm_peak_tokens <= bound
    assert 0 < e_c.prefill_hbm_peak_tokens <= bound
    # chunked: whole-batch whole-prompt residency x all layers at the peak
    assert e_m.prefill_hbm_peak_tokens > bound
    assert e_m.prefill_hbm_peak_tokens <= bound * e_m.cfg.num_layers


def test_fused_d2h_one_call_per_group_not_per_request(gqa_runs):
    """The plane replaces per-request save_contiguous calls with ONE fused
    FlashD2H save per (layer, chunk) group: fewer d2h launches than the
    legacy executor on the same workload, same bytes and blocks moved."""
    e_p, _ = gqa_runs["plane"]
    e_l, _ = gqa_runs["legacy"]
    s_p, s_l = e_p.transfer_stats(), e_l.transfer_stats()
    assert s_p.d2h_calls < s_l.d2h_calls
    assert s_p.d2h_bytes == s_l.d2h_bytes
    assert s_p.d2h_blocks == s_l.d2h_blocks


@pytest.mark.parametrize("arch,step", [("minicpm3-4b", 0),
                                       ("jamba-v0.1-52b", 24),
                                       ("whisper-small", 24)])
def test_plane_equivalence_across_arch_families(arch, step, smoke_setup):
    """Satellite coverage: MLA (whole-layer only — no latent-context
    chunk path), jamba-style hybrid (masked mamba recurrence), and whisper
    enc-dec (cross-attention KV rows) all match the legacy oracle, with
    chunked segments where supported."""
    cfg, params = smoke_setup(arch)
    prompts = (48, 64, 72)
    _, toks_l = _run_engine(cfg, params, prompts, gen=3,
                            prefill_exec="legacy")
    e_p, toks_p = _run_engine(cfg, params, prompts, gen=3)
    assert toks_p == toks_l
    if step:
        e_c, toks_c = _run_engine(cfg, params, prompts, gen=3,
                                  prefill_max_tokens_per_step=step)
        assert toks_c == toks_l
        assert sum(p.chunk_launches
                   for p in e_c.prefill_planes.values()) > 0
    else:
        # MLA ignores the chunk knob (planner falls back to whole layers)
        e_c, toks_c = _run_engine(cfg, params, prompts, gen=3,
                                  prefill_max_tokens_per_step=24)
        assert toks_c == toks_l
        assert sum(p.chunk_launches
                   for p in e_c.prefill_planes.values()) == 0
    for p in e_p.prefill_planes.values():
        pa.assert_cache_hit_invariant(p.fns)


def test_whisper_groups_by_encoder_length(smoke_setup):
    """Requests with unequal encoder KV shapes cannot share a launch; the
    engine keeps one plane per group and still matches the legacy
    executor."""
    cfg, params = smoke_setup("whisper-small")
    kw = dict(prompts=(48, 48, 64), gen=3, enc_lens=(16, 16, 24))
    e_p, toks_p = _run_engine(cfg, params, **kw)
    _, toks_l = _run_engine(cfg, params, prefill_exec="legacy", **kw)
    assert toks_p == toks_l
    assert len(e_p.prefill_planes) == 2          # one per encoder shape


def test_plane_row_reuse_and_release(smoke_setup):
    """A finished request's plane row is released and reused by a later
    admission (slot lifecycle mirrors the decode plane)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    eng = ServingEngine(params, cfg, EngineConfig(r_max=2))
    rng = np.random.default_rng(3)
    reqs = [Request(prompt_len=48, max_new_tokens=2),
            Request(prompt_len=48, max_new_tokens=2),
            Request(prompt_len=48, max_new_tokens=2, arrival_time=1e-6)]
    for r in reqs:
        eng.submit(r, tokens=rng.integers(4, cfg.vocab_size,
                                          r.prompt_len).astype(np.int32))
    eng.run()
    assert all(r.phase == Phase.FINISHED for r in reqs)
    [plane] = eng.prefill_planes.values()
    assert plane.admits == 3
    assert plane.rows_reused >= 1
    assert len(plane.rows) == 0                  # all rows freed
    assert not eng._req_prefill_plane


def test_watermark_counts_only_attention_layers(smoke_setup):
    """Recurrent (mamba) layers hold no paged KV: a hybrid row's watermark
    peak is its chunk progress through ATTENTION layers and exactly 0
    while a recurrent layer's segments run."""
    import jax.numpy as jnp

    from repro.core.prefill_plane import PrefillPlane
    from repro.models import model as M

    cfg, params = smoke_setup("jamba-v0.1-52b")
    h, _, _ = M.prefill_embed(
        params, cfg, {"tokens": jnp.arange(5, 53, dtype=jnp.int32)[None]})
    plane = PrefillPlane(cfg)
    segs = plan_segments(48, cfg.num_layers, 16)       # 3 chunks per layer
    plane.admit("r0", h, segs)
    kinds_seen = set()
    while not plane.done("r0"):
        seg = segs[plane.next_idx["r0"]]
        kind = "attn" if M.layer_kind(cfg, seg.layer) == "attn" else "rec"
        kinds_seen.add(kind)
        res = plane.run_iteration(params, {"r0": 1})   # exactly one segment
        expected = (seg.chunk_start + seg.chunk_len if kind == "attn"
                    else 0)
        assert res.peaks["r0"] == expected, (seg, kind)
    assert kinds_seen == {"attn", "rec"}               # both cases hit


def test_admission_embed_batched_one_launch(smoke_setup):
    """Bugfix: admission-time embedding is BATCHED — every pure-text
    request admitted in an iteration shares ONE bucketed embed launch
    (``_AdmitEmbedFns``), and bucketed shapes make admission batch sizes
    3 and 4 share ONE compiled trace (cache-hit invariant:
    trace_count == len(shape_signatures))."""
    from repro.core.prefill_plane import admit_embed_fns_for
    cfg, params = smoke_setup("qwen2-0.5b")
    fns = admit_embed_fns_for(cfg)
    traced = {}
    for n in (3, 4):
        c0, t0 = fns.calls, fns.trace_count
        # inject budget large enough that every request is admitted (and
        # hence embedded) in the SAME hybrid iteration
        eng, toks = _run_engine(cfg, params, (48,) * n, gen=2,
                                max_inject_tokens=4096)
        assert all(len(t) == 2 for t in toks)
        # all n admissions happened in one iteration -> ONE embed launch
        assert eng.admit_embed_launches == 1
        assert fns.calls - c0 == 1
        traced[n] = fns.trace_count - t0
    # 3 and 4 rows bucket to the same (batch, token) shape: the second
    # admission batch size is a pure compile-cache hit
    assert traced[4] == 0
    pa.assert_cache_hit_invariant(fns)


def test_admission_embed_fallback_for_frontend_inputs(smoke_setup):
    """Whisper requests carry frames: they fall back to the per-request
    embed (encoder KV) and never count a batched admission launch."""
    cfg, params = smoke_setup("whisper-small")
    eng, toks = _run_engine(cfg, params, (48, 48), gen=2)
    assert eng.admit_embed_launches == 0
    assert all(len(t) == 2 for t in toks)


def test_chunked_rec_state_carries_exactly(smoke_setup):
    """Chunked segments over a hybrid arch: the mamba recurrent state (and
    its conv window) carried across same-layer chunks yields the SAME
    decode state as whole-layer execution — pinned by greedy outputs under
    longer generation."""
    cfg, params = smoke_setup("jamba-v0.1-52b")
    _, toks_whole = _run_engine(cfg, params, (72,), gen=6)
    e_c, toks_chunk = _run_engine(cfg, params, (72,), gen=6,
                                  prefill_max_tokens_per_step=16)
    assert toks_whole == toks_chunk
    assert sum(p.chunk_launches for p in e_c.prefill_planes.values()) > 0
