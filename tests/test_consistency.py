"""Cross-path numerical consistency tests: MLA absorbed decode, whisper
cross-attention cache, VLM patch prefix, hybrid recurrent state carry."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


def _teacher_force_check(smoke, arch, S=64, atol=5e-3, capacity_factor=None,
                         **extra_shapes):
    """prefill(t0..tn-1)+decode(tn) must equal prefill(t0..tn) — exercises
    the absorbed/incremental decode path against the full-sequence path."""
    import dataclasses
    cfg, params = smoke(arch)
    if capacity_factor is not None:
        # capacity_factor is runtime-only: cached params stay valid
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    toks = np.random.default_rng(1).integers(4, cfg.vocab_size,
                                             S + 1).astype(np.int32)
    extra = {}
    if cfg.is_encoder_decoder:
        extra["frames"] = jnp.ones((1, 16, cfg.d_model), jnp.float32) * .01
    if cfg.frontend == "vit_patch_stub":
        extra["patch_embeds"] = jnp.ones(
            (1, cfg.num_patches, cfg.d_model), jnp.float32) * .01
    pe = cfg.num_patches if cfg.frontend == "vit_patch_stub" else 0
    nb = (S + 1 + pe) // cfg.dsa.block_size + 2
    lg_full, _ = M.prefill(params, cfg,
                           {"tokens": jnp.asarray(toks[None, :]), **extra},
                           nb, cache_dtype=jnp.float32)
    lg_part, state = M.prefill(params, cfg,
                               {"tokens": jnp.asarray(toks[None, :-1]),
                                **extra},
                               nb, cache_dtype=jnp.float32)
    lg_dec, _ = M.decode_step(params, cfg, jnp.asarray([toks[-1]]), state)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=atol, atol=atol)


def test_mla_absorbed_decode_matches_prefill(smoke_setup):
    """MiniCPM3: the absorbed-latent decode path (W_UK folded into the
    query, latent-space DSA) must agree with the non-absorbed prefill."""
    _teacher_force_check(smoke_setup, "minicpm3-4b")


def test_whisper_decode_uses_cached_cross_kv(smoke_setup):
    _teacher_force_check(smoke_setup, "whisper-small")


def test_vlm_patch_prefix_positions(smoke_setup):
    _teacher_force_check(smoke_setup, "internvl2-2b")


def test_jamba_recurrent_state_carry(smoke_setup):
    _teacher_force_check(smoke_setup, "jamba-v0.1-52b")


def test_rwkv_state_carry(smoke_setup):
    _teacher_force_check(smoke_setup, "rwkv6-1.6b")


def test_moe_decode_matches_prefill(smoke_setup):
    """Capacity-bounded MoE DROPS overflow tokens during prefill but never
    during single-token decode (a real GShard-style prefill/decode
    inconsistency, amplified by random-weight routing).  With drop-free
    capacity the two paths must agree exactly."""
    _teacher_force_check(smoke_setup, "kimi-k2-1t-a32b", capacity_factor=16.0)


def test_moe_capacity_drops_cause_prefill_decode_gap(smoke_setup):
    """Documents the inconsistency: with tight capacity the paths DIVERGE
    (this is the phenomenon, not a bug — see docstring above)."""
    import dataclasses
    cfg, params = smoke_setup("kimi-k2-1t-a32b")
    cfg = dataclasses.replace(cfg, capacity_factor=0.5)
    toks = np.random.default_rng(1).integers(4, cfg.vocab_size, 65)
    nb = 4
    lg_full, _ = M.prefill(params, cfg,
                           {"tokens": jnp.asarray(toks[None, :])}, nb,
                           cache_dtype=jnp.float32)
    _, state = M.prefill(params, cfg,
                         {"tokens": jnp.asarray(toks[None, :-1])}, nb,
                         cache_dtype=jnp.float32)
    lg_dec, _ = M.decode_step(params, cfg, jnp.asarray([toks[-1]]), state)
    gap = float(jnp.abs(lg_dec - lg_full).max())
    assert gap > 1e-3     # drops visibly change the output


def test_mqa_granite(smoke_setup):
    _teacher_force_check(smoke_setup, "granite-20b")


def test_long_generation_stays_finite(tiny_cfg, tiny_params):
    """Decode steps crossing multiple block boundaries stay finite and
    cur_len advances exactly (60 + 40 tokens crosses the 64- and 96-token
    boundaries at block_size=32; shrunk from 64 steps to fit the tier-1 CPU
    budget)."""
    cfg, params = tiny_cfg, tiny_params
    steps = 40
    toks = np.random.default_rng(2).integers(4, cfg.vocab_size, 60)
    _, state = M.prefill(params, cfg, {"tokens": jnp.asarray(toks[None])},
                         num_blocks=6, cache_dtype=jnp.float32)
    tok = jnp.asarray([7], jnp.int32)
    for i in range(steps):
        lg, state = M.decode_step(params, cfg, tok, state)
        assert bool(jnp.all(jnp.isfinite(lg))), f"step {i}"
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    assert int(state["cur_len"][0]) == 60 + steps
