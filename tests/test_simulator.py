"""Discrete-event simulator tests: the paper's qualitative results must
reproduce on the cost model (Figs. 1, 10-13, 15)."""
import pytest

from repro.configs import get_config
from repro.serving import costmodel as cm
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace


@pytest.fixture(scope="module")
def lwm():
    return get_config("lwm-7b")


def run(lwm, system, rate=0.125, n=16, seed=0, **kw):
    sim = ServingSimulator(lwm, SYSTEMS[system], sim=SimConfig(**kw))
    trace = generate_trace(TraceConfig(request_rate=rate, num_requests=n,
                                       seed=seed))
    return sim, sim.run(trace)


def test_all_systems_complete(lwm):
    for name in SYSTEMS:
        _, m = run(lwm, name, n=8)
        assert m.num_finished == 8, name


def test_sparse_attention_faster_decode_than_vllm(lwm):
    """vLLM-S's TBT < vLLM's TBT (paper Fig. 12 at moderate rate)."""
    _, m_v = run(lwm, "vllm", rate=0.1)
    _, m_s = run(lwm, "vllm-s", rate=0.1)
    assert m_s.mean_tbt < m_v.mean_tbt


@pytest.mark.slow
def test_naive_offload_has_worst_tbt(lwm):
    """vLLM-SO pays fragmented-transfer cost every step (Fig. 12)."""
    _, m_so = run(lwm, "vllm-so", rate=0.1)
    for other in ("vllm", "vllm-s", "sparseserve"):
        _, m_o = run(lwm, other, rate=0.1)
        assert m_so.mean_tbt > m_o.mean_tbt, other


@pytest.mark.slow
def test_sparseserve_highest_throughput_at_high_rate(lwm):
    """Figs. 10-11: under load SparseServe beats every baseline."""
    results = {}
    for name in ("vllm", "vllm-s", "vllm-so", "sparseserve"):
        _, m = run(lwm, name, rate=0.5, n=24)
        results[name] = m
    best = max(results, key=lambda k: results[k].token_throughput)
    assert best == "sparseserve", {
        k: round(v.token_throughput, 1) for k, v in results.items()}
    assert results["sparseserve"].mean_ttft <= min(
        results[k].mean_ttft for k in ("vllm", "vllm-so"))


@pytest.mark.slow
def test_ws_control_reduces_block_loads(lwm):
    """Fig. 15: WS-aware batch control cuts block loads under pressure."""
    sim_no, _ = run(lwm, "vllm-so+ft", rate=0.5, n=24)
    sim_wc, _ = run(lwm, "vllm-so+ft+wc", rate=0.5, n=24)
    loads_no = sum(sim_no.loads_per_iter)
    loads_wc = sum(sim_wc.loads_per_iter)
    assert loads_wc < loads_no


def test_transfer_cost_model_matches_fig4_shape():
    """Fused transfers sustain >20 GB/s; memcpy collapses below 5-6 GB/s for
    16 KB blocks (paper Fig. 4)."""
    hw = cm.A100_40G
    blk = 16 * 1024
    bw_memcpy = cm.effective_bandwidth(hw, 256, blk, fused=False)
    bw_fused = cm.effective_bandwidth(hw, 256, blk, fused=True)
    assert bw_memcpy < 6e9
    assert bw_fused > 20e9


@pytest.mark.slow
def test_goodput_ladder_monotone(lwm):
    """Fig. 13: each SparseServe mechanism adds goodput (weak check: the
    full system >= plain offloading system on sustainable throughput)."""
    _, m_so = run(lwm, "vllm-so", rate=0.3, n=24)
    _, m_ss = run(lwm, "sparseserve", rate=0.3, n=24)
    assert m_ss.token_throughput >= m_so.token_throughput
    assert m_ss.mean_queue_delay <= max(m_so.mean_queue_delay, 2.0)
