"""DevicePoolPlane unit tests: slot lifecycle, bucketed jit retraces,
step_mask row parking, and the drop/restore block data plane."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_pool import (BucketingPolicy, DevicePoolPlane,
                                    gather_row_blocks)
from repro.models import model as M

import planeasserts as pa


def _prefill_state(cfg, params, S, nb, seed=0):
    """One request's list-mode DecodeState (the engine's representation)."""
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, S), 4,
                              cfg.vocab_size)
    _, st = M.prefill(params, cfg, {"tokens": toks}, nb,
                      cache_dtype=jnp.float32)
    if isinstance(st["caches"], dict):         # stacked scan caches -> list
        st["caches"] = [jax.tree.map(lambda x, i=i: x[i], st["caches"])
                        for i in range(cfg.num_layers)]
    return st


def _assert_states_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_bucketing_policy():
    p = BucketingPolicy(batch_buckets=(1, 2, 4, 8), block_bucket=8)
    assert [p.bucket_batch(n) for n in (1, 2, 3, 5, 8, 9, 17)] == \
        [1, 2, 4, 8, 8, 16, 32]
    assert [p.bucket_blocks(n) for n in (1, 8, 9, 16)] == [8, 8, 16, 16]


def test_admit_extract_roundtrip(smoke_setup):
    cfg, params = smoke_setup("qwen2-0.5b")
    plane = DevicePoolPlane(cfg)
    states = {f"r{i}": _prefill_state(cfg, params, S, nb, seed=i)
              for i, (S, nb) in enumerate(((40, 4), (64, 6)))}
    for rid, st in states.items():
        plane.admit(rid, st)
    for rid, st in states.items():
        _assert_states_equal(plane.extract(rid), st)


def test_slot_reuse_and_bucket_growth(smoke_setup):
    cfg, params = smoke_setup("qwen2-0.5b")
    plane = DevicePoolPlane(cfg, BucketingPolicy(batch_buckets=(1, 2, 4)))
    st = _prefill_state(cfg, params, 40, 4)
    plane.admit("a", st)
    plane.admit("b", _prefill_state(cfg, params, 33, 4, seed=1))
    assert plane.b_cap == 2
    freed = plane.release("a")
    plane.admit("c", _prefill_state(cfg, params, 48, 4, seed=2))
    assert plane.rows["c"] == freed            # freed slot reused in place
    assert plane.rows_reused == 1
    assert plane.b_cap == 2                    # no growth for the reuse
    plane.admit("d", _prefill_state(cfg, params, 40, 4, seed=3))
    assert plane.b_cap == 4                    # next batch bucket
    _assert_states_equal(plane.extract("c"),
                         _prefill_state(cfg, params, 48, 4, seed=2))


def test_drop_then_restore_from_host_copy(smoke_setup):
    """HBM eviction drops device block data; a fused-H2D restore puts the
    host copy back bit-for-bit (metadata stays resident throughout)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    plane = DevicePoolPlane(cfg)
    st = _prefill_state(cfg, params, 64, 4)
    plane.admit("a", st)
    layer = plane.pool_layers()[0]
    blocks = [0, 2]
    row = plane.rows["a"]
    c = plane.state["caches"][layer]
    host_k = np.asarray(gather_row_blocks(c["k"], row, blocks))
    host_v = np.asarray(gather_row_blocks(c["v"], row, blocks))
    plane.drop_blocks("a", layer, blocks)
    dropped = plane.extract("a")["caches"][layer]
    assert float(np.abs(np.asarray(dropped["k"][0, :, blocks])).sum()) == 0.0
    assert plane.blocks_dropped == 2
    plane.restore_blocks("a", layer, blocks, host_k, host_v)
    _assert_states_equal(plane.extract("a"), st)
    assert plane.blocks_restored == 2


def test_step_mask_parks_unscheduled_rows(smoke_setup):
    """Stepping a subset must leave parked rows byte-for-byte unchanged
    (pools, metadata, recurrent state, cur_len)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    plane = DevicePoolPlane(cfg)
    plane.admit("a", _prefill_state(cfg, params, 40, 4))
    plane.admit("b", _prefill_state(cfg, params, 33, 4, seed=1))
    before_b = plane.extract("b")
    logits, info, prev = plane.step(params, {"a": 7})
    assert prev == {"a": 40}
    _assert_states_equal(plane.extract("b"), before_b)
    assert int(plane.extract("a")["cur_len"][0]) == 41


def test_stepped_subset_matches_solo_decode(smoke_setup):
    """A row stepped inside a padded, partially-active batch produces the
    same logits and cache updates as decoding it alone."""
    cfg, params = smoke_setup("qwen2-0.5b")
    st_solo = _prefill_state(cfg, params, 40, 4)
    lg_solo, ns_solo = M.decode_step(params, cfg,
                                     jnp.asarray([7], jnp.int32), st_solo)
    plane = DevicePoolPlane(cfg)
    plane.admit("a", _prefill_state(cfg, params, 40, 4))
    plane.admit("b", _prefill_state(cfg, params, 33, 4, seed=1))
    logits, _, _ = plane.step(params, {"a": 7})
    row = plane.rows["a"]
    np.testing.assert_allclose(np.asarray(logits[row]),
                               np.asarray(lg_solo[0]), rtol=1e-5, atol=1e-5)
    # jit (plane) vs eager (solo) may differ in float low bits
    for x, y in zip(jax.tree.leaves(plane.extract("a")),
                    jax.tree.leaves(ns_solo)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)


def test_staged_step_matches_fused_step(smoke_setup):
    """The staged per-layer pipeline (select -> attend per layer, separate
    jits) computes the same logits and state updates as the fused one-launch
    ``step`` — the numeric backbone of the plane-equivalence guarantee."""
    cfg, params = smoke_setup("qwen2-0.5b")
    pf, ps = DevicePoolPlane(cfg), DevicePoolPlane(cfg)
    for plane in (pf, ps):
        plane.admit("a", _prefill_state(cfg, params, 40, 4))
        plane.admit("b", _prefill_state(cfg, params, 33, 4, seed=1))
    lg_f, info_f, prev_f = pf.step(params, {"a": 7, "b": 9})
    lg_s, info_s, prev_s = ps.step_staged(params, {"a": 7, "b": 9})
    assert prev_f == prev_s
    assert sorted(info_f["selected"]) == sorted(info_s["selected"])
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_s),
                               rtol=1e-5, atol=1e-5)
    for rid in ("a", "b"):
        for x, y in zip(jax.tree.leaves(pf.extract(rid)),
                        jax.tree.leaves(ps.extract(rid))):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, atol=1e-5)


def test_staged_restore_lands_between_select_and_attend(smoke_setup):
    """Dropped device blocks restored in the stage callback are read by the
    SAME iteration's attention: a step over a pool with blocks zeroed +
    in-window restores matches a step over the never-dropped pool."""
    cfg, params = smoke_setup("qwen2-0.5b")
    clean, dropped = DevicePoolPlane(cfg), DevicePoolPlane(cfg)
    for plane in (clean, dropped):
        plane.admit("a", _prefill_state(cfg, params, 64, 4))
    layers = dropped.pool_layers()
    blocks = [0, 1]           # full blocks (cur_len=64 appends to block 2)
    host = {}                 # the DRAM copies the restores come from
    row = dropped.rows["a"]
    for l in layers:
        c = dropped.state["caches"][l]
        host[l] = (np.asarray(gather_row_blocks(c["k"], row, blocks)),
                   np.asarray(gather_row_blocks(c["v"], row, blocks)))
        dropped.drop_blocks("a", l, blocks)

    def stage_cb(layer, sel, prev):
        k, v = host[layer]
        dropped.restore_blocks_fused(
            layer, {"a": (blocks, k, v)}, before_use=True)

    lg_clean, _, _ = clean.step_staged(params, {"a": 7})
    lg_drop, _, _ = dropped.step_staged(params, {"a": 7}, stage_cb)
    np.testing.assert_allclose(np.asarray(lg_drop), np.asarray(lg_clean),
                               rtol=1e-5, atol=1e-5)
    assert dropped.blocks_restored_before_use == len(layers) * len(blocks)
    _assert_states_equal(dropped.extract("a"), clean.extract("a"))


def test_staged_launches_o_num_layers_traces_bounded(smoke_setup):
    """Per-iteration launch count is exactly embed + 2 x attn layers +
    recurrent layers + logits; traces stay one per (stage, shape bucket)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, name=cfg.name + "-staged-retrace")
    plane = DevicePoolPlane(cfg, BucketingPolicy(batch_buckets=(1, 2, 4),
                                                 block_bucket=4))
    fns = plane.staged_fns
    assert fns.calls == 0 and fns.trace_count == 0
    per_iter = pa.staged_launches_per_iteration(cfg)
    plane.admit("a", _prefill_state(cfg, params, 40, 4))
    for tok in (5, 6, 7):
        plane.step_staged(params, {"a": tok})
    assert fns.calls == 3 * per_iter
    n_stage_kinds = pa.staged_stage_kinds(cfg)
    assert fns.trace_count == n_stage_kinds          # one bucket so far
    plane.admit("b", _prefill_state(cfg, params, 33, 4, seed=1))
    plane.step_staged(params, {"a": 5, "b": 6})
    plane.step_staged(params, {"b": 6})     # occupancy change: no retrace
    assert fns.trace_count == 2 * n_stage_kinds      # b_cap=2 bucket
    pa.assert_cache_hit_invariant(fns)
    assert fns.calls == 5 * per_iter                 # 5 steps total


def test_jit_retraces_bounded_by_buckets(smoke_setup):
    """The cache-hit invariant: one XLA trace per distinct shape bucket,
    never per iteration or per occupancy change."""
    cfg, params = smoke_setup("qwen2-0.5b")
    # the decode-fn cache is keyed structurally, so give this test its own
    # entry (fresh counters) via a distinct name
    cfg = dataclasses.replace(cfg, name=cfg.name + "-retrace")
    plane = DevicePoolPlane(cfg, BucketingPolicy(batch_buckets=(1, 2, 4),
                                                 block_bucket=4))
    fn = plane.decode_fn
    assert fn.trace_count == 0
    plane.admit("a", _prefill_state(cfg, params, 40, 4))
    for tok in (5, 6, 7):
        plane.step(params, {"a": tok})
    assert fn.trace_count == 1                     # b_cap=1 bucket
    plane.admit("b", _prefill_state(cfg, params, 33, 4, seed=1))
    plane.step(params, {"a": 5, "b": 6})
    plane.step(params, {"b": 6})                   # occupancy change: no trace
    assert fn.trace_count == 2                     # b_cap=2 bucket
    plane.release("a")
    plane.admit("c", _prefill_state(cfg, params, 48, 4, seed=2))
    plane.step(params, {"b": 5, "c": 6})           # same buckets: cache hit
    assert fn.trace_count == 2
    pa.assert_cache_hit_invariant(fn)
    n_buckets = len({1, 2}) * 1                    # batch buckets x nb buckets
    assert fn.trace_count <= n_buckets