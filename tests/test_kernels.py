"""Per-kernel allclose tests vs the pure-jnp oracles (ref.py), sweeping
shapes and dtypes — deliverable (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def key(i):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# gather_blocks (FlashH2D analogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb,bs,d", [(8, 32, 64), (64, 32, 128), (17, 16, 96),
                                     (128, 8, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_blocks(nb, bs, d, dtype):
    pool = jax.random.normal(key(0), (nb, bs, d), jnp.float32).astype(dtype)
    idx = jax.random.randint(key(1), (min(nb, 16),), 0, nb)
    out = ops.gather_blocks(pool, idx)
    want = ref.gather_blocks(pool, idx)
    assert out.shape == want.shape and out.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_gather_blocks_duplicate_and_boundary_indices():
    pool = jax.random.normal(key(2), (16, 32, 64), jnp.float32)
    idx = jnp.array([0, 0, 15, 15, 7], jnp.int32)
    np.testing.assert_array_equal(np.asarray(ops.gather_blocks(pool, idx)),
                                  np.asarray(ref.gather_blocks(pool, idx)))


# ---------------------------------------------------------------------------
# scatter_blocks (FlashD2H analogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb,bs,d,n_new", [(16, 32, 64, 4), (64, 16, 128, 8),
                                           (9, 8, 32, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scatter_blocks(nb, bs, d, n_new, dtype):
    pool = jax.random.normal(key(3), (nb, bs, d), jnp.float32).astype(dtype)
    new = jax.random.normal(key(4), (n_new * bs, d), jnp.float32).astype(dtype)
    dest = jax.random.choice(key(5), nb, (n_new,), replace=False)
    out = ops.scatter_blocks(pool, new, dest)
    want = ref.scatter_blocks(pool, new, dest)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_scatter_then_gather_roundtrip():
    pool = jnp.zeros((32, 16, 64))
    new = jax.random.normal(key(6), (4 * 16, 64), jnp.float32)
    dest = jnp.array([3, 9, 20, 31])
    pool2 = ops.scatter_blocks(pool, new, dest)
    got = ops.gather_blocks(pool2, dest)
    np.testing.assert_allclose(np.asarray(got).reshape(-1, 64),
                               np.asarray(new), rtol=1e-6)


# ---------------------------------------------------------------------------
# head-major (H, NB, bs, D) variants — persistent device plane row slots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,nb,bs,d,k", [(1, 8, 16, 32, 3), (2, 24, 8, 64, 6),
                                         (4, 17, 16, 32, 5)])
def test_gather_blocks_hkv(h, nb, bs, d, k):
    pool = jax.random.normal(key(20), (h, nb, bs, d), jnp.float32)
    idx = jax.random.randint(key(21), (k,), 0, nb)
    out = ops.gather_blocks_hkv(pool, idx)
    want = ref.gather_blocks_hkv(pool, idx)
    assert out.shape == (h, k, bs, d)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("h,nb,bs,d,k", [(1, 8, 16, 32, 3), (2, 24, 8, 64, 6)])
def test_scatter_blocks_hkv(h, nb, bs, d, k):
    pool = jax.random.normal(key(22), (h, nb, bs, d), jnp.float32)
    new = jax.random.normal(key(23), (h, k, bs, d), jnp.float32)
    dest = jax.random.choice(key(24), nb, (k,), replace=False)
    out = ops.scatter_blocks_hkv(pool, new, dest)
    want = ref.scatter_blocks_hkv(pool, new, dest)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_scatter_gather_hkv_roundtrip_preserves_other_blocks():
    pool = jax.random.normal(key(25), (2, 16, 8, 32), jnp.float32)
    new = jax.random.normal(key(26), (2, 3, 8, 32), jnp.float32)
    dest = jnp.array([1, 7, 15], jnp.int32)
    pool2 = ops.scatter_blocks_hkv(pool, new, dest)
    np.testing.assert_array_equal(
        np.asarray(ops.gather_blocks_hkv(pool2, dest)), np.asarray(new))
    untouched = [b for b in range(16) if b not in (1, 7, 15)]
    np.testing.assert_array_equal(np.asarray(pool2[:, untouched]),
                                  np.asarray(pool[:, untouched]))


# ---------------------------------------------------------------------------
# block_score (Quest cuboid upper bound)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,nb,d", [(1, 4, 1, 16, 64), (2, 8, 2, 40, 64),
                                           (3, 6, 3, 130, 128)])
def test_block_score(b, hq, hkv, nb, d):
    q = jax.random.normal(key(7), (b, hq, d))
    mn = jax.random.normal(key(8), (b, hkv, nb, d))
    mx = mn + jnp.abs(jax.random.normal(key(9), (b, hkv, nb, d)))
    out = ops.block_score(q, mn, mx)
    want = ref.block_score(q, mn, mx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# sparse_decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,nb,bs,d,k",
                         [(1, 4, 1, 8, 32, 64, 4), (2, 8, 2, 40, 32, 64, 8),
                          (2, 14, 2, 16, 16, 128, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_decode_attention(b, hq, hkv, nb, bs, d, k, dtype):
    q = jax.random.normal(key(10), (b, hq, d), jnp.float32).astype(dtype)
    kp = jax.random.normal(key(11), (b, hkv, nb, bs, d),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(key(12), (b, hkv, nb, bs, d),
                           jnp.float32).astype(dtype)
    bi = jnp.tile(jnp.arange(k, dtype=jnp.int32)[None, None], (b, hkv, 1))
    sv = jnp.ones((b, hkv, k), bool)
    cl = jnp.full((b,), nb * bs - 3, jnp.int32)   # last block partially valid
    out = ops.sparse_decode_attention(q, kp, vp, bi, sv, cl)
    want = ref.sparse_decode_attention(q, kp, vp, bi, sv, cl)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_sparse_decode_attention_invalid_selection_masked():
    """Invalid selections (sel_valid=False) must not affect the output."""
    b, hq, hkv, nb, bs, d, k = 1, 4, 1, 8, 16, 32, 4
    q = jax.random.normal(key(13), (b, hq, d))
    kp = jax.random.normal(key(14), (b, hkv, nb, bs, d))
    vp = jax.random.normal(key(15), (b, hkv, nb, bs, d))
    cl = jnp.full((b,), nb * bs, jnp.int32)
    bi = jnp.array([[[0, 1, 2, 3]]], jnp.int32)
    sv_all = jnp.array([[[True, True, True, False]]])
    out_masked = ref.sparse_decode_attention(q, kp, vp, bi, sv_all, cl)
    bi3 = jnp.array([[[0, 1, 2, 0]]], jnp.int32)   # 4th points elsewhere
    out_masked2 = ref.sparse_decode_attention(q, kp, vp, bi3, sv_all, cl)
    np.testing.assert_allclose(np.asarray(out_masked),
                               np.asarray(out_masked2), rtol=1e-6)


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,sk,hq,hkv,d",
                         [(1, 64, 64, 4, 1, 64), (2, 128, 128, 8, 2, 64),
                          (1, 96, 96, 2, 2, 128)])
def test_flash_prefill(b, sq, sk, hq, hkv, d):
    q = jax.random.normal(key(16), (b, sq, hq, d))
    k = jax.random.normal(key(17), (b, sk, hkv, d))
    v = jax.random.normal(key(18), (b, sk, hkv, d))
    out = ops.flash_prefill(q, k, v, q_tile=32, k_tile=32)
    want = ref.flash_prefill(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_prefill_q_offset():
    """Chunked continuation: q starts at absolute position q_offset."""
    b, s, hq, hkv, d = 1, 64, 4, 2, 32
    q = jax.random.normal(key(19), (b, 16, hq, d))
    k = jax.random.normal(key(20), (b, s, hkv, d))
    v = jax.random.normal(key(21), (b, s, hkv, d))
    out = ops.flash_prefill(q, k, v, q_offset=48, q_tile=16, k_tile=16)
    want = ref.flash_prefill(q, k, v, q_offset=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
