"""Batched multi-request decode: equivalence with the sequential loop,
padded-batch stack/unstack invariants, and fused FlashH2D call scaling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import model as M
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request


def _run_engine(cfg, params, batched, prompts, gen=5, seed=7, **kw):
    eng = ServingEngine(params, cfg, EngineConfig(
        chunk_size=64, r_max=4, batched_decode=batched, **kw))
    rng = np.random.default_rng(seed)
    order = []
    for p in prompts:
        toks = rng.integers(4, cfg.vocab_size, p).astype(np.int32)
        r = Request(prompt_len=p, max_new_tokens=gen)
        eng.submit(r, tokens=toks)
        order.append(r.req_id)
    eng.run()
    return eng, [eng.states[rid].out_tokens for rid in order]


@pytest.fixture(scope="module")
def mixed_runs(smoke_setup):
    """Batched + sequential runs over mixed prompt lengths (48/96/72)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    return (_run_engine(cfg, params, True, (48, 96, 72)),
            _run_engine(cfg, params, False, (48, 96, 72)))


@pytest.fixture(scope="module")
def miss_runs(smoke_setup):
    """Batched + sequential runs with a 1-block LRU: every decode step
    misses, exposing the FlashH2D launch-count difference."""
    cfg, params = smoke_setup("qwen2-0.5b")
    return (_run_engine(cfg, params, True, (64, 64, 64), gen=8,
                        hbm_blocks_per_request=1),
            _run_engine(cfg, params, False, (64, 64, 64), gen=8,
                        hbm_blocks_per_request=1))


def test_batched_equals_sequential_mixed_prompt_lengths(mixed_runs):
    """The tentpole guarantee: batched decode produces identical greedy
    tokens to the per-request loop across heterogeneous pool sizes."""
    (e_b, toks_b), (e_s, toks_s) = mixed_runs
    assert toks_b == toks_s
    assert all(len(t) == 5 for t in toks_b)
    # batching collapses per-request forwards into per-iteration forwards
    # (each request's FIRST token is sampled from prefill logits, so decode
    # produces gen-1 = 4 tokens per request)
    assert e_b.decode_step_calls < e_s.decode_step_calls
    assert e_b.decode_tokens == e_s.decode_tokens == 12
    assert e_s.decode_step_calls == 12               # legacy: one per token


def test_batched_decode_transfer_accounting_identical(miss_runs):
    """Blocks moved (bytes, misses) must not depend on the decode path;
    only the CALL count (fused launches) may shrink."""
    (e_b, _), (e_s, _) = miss_runs
    s_b, s_s = e_b.transfer_stats(), e_s.transfer_stats()
    assert s_b.h2d_blocks == s_s.h2d_blocks
    assert s_b.h2d_bytes == s_s.h2d_bytes
    assert s_b.misses == s_s.misses
    assert sum(e_b.loads_per_iter) == sum(e_s.loads_per_iter)


def test_fused_h2d_calls_per_layer_not_per_request(miss_runs):
    """Launch counts: at most layers-per-iteration (batched) vs
    layers-per-request-per-iteration (sequential)."""
    (e_b, _), (e_s, _) = miss_runs
    s_b, s_s = e_b.transfer_stats(), e_s.transfer_stats()
    assert s_b.h2d_calls < s_s.h2d_calls
    # batched: at most one fused launch per attention layer per iteration
    assert s_b.h2d_calls <= e_b.geom.num_layers * e_b.iterations
    # sequential: some iterations must have paid per-request launches
    assert s_s.h2d_calls > e_s.geom.num_layers * e_s.iterations


def test_batched_greedy_tokens_with_misses(miss_runs):
    (e_b, toks_b), (e_s, toks_s) = miss_runs
    assert toks_b == toks_s
    assert all(len(t) == 8 for t in toks_b)


def test_stack_unstack_roundtrip(smoke_setup):
    """stack -> unstack returns each request's state unchanged (padded
    blocks trimmed back)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    states = []
    for S, nb in ((40, 4), (64, 6)):
        toks = jnp.arange(5, 5 + S, dtype=jnp.int32)[None, :]
        _, st = M.prefill(params, cfg, {"tokens": toks}, nb,
                          cache_dtype=jnp.float32)
        # engine states are list-mode; prefill with stacked params returns
        # stacked caches -> expand to the per-layer list form
        if isinstance(st["caches"], dict):
            st["caches"] = [
                jax.tree.map(lambda x, i=i: x[i], st["caches"])
                for i in range(cfg.num_layers)]
        states.append(st)
    batched, layout = M.stack_decode_states(states)
    assert int(batched["cur_len"].shape[0]) == 2
    back = M.unstack_decode_states(batched, layout)
    for orig, rec in zip(states, back):
        for a, b in zip(jax.tree.leaves(orig), jax.tree.leaves(rec)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pad_slice_pool_cache_roundtrip():
    pool = {"k": jnp.ones((1, 2, 3, 4, 8)), "v": jnp.ones((1, 2, 3, 4, 8)),
            "meta": jnp.ones((1, 2, 3, 2, 8))}
    padded = attn.pad_pool_cache(pool, 7)
    assert padded["k"].shape == (1, 2, 7, 4, 8)
    assert padded["meta"].shape == (1, 2, 7, 2, 8)
    assert float(padded["k"][:, :, 3:].sum()) == 0.0
    back = attn.slice_pool_cache(padded, 3)
    for key in pool:
        np.testing.assert_array_equal(np.asarray(back[key]),
                                      np.asarray(pool[key]))
    with pytest.raises(ValueError):
        attn.pad_pool_cache(pool, 2)


def test_batched_decode_groups_by_encoder_length(smoke_setup):
    """Whisper requests with unequal encoder lengths cannot share one
    forward; the engine groups them and still matches sequential decode
    (regression: enc_kvs must batch along the BATCH axis, not layers)."""
    cfg, params = smoke_setup("whisper-small")

    def run(batched):
        eng = ServingEngine(params, cfg, EngineConfig(
            r_max=4, batched_decode=batched))
        for S_enc in (16, 16, 24):
            eng.submit(Request(prompt_len=48, max_new_tokens=3),
                       frames=np.ones((1, S_enc, cfg.d_model),
                                      np.float32) * .01)
        eng.run()
        return eng, [st.out_tokens for st in eng.states.values()]

    e_b, toks_b = run(True)
    e_s, toks_s = run(False)
    assert toks_b == toks_s
    # the two S_enc=16 requests share a forward; S_enc=24 gets its own
    assert e_b.decode_step_calls < e_s.decode_step_calls


def test_batched_decode_on_hybrid_arch(smoke_setup):
    """Recurrent (mamba) layer states batch alongside paged attn pools."""
    cfg, params = smoke_setup("jamba-v0.1-52b")
    e_b, toks_b = _run_engine(cfg, params, True, (48, 64), gen=4)
    e_s, toks_s = _run_engine(cfg, params, False, (48, 64), gen=4)
    assert toks_b == toks_s
    assert e_b.decode_step_calls < e_s.decode_step_calls


def test_moe_capacity_does_not_couple_batched_requests(smoke_setup):
    """Regression: MoE expert capacity scales with the number of tokens in
    the forward, so a batched decode step (T = B) could drop tokens that a
    per-request step (T = 1) never drops — decode runs drop-free so batched
    greedy outputs match sequential even under a tight capacity_factor."""
    import dataclasses
    cfg, params = smoke_setup("kimi-k2-1t-a32b")
    cfg = dataclasses.replace(cfg, capacity_factor=0.3)  # runtime-only knob
    rng = np.random.default_rng(3)
    states, toks_next = [], []
    for _ in range(8):
        S = int(rng.integers(33, 64))
        toks = rng.integers(4, cfg.vocab_size, S).astype(np.int32)
        _, st = M.prefill(params, cfg, {"tokens": jnp.asarray(toks[None])},
                          num_blocks=4, cache_dtype=jnp.float32)
        if isinstance(st["caches"], dict):          # scan caches -> list
            st["caches"] = [
                jax.tree.map(lambda x, i=i: x[i], st["caches"])
                for i in range(cfg.num_layers)]
        states.append(st)
        toks_next.append(int(rng.integers(4, cfg.vocab_size)))
    batched, _ = M.stack_decode_states(states)
    lg_b, _, _ = M.decode_step(params, cfg,
                               jnp.asarray(toks_next, jnp.int32), batched,
                               return_info=True)
    got_b = np.argmax(np.asarray(lg_b), axis=-1)
    got_s = np.asarray([int(np.argmax(np.asarray(M.decode_step(
        params, cfg, jnp.asarray([t], jnp.int32), st)[0])[0]))
        for st, t in zip(states, toks_next)])
    np.testing.assert_array_equal(got_b, got_s)
