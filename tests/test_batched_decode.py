"""Batched multi-request decode: equivalence with the sequential loop,
padded-batch stack/unstack invariants, fused FlashH2D call scaling, the
DevicePoolPlane hot paths (slot reuse, bounded jit retraces, zero
per-iteration stack/unstack, FlashD2H write-back coherence), and the staged
plane's eviction-pressure oracle-exactness (restores land before use)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import model as M
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Phase, Request


def _run_engine(cfg, params, batched, prompts, gen=5, seed=7, **kw):
    eng = ServingEngine(params, cfg, EngineConfig(
        chunk_size=64, r_max=4, batched_decode=batched, **kw))
    rng = np.random.default_rng(seed)
    order = []
    for p in prompts:
        toks = rng.integers(4, cfg.vocab_size, p).astype(np.int32)
        r = Request(prompt_len=p, max_new_tokens=gen)
        eng.submit(r, tokens=toks)
        order.append(r.req_id)
    eng.run()
    return eng, [eng.states[rid].out_tokens for rid in order]


@pytest.fixture(scope="module")
def mixed_runs(smoke_setup):
    """Batched + sequential runs over mixed prompt lengths (48/96/72)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    return (_run_engine(cfg, params, True, (48, 96, 72)),
            _run_engine(cfg, params, False, (48, 96, 72)))


@pytest.fixture(scope="module")
def miss_runs(smoke_setup):
    """Batched + sequential runs with a 1-block LRU: every decode step
    misses, exposing the FlashH2D launch-count difference."""
    cfg, params = smoke_setup("qwen2-0.5b")
    return (_run_engine(cfg, params, True, (64, 64, 64), gen=8,
                        hbm_blocks_per_request=1),
            _run_engine(cfg, params, False, (64, 64, 64), gen=8,
                        hbm_blocks_per_request=1))


def test_batched_equals_sequential_mixed_prompt_lengths(mixed_runs):
    """The tentpole guarantee: batched decode produces identical greedy
    tokens to the per-request loop across heterogeneous pool sizes."""
    (e_b, toks_b), (e_s, toks_s) = mixed_runs
    assert toks_b == toks_s
    assert all(len(t) == 5 for t in toks_b)
    # batching collapses per-request forwards into per-iteration forwards
    # (each request's FIRST token is sampled from prefill logits, so decode
    # produces gen-1 = 4 tokens per request)
    assert e_b.decode_step_calls < e_s.decode_step_calls
    assert e_b.decode_tokens == e_s.decode_tokens == 12
    assert e_s.decode_step_calls == 12               # legacy: one per token


def test_batched_decode_transfer_accounting_identical(miss_runs):
    """Blocks moved (bytes, misses) must not depend on the decode path;
    only the CALL count (fused launches) may shrink."""
    (e_b, _), (e_s, _) = miss_runs
    s_b, s_s = e_b.transfer_stats(), e_s.transfer_stats()
    assert s_b.h2d_blocks == s_s.h2d_blocks
    assert s_b.h2d_bytes == s_s.h2d_bytes
    assert s_b.misses == s_s.misses
    assert sum(e_b.loads_per_iter) == sum(e_s.loads_per_iter)


def test_fused_h2d_calls_per_layer_not_per_request(miss_runs):
    """Launch counts: at most layers-per-iteration (batched) vs
    layers-per-request-per-iteration (sequential)."""
    (e_b, _), (e_s, _) = miss_runs
    s_b, s_s = e_b.transfer_stats(), e_s.transfer_stats()
    assert s_b.h2d_calls < s_s.h2d_calls
    # batched: at most one fused launch per attention layer per iteration
    assert s_b.h2d_calls <= e_b.geom.num_layers * e_b.iterations
    # sequential: some iterations must have paid per-request launches
    assert s_s.h2d_calls > e_s.geom.num_layers * e_s.iterations


def test_batched_greedy_tokens_with_misses(miss_runs):
    (e_b, toks_b), (e_s, toks_s) = miss_runs
    assert toks_b == toks_s
    assert all(len(t) == 8 for t in toks_b)


def test_stack_unstack_roundtrip(smoke_setup):
    """stack -> unstack returns each request's state unchanged (padded
    blocks trimmed back)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    states = []
    for S, nb in ((40, 4), (64, 6)):
        toks = jnp.arange(5, 5 + S, dtype=jnp.int32)[None, :]
        _, st = M.prefill(params, cfg, {"tokens": toks}, nb,
                          cache_dtype=jnp.float32)
        # engine states are list-mode; prefill with stacked params returns
        # stacked caches -> expand to the per-layer list form
        if isinstance(st["caches"], dict):
            st["caches"] = [
                jax.tree.map(lambda x, i=i: x[i], st["caches"])
                for i in range(cfg.num_layers)]
        states.append(st)
    batched, layout = M.stack_decode_states(states)
    assert int(batched["cur_len"].shape[0]) == 2
    back = M.unstack_decode_states(batched, layout)
    for orig, rec in zip(states, back):
        for a, b in zip(jax.tree.leaves(orig), jax.tree.leaves(rec)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pad_slice_pool_cache_roundtrip():
    pool = {"k": jnp.ones((1, 2, 3, 4, 8)), "v": jnp.ones((1, 2, 3, 4, 8)),
            "meta": jnp.ones((1, 2, 3, 2, 8))}
    padded = attn.pad_pool_cache(pool, 7)
    assert padded["k"].shape == (1, 2, 7, 4, 8)
    assert padded["meta"].shape == (1, 2, 7, 2, 8)
    assert float(padded["k"][:, :, 3:].sum()) == 0.0
    back = attn.slice_pool_cache(padded, 3)
    for key in pool:
        np.testing.assert_array_equal(np.asarray(back[key]),
                                      np.asarray(pool[key]))
    with pytest.raises(ValueError):
        attn.pad_pool_cache(pool, 2)


def test_batched_decode_groups_by_encoder_length(smoke_setup):
    """Whisper requests with unequal encoder lengths cannot share one
    forward; the engine groups them and still matches sequential decode
    (regression: enc_kvs must batch along the BATCH axis, not layers)."""
    cfg, params = smoke_setup("whisper-small")

    def run(batched):
        eng = ServingEngine(params, cfg, EngineConfig(
            r_max=4, batched_decode=batched))
        for S_enc in (16, 16, 24):
            eng.submit(Request(prompt_len=48, max_new_tokens=3),
                       frames=np.ones((1, S_enc, cfg.d_model),
                                      np.float32) * .01)
        eng.run()
        return eng, [st.out_tokens for st in eng.states.values()]

    e_b, toks_b = run(True)
    e_s, toks_s = run(False)
    assert toks_b == toks_s
    # the two S_enc=16 requests share a forward; S_enc=24 gets its own
    assert e_b.decode_step_calls < e_s.decode_step_calls


def test_staged_matches_persistent_and_stacked_oracles(smoke_setup,
                                                       mixed_runs):
    """Acceptance: greedy outputs of the staged plane (the default) match
    both the fused persistent plane and the legacy stack/unstack path on
    the same workload."""
    cfg, params = smoke_setup("qwen2-0.5b")
    (e_p, toks_p), _ = mixed_runs                 # staged (default)
    assert e_p.eng.decode_plane == "staged"
    e_fu, toks_fu = _run_engine(cfg, params, True, (48, 96, 72),
                                decode_plane="persistent")
    e_st, toks_st = _run_engine(cfg, params, True, (48, 96, 72),
                                decode_plane="stacked")
    assert toks_p == toks_fu == toks_st
    # neither device plane ever stacks/unstacks; the legacy path does every
    # decode iteration
    assert e_p.stack_calls == e_fu.stack_calls == 0
    assert e_st.stack_calls > 0
    assert e_st.stack_calls == e_st.decode_step_calls


def test_staged_engine_retraces_bounded_by_buckets(mixed_runs):
    """jit retrace count == distinct shape signatures (every repeat shape
    is a compile-cache hit) for the per-stage jits, and the engine only
    ever steps at policy bucket shapes — so compiles stay bounded by
    (stage kinds x bucket grid), never the iteration count, even though
    per-iteration LAUNCHES are O(num_layers)."""
    (e_p, _), _ = mixed_runs
    assert e_p.eng.decode_plane == "staged"
    [plane] = e_p.planes.values()
    fns = plane.staged_fns
    # exact cache-hit invariant: one XLA trace per distinct (stage, shape)
    assert fns.trace_count == len(fns.shape_signatures)
    pol = e_p.eng.bucketing
    assert plane.buckets_seen                 # the plane actually stepped
    for b_cap, nb_cap in plane.buckets_seen:
        assert b_cap == pol.bucket_batch(b_cap)       # a policy batch bucket
        assert nb_cap % pol.block_bucket == 0         # a block-cap bucket
    # steady state: strictly fewer distinct buckets than iterations, i.e.
    # most iterations were compile-cache hits
    assert len(plane.buckets_seen) < plane.steps


def test_persistent_engine_retraces_bounded_by_buckets(smoke_setup):
    """Same invariant for the fused persistent plane's single decode jit."""
    cfg, params = smoke_setup("qwen2-0.5b")
    e_p, _ = _run_engine(cfg, params, True, (48, 96, 72),
                         decode_plane="persistent")
    [plane] = e_p.planes.values()
    fn = plane.decode_fn
    assert fn.trace_count == len(fn.shape_signatures)
    assert len(plane.buckets_seen) < plane.steps


def test_staged_launches_per_iteration_o_num_layers(smoke_setup):
    """The staged pipeline costs a BOUNDED number of jitted launches per
    iteration: embed + (select + attend) per attention layer + one per
    recurrent layer + logits — O(num_layers), independent of batch size
    and iteration count."""
    cfg, params = smoke_setup("qwen2-0.5b")
    from repro.core.device_pool import staged_fns_for
    fns = staged_fns_for(cfg, "ref")
    calls0 = fns.calls
    eng, _ = _run_engine(cfg, params, True, (48, 48), gen=6)
    n_attn = cfg.num_attention_layers()
    n_rec = cfg.num_layers - n_attn
    per_iter = 2 + 2 * n_attn + n_rec            # embed+logits+stages
    assert fns.calls - calls0 == per_iter * eng.decode_step_calls
    assert fns.trace_count == len(fns.shape_signatures)


def test_plane_slot_reuse_mid_batch(smoke_setup):
    """A request finishing mid-batch frees its device slots; a later
    arrival reuses them; greedy outputs still match the sequential
    oracle."""
    cfg, params = smoke_setup("qwen2-0.5b")

    def run(batched):
        eng = ServingEngine(params, cfg, EngineConfig(
            chunk_size=64, r_max=2, batched_decode=batched))
        rng = np.random.default_rng(11)
        reqs = [Request(prompt_len=48, max_new_tokens=3),       # finishes 1st
                Request(prompt_len=48, max_new_tokens=10),
                Request(prompt_len=48, max_new_tokens=4,        # arrives late
                        arrival_time=1e-6)]
        for r in reqs:
            eng.submit(r, tokens=rng.integers(4, cfg.vocab_size,
                                              r.prompt_len).astype(np.int32))
        eng.run()
        return eng, [eng.states[r.req_id].out_tokens for r in reqs]

    e_p, toks_p = run(True)
    e_s, toks_s = run(False)
    assert toks_p == toks_s
    [plane] = e_p.planes.values()
    assert plane.admits == 3
    assert plane.rows_reused >= 1        # late request reused a freed slot
    assert plane.b_cap <= 2              # reuse, not growth
    assert len(plane.rows) == 0          # all slots freed at the end


def test_decode_write_back_keeps_host_pool_coherent(smoke_setup):
    """FlashD2H write-back: after decode iterations, the host pool holds
    the decode-appended KV byte-for-byte equal to the device plane slots —
    the invariant that makes fused H2D restores safe to scatter straight
    into device memory."""
    cfg, params = smoke_setup("qwen2-0.5b")
    eng = ServingEngine(params, cfg, EngineConfig(chunk_size=64, r_max=2))
    r = Request(prompt_len=48, max_new_tokens=8)
    eng.submit(r, tokens=np.arange(5, 53, dtype=np.int32))
    for _ in range(30):
        if r.generated >= 5:
            break
        eng.step()
    assert r.generated >= 5 and r.phase != Phase.FINISHED
    [plane] = eng.planes.values()
    st = plane.extract(r.req_id)
    host = eng.kv_mgr.pools[r.req_id]
    bs = cfg.dsa.block_size
    n_dec = int(st["cur_len"][0]) - r.prompt_len
    assert n_dec >= 1
    for l in plane.pool_layers():
        lidx = eng._attn_layer_index(l)
        for pos in range(r.prompt_len, r.prompt_len + n_dec):
            blk, slot = pos // bs, pos % bs
            np.testing.assert_array_equal(
                host.k[lidx, :, blk, slot],
                np.asarray(st["caches"][l]["k"][0, :, blk, slot]))
            np.testing.assert_array_equal(
                host.v[lidx, :, blk, slot],
                np.asarray(st["caches"][l]["v"][0, :, blk, slot]))


def test_drop_evicted_device_blocks_runs_and_drops(smoke_setup):
    """With the true-drop knob on, HBM evictions physically zero device
    blocks and re-selections restore them; generation completes."""
    cfg, params = smoke_setup("qwen2-0.5b")
    eng, toks = _run_engine(cfg, params, True, (64, 64), gen=6,
                            hbm_blocks_per_request=1,
                            drop_evicted_device_blocks=True)
    assert all(len(t) == 6 for t in toks)
    planes = list(eng.planes.values())
    assert sum(p.blocks_dropped for p in planes) > 0
    assert sum(p.blocks_restored for p in planes) > 0


# ---------------------------------------------------------------------------
# Eviction-pressure equivalence (the staged plane's tentpole guarantee)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def evict_runs(smoke_setup):
    """Runs under an HBM budget (1-block LRU) that forces evictions every
    decode iteration: staged (default, physical drops auto-ON) + the three
    oracles + the fused plane with physical drops."""
    cfg, params = smoke_setup("qwen2-0.5b")
    kw = dict(gen=8, hbm_blocks_per_request=1)
    return {
        "staged": _run_engine(cfg, params, True, (64, 64, 64), **kw),
        "persistent": _run_engine(cfg, params, True, (64, 64, 64),
                                  decode_plane="persistent", **kw),
        "stacked": _run_engine(cfg, params, True, (64, 64, 64),
                               decode_plane="stacked", **kw),
        "sequential": _run_engine(cfg, params, False, (64, 64, 64), **kw),
        "fused_drop": _run_engine(cfg, params, True, (64, 64, 64),
                                  decode_plane="persistent",
                                  drop_evicted_device_blocks=True, **kw),
    }


def test_staged_oracle_exact_under_eviction_pressure(evict_runs):
    """Acceptance: the staged plane — with drop_evicted_device_blocks
    resolved ON by default, physically zeroing device blocks every
    iteration — produces greedy tokens identical to all three oracles,
    because per-layer restores land BEFORE the attention that selected
    them."""
    e, toks = evict_runs["staged"]
    assert e.eng.drop_evicted_device_blocks        # auto-resolved ON
    for oracle in ("persistent", "stacked", "sequential"):
        assert toks == evict_runs[oracle][1], oracle
    # the pressure was real: >= 1 LRU eviction per decode iteration, and
    # the drops/restores actually touched device memory
    s = e.transfer_stats()
    assert s.evictions >= e.decode_step_calls
    [plane] = e.planes.values()
    assert plane.blocks_dropped > 0
    assert plane.blocks_restored > 0
    # every restore landed in the select->attend window (before use)
    assert plane.blocks_restored_before_use == plane.blocks_restored


def test_fused_plane_drop_is_not_oracle_exact(evict_runs):
    """The same workload on the FUSED plane with physical drops diverges:
    select and attend run in one launch, so a re-selected evicted block can
    only be restored after the forward already read zeros.  This is the
    failure mode the staged pipeline exists to fix."""
    _, toks_oracle = evict_runs["stacked"]
    e_fd, toks_fd = evict_runs["fused_drop"]
    [plane] = e_fd.planes.values()
    assert plane.blocks_dropped > 0                # drops really happened
    assert plane.blocks_restored_before_use == 0   # ...and never in-window
    assert toks_fd != toks_oracle


def test_staged_transfer_accounting_matches_stacked(evict_runs):
    """Blocks moved (bytes, misses, evictions) must not depend on the
    decode plane; the staged pipeline keeps the one-fused-launch-per-layer
    call shape."""
    (e_s, _), (e_st, _) = evict_runs["staged"], evict_runs["stacked"]
    s_s, s_st = e_s.transfer_stats(), e_st.transfer_stats()
    assert s_s.h2d_blocks == s_st.h2d_blocks
    assert s_s.h2d_bytes == s_st.h2d_bytes
    assert s_s.misses == s_st.misses
    assert s_s.evictions == s_st.evictions
    # at most one fused FlashH2D launch per attention layer per iteration
    assert s_s.h2d_calls <= e_s.geom.num_layers * e_s.iterations


def test_staged_restore_ordering_no_stale_attended_blocks(smoke_setup):
    """Satellite assertion: in the restore->attend window of EVERY layer of
    EVERY iteration, each block the attention is about to read is
    byte-identical to its host copy — in particular, no attended block is
    zero on device while its host copy is nonzero (the fused plane's
    failure mode under drops)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    eng = ServingEngine(params, cfg, EngineConfig(
        chunk_size=64, r_max=4, hbm_blocks_per_request=1))
    assert eng.eng.drop_evicted_device_blocks
    checked = [0]

    def probe(engine, plane, layer, sts, blocks_by_req):
        lidx = engine._attn_layer_index(layer)
        c = plane.state["caches"][layer]
        for st in sts:
            rid = st.req.req_id
            row = plane.rows[rid]
            host = engine.kv_mgr.pools[rid]
            for b in blocks_by_req[rid]:
                dev_k = np.asarray(c["k"][row, :, b])
                np.testing.assert_array_equal(dev_k, host.k[lidx, :, b])
                if np.any(host.k[lidx, :, b]):
                    assert np.any(dev_k), (layer, b)
                np.testing.assert_array_equal(np.asarray(c["v"][row, :, b]),
                                              host.v[lidx, :, b])
                checked[0] += 1

    eng.staged_probe = probe
    rng = np.random.default_rng(7)
    for p in (64, 64):
        eng.submit(Request(prompt_len=p, max_new_tokens=6),
                   tokens=rng.integers(4, cfg.vocab_size, p).astype(np.int32))
    eng.run()
    assert checked[0] > 0
    [plane] = eng.planes.values()
    assert plane.blocks_dropped > 0       # the window was actually exercised


def test_batched_decode_on_hybrid_arch(smoke_setup):
    """Recurrent (mamba) layer states batch alongside paged attn pools."""
    cfg, params = smoke_setup("jamba-v0.1-52b")
    e_b, toks_b = _run_engine(cfg, params, True, (48, 64), gen=4)
    e_s, toks_s = _run_engine(cfg, params, False, (48, 64), gen=4)
    assert toks_b == toks_s
    assert e_b.decode_step_calls < e_s.decode_step_calls
    # Algorithm 1 working-set estimates count only layers with paged KV:
    # jamba-smoke has 2 model layers but 1 attention layer
    assert e_b.scheduler.num_attn_layers == cfg.num_attention_layers()
    assert e_b.scheduler.num_attn_layers < cfg.num_layers


def test_moe_capacity_does_not_couple_batched_requests(smoke_setup):
    """Regression: MoE expert capacity scales with the number of tokens in
    the forward, so a batched decode step (T = B) could drop tokens that a
    per-request step (T = 1) never drops — decode runs drop-free so batched
    greedy outputs match sequential even under a tight capacity_factor."""
    import dataclasses
    cfg, params = smoke_setup("kimi-k2-1t-a32b")
    cfg = dataclasses.replace(cfg, capacity_factor=0.3)  # runtime-only knob
    rng = np.random.default_rng(3)
    states, toks_next = [], []
    for _ in range(8):
        S = int(rng.integers(33, 64))
        toks = rng.integers(4, cfg.vocab_size, S).astype(np.int32)
        _, st = M.prefill(params, cfg, {"tokens": jnp.asarray(toks[None])},
                          num_blocks=4, cache_dtype=jnp.float32)
        if isinstance(st["caches"], dict):          # scan caches -> list
            st["caches"] = [
                jax.tree.map(lambda x, i=i: x[i], st["caches"])
                for i in range(cfg.num_layers)]
        states.append(st)
        toks_next.append(int(rng.integers(4, cfg.vocab_size)))
    batched, _ = M.stack_decode_states(states)
    lg_b, _, _ = M.decode_step(params, cfg,
                               jnp.asarray(toks_next, jnp.int32), batched,
                               return_info=True)
    got_b = np.argmax(np.asarray(lg_b), axis=-1)
    got_s = np.asarray([int(np.argmax(np.asarray(M.decode_step(
        params, cfg, jnp.asarray([t], jnp.int32), st)[0])[0]))
        for st, t in zip(states, toks_next)])
    np.testing.assert_array_equal(got_b, got_s)
