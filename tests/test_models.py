"""Per-architecture smoke tests (deliverable f) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.models import model as M
from conftest import make_batch


# ---------------------------------------------------------------------------
# Assigned full configs carry the exact published dimensions
# ---------------------------------------------------------------------------

EXPECT = {
    "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                            num_kv_heads=8, d_ff=2048, vocab_size=163840,
                            num_experts=384, top_k_experts=8),
    "minicpm3-4b": dict(num_layers=62, d_model=2560, num_heads=40,
                        d_ff=6400, vocab_size=73448),
    "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=65536,
                           num_experts=16, top_k_experts=2),
    "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                        num_kv_heads=8, d_ff=4864, vocab_size=32000,
                        num_experts=128, top_k_experts=2),
    "whisper-small": dict(num_layers=12, d_model=768, num_heads=12,
                          num_kv_heads=12, d_ff=3072, vocab_size=51865),
    "internvl2-2b": dict(num_layers=24, d_model=2048, num_heads=16,
                         num_kv_heads=8, d_ff=8192, vocab_size=92553),
    "rwkv6-1.6b": dict(num_layers=24, d_model=2048, d_ff=7168,
                       vocab_size=65536),
    "granite-20b": dict(num_layers=52, d_model=6144, num_heads=48,
                        num_kv_heads=1, d_ff=24576, vocab_size=49152),
    "qwen2.5-3b": dict(num_layers=36, d_model=2048, num_heads=16,
                       num_kv_heads=2, d_ff=11008, vocab_size=151936),
    "qwen2-0.5b": dict(num_layers=24, d_model=896, num_heads=14,
                       num_kv_heads=2, d_ff=4864, vocab_size=151936),
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_assigned_config_dimensions(arch):
    cfg = get_config(arch)
    for k, v in EXPECT[arch].items():
        assert getattr(cfg, k) == v, f"{arch}.{k}"
    assert cfg.source, f"{arch} must cite its source"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_config_is_reduced(arch):
    s = get_smoke_config(arch)
    assert s.num_layers <= 2 and s.d_model <= 512
    if s.num_experts:
        assert s.num_experts <= 4
    f = get_config(arch)
    assert s.arch_type == f.arch_type and s.attention_type == f.attention_type


# ---------------------------------------------------------------------------
# Smoke: one forward/train step per arch — shapes + no NaNs (deliverable f)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_train_step(arch, smoke_setup):
    cfg, params = smoke_setup(arch)
    B, S = 2, 32      # grad+opt step per arch: small shapes keep tier-1 fast
    batch = make_batch(cfg, B, S)
    loss, logits = M.forward_train(params, cfg, batch, remat=False)
    text = S  # labels length
    assert logits.shape == (B, text, cfg.vocab_size)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one actual optimizer step
    from repro.training.optimizer import AdamWConfig, adamw_update, \
        init_opt_state
    opt = init_opt_state(params)
    grads = jax.grad(
        lambda p: M.forward_train(p, cfg, batch, remat=False)[0])(params)
    p2, o2, m = adamw_update(AdamWConfig(lr=1e-3), params, grads, opt)
    assert bool(jnp.isfinite(m["grad_norm"]))
    # params actually changed
    moved = any(not np.allclose(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_prefill_decode(arch, smoke_setup):
    cfg, params = smoke_setup(arch)
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    extra = cfg.num_patches if cfg.frontend == "vit_patch_stub" else 0
    nb = (S + extra) // cfg.dsa.block_size + 2
    logits, state = M.prefill(params, cfg, batch, nb, cache_dtype=jnp.float32)
    assert logits.shape == (B, cfg.vocab_size)
    for _ in range(3):
        logits, state = M.decode_step(params, cfg,
                                      jnp.array([5, 9], jnp.int32), state)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(state["cur_len"][0]) == S + extra + 3


# ---------------------------------------------------------------------------
# Decode == teacher-forced forward (consistency across the two paths)
# ---------------------------------------------------------------------------

def test_decode_matches_teacher_forcing(tiny_cfg, tiny_params):
    """Prefill(t0..tn) then decode(t_{n+1}) must equal prefill(t0..t_{n+1})
    when DSA covers every block (budget >= context)."""
    cfg, params = tiny_cfg, tiny_params
    toks = np.arange(5, 5 + 65, dtype=np.int32)
    full = {"tokens": jnp.asarray(toks[None, :])}
    part = {"tokens": jnp.asarray(toks[None, :-1])}
    nb = 4
    lg_full, _ = M.prefill(params, cfg, full, nb, cache_dtype=jnp.float32)
    lg_part, state = M.prefill(params, cfg, part, nb,
                               cache_dtype=jnp.float32)
    lg_dec, _ = M.decode_step(params, cfg, jnp.asarray([toks[-1]]), state)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=2e-3, atol=2e-3)


def test_stacked_and_list_params_identical(tiny_cfg, tiny_params,
                                           tiny_params_list):
    cfg = tiny_cfg
    batch = make_batch(cfg, 2, 64)
    l1, _ = M.forward_train(tiny_params, cfg, batch, remat=False)
    l2, _ = M.forward_train(tiny_params_list, cfg, batch, remat=False)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_dsa_off_equals_dsa_on_with_full_budget(tiny_cfg):
    """DSA with budget >= context must equal full (non-sparse) attention."""
    cfg_on = tiny_cfg
    cfg_off = dataclasses.replace(
        tiny_cfg, dsa=dataclasses.replace(tiny_cfg.dsa, enabled=False))
    params = M.init_params(cfg_on, jax.random.PRNGKey(0), jnp.float32)
    toks = np.arange(5, 101, dtype=np.int32)
    inp = {"tokens": jnp.asarray(toks[None, :])}
    _, st_on = M.prefill(params, cfg_on, inp, 5, cache_dtype=jnp.float32)
    _, st_off = M.prefill(params, cfg_off, inp, 5, cache_dtype=jnp.float32)
    lg_on, _ = M.decode_step(params, cfg_on, jnp.asarray([7]), st_on)
    lg_off, _ = M.decode_step(params, cfg_off, jnp.asarray([7]), st_off)
    np.testing.assert_allclose(np.asarray(lg_on), np.asarray(lg_off),
                               rtol=1e-4, atol=1e-4)


def test_kernel_attn_impl_matches_ref(tiny_cfg, tiny_params):
    cfg, params = tiny_cfg, tiny_params
    toks = np.arange(5, 101, dtype=np.int32)
    inp = {"tokens": jnp.asarray(toks[None, :])}
    _, s1 = M.prefill(params, cfg, inp, 5, cache_dtype=jnp.float32)
    _, s2 = M.prefill(params, cfg, inp, 5, cache_dtype=jnp.float32)
    lg1, _ = M.decode_step(params, cfg, jnp.asarray([7]), s1,
                           attn_impl="ref")
    lg2, _ = M.decode_step(params, cfg, jnp.asarray([7]), s2,
                           attn_impl="kernel")
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-4, atol=1e-4)


def test_param_count_matches_actual(tiny_cfg, tiny_params):
    from repro.models.common import num_params
    analytic = tiny_cfg.param_count()
    actual = num_params(tiny_params)
    # analytic formula ignores tiny norm/decay vectors — within 5 %
    assert abs(analytic - actual) / actual < 0.05
