"""Roofline extraction + sharding-rule unit tests (no devices needed:
AbstractMesh supplies axis names/sizes)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from conftest import make_abstract_mesh
from repro.roofline.analysis import (_shape_bytes, collective_bytes_from_hlo, model_flops)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule jit_step, entry_computation_layout={...}

ENTRY %main (p0: bf16[8,64]) -> bf16[8,1024] {
  %p0 = bf16[8,64]{1,0} parameter(0)
  %c = f32[4,4]{1,0} constant({...})
  %ag = bf16[8,1024]{1,0} all-gather(%p0), channel_id=1, dimensions={1}
  %conv = f32[8,1024]{1,0} convert(%ag)
  %ar = f32[8,1024]{1,0} all-reduce(%conv), channel_id=2, to_apply=%add
  %a2a = f32[8,1024]{1,0} all-to-all(%ar), channel_id=3, dimensions={0}
  %cp = f32[8,1024]{1,0} collective-permute(%a2a), channel_id=4
  %start = (f32[8,1024], f32[8,1024]) all-reduce-start(%cp), channel_id=5, to_apply=%add
  %done = f32[8,1024]{1,0} all-reduce-done(%start)
  ROOT %out = bf16[8,1024]{1,0} convert(%done)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16", "8,64") == 8 * 64 * 2
    assert _shape_bytes("f32", "") == 4            # scalar
    assert _shape_bytes("pred", "16") == 16


def test_collective_parse_counts_and_bytes():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    c = out["counts"]
    assert c["all-gather"] == 1
    assert c["all-reduce"] == 2        # plain + -start (done skipped)
    assert c["all-to-all"] == 1
    assert c["collective-permute"] == 1
    b = out["per_kind_bytes"]
    assert b["all-gather"] == 8 * 64 * 2             # operand %p0
    f32row = 8 * 1024 * 4
    assert b["all-reduce"] == 2 * f32row             # %conv + %cp
    assert out["bytes_per_device"] == sum(b.values())


def test_collective_parse_empty():
    out = collective_bytes_from_hlo("ENTRY %m { ROOT %x = f32[] constant(0) }")
    assert out["bytes_per_device"] == 0


# ---------------------------------------------------------------------------
# model_flops (6ND / 2ND accounting)
# ---------------------------------------------------------------------------

def test_model_flops_moe_uses_active_params():
    from repro.configs import get_config
    kimi = get_config("kimi-k2-1t-a32b")
    total, active = kimi.param_count(), kimi.active_param_count()
    assert active < total / 5                      # 32B active of 1T
    f = model_flops(kimi, "decode", 32768, 128)
    assert f == 2.0 * active * 128


def test_model_flops_train_is_6nd(tiny_cfg):
    n = tiny_cfg.active_param_count()
    assert model_flops(tiny_cfg, "train", 128, 4) == 6.0 * n * 512


# ---------------------------------------------------------------------------
# Sharding rules (AbstractMesh — no real devices)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh():
    return make_abstract_mesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def pod_mesh():
    return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def specs_of(tree):
    return jax.tree.map(lambda s: s.spec, tree,
                        is_leaf=lambda x: hasattr(x, "spec"))


def test_param_sharding_rules(mesh):
    from repro.launch.sharding import param_shardings
    from repro.launch.steps import abstract_params
    from repro.configs import get_config
    cfg = get_config("qwen2.5-3b")      # heads=16 divisible by 16
    shapes = abstract_params(cfg)
    sh = param_shardings(shapes, mesh)
    specs = specs_of(sh)
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    layers = specs["layers"]            # stacked: leading None
    assert layers["attn"]["wq"] == P(None, None, "model")
    assert layers["attn"]["wo"] == P(None, "model", None)
    assert layers["ffn"]["w_gate"] == P(None, None, "model")
    assert layers["ffn"]["w_down"] == P(None, "model", None)


def test_param_sharding_moe_expert_parallel(mesh):
    from repro.launch.sharding import param_shardings
    from repro.launch.steps import abstract_params
    from repro.configs import get_config
    cfg = get_config("kimi-k2-1t-a32b")   # 384 experts
    sh = param_shardings(abstract_params(cfg), mesh)
    specs = specs_of(sh)
    moe = specs["layers"]["moe"]
    assert moe["w_gate"] == P(None, "model", None, None)   # (L, E, d, f)
    assert moe["router"] == P(None, None, None)            # replicated


def test_param_sharding_nondivisible_replicates(mesh):
    """Dims not divisible by the 16-way model axis must replicate rather
    than produce an invalid sharding."""
    from repro.launch.sharding import param_shardings
    shapes = {"layers": [{"attn": {
        "wq": jax.ShapeDtypeStruct((100, 37), jnp.float32),   # 37 % 16 != 0
        "wo": jax.ShapeDtypeStruct((37, 100), jnp.float32),
    }}]}
    specs = specs_of(param_shardings(shapes, mesh))
    assert specs["layers"][0]["attn"]["wq"] == P(None, None)
    assert specs["layers"][0]["attn"]["wo"] == P(None, None)
    # divisible dims do shard
    shapes2 = {"layers": [{"attn": {
        "wq": jax.ShapeDtypeStruct((100, 64), jnp.float32)}}]}
    specs2 = specs_of(param_shardings(shapes2, mesh))
    assert specs2["layers"][0]["attn"]["wq"] == P(None, "model")


def test_state_sharding_pools(mesh):
    from repro.launch.sharding import state_shardings
    from repro.launch.steps import abstract_decode_state
    from repro.configs import get_config
    cfg = get_config("qwen2.5-3b")
    st = abstract_decode_state(cfg, 128, 32768)
    sh = state_shardings(st, mesh)
    specs = specs_of(sh)
    # stacked pools (L, B, Hkv, NB, bs, D): batch on data, blocks on model
    assert specs["caches"]["k"] == P(None, "data", None, "model", None, None)
    assert specs["cur_len"] == P("data")


def test_batch_sharding_multipod(pod_mesh):
    from repro.launch.sharding import batch_shardings
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    sh = batch_shardings(batch, pod_mesh)
    assert sh["tokens"].spec == P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard -> replicated
    sh1 = batch_shardings({"tokens": jax.ShapeDtypeStruct((1,), jnp.int32)},
                          pod_mesh)
    assert sh1["tokens"].spec == P(None)


def test_input_specs_cover_all_shapes():
    from repro.launch.steps import SHAPES, step_and_specs
    from repro.configs import ASSIGNED_ARCHS, get_config
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            fn, args, kind = step_and_specs(cfg, shape)
            leaves = jax.tree.leaves(args)
            assert leaves, (arch, shape)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
