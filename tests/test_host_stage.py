"""Host-stage worker thread (core/host_stage.py): FIFO ordering and
per-key fences, write-back-vs-gather ordering under the fence discipline,
clean shutdown on engine release, and exception propagation from a
worker job back into the iteration that dispatched it."""

import threading
import time

import numpy as np
import pytest

from repro.core.host_stage import HostStageError, HostStageWorker
from repro.core.kv_cache import HostPool, KVGeometry
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request


def _worker():
    return HostStageWorker(name="test-host-stage")


# ---------------------------------------------------------------------------
# Ordering: FIFO execution, per-key fences, drain
# ---------------------------------------------------------------------------

def test_fifo_order_and_fence_per_key():
    """Jobs run in submission order; fence(key) waits for every job of
    that key but not for later-submitted keys."""
    w = _worker()
    ran = []
    release = threading.Event()

    def slow(tag):
        release.wait(timeout=5)
        ran.append(tag)

    def fast(tag):
        ran.append(tag)

    w.submit(0, slow, "l0-a")
    w.submit(0, fast, "l0-b")
    w.submit(1, fast, "l1-a")
    assert w.pending(0) and w.pending(1)
    release.set()
    w.fence(0)
    # FIFO: both key-0 jobs done, in order, before the fence returned
    assert ran[:2] == ["l0-a", "l0-b"]
    w.drain()
    assert ran == ["l0-a", "l0-b", "l1-a"]
    assert not w.pending(0) and not w.pending(1)
    w.close()


def test_writeback_lands_before_fenced_gather():
    """The restore-before-use discipline at the unit level: a DRAM gather
    fenced on the write-back's key always reads the flushed stripe, even
    when the worker job is slow — the exact 1-block-LRU rollover case the
    engine fences for (gather of the block the token just appended to)."""
    geom = KVGeometry(num_layers=1, num_kv_heads=2, block_size=4,
                      head_dim=8, kv_factor=2)
    pool = HostPool(geom, num_blocks=4)
    w = _worker()
    stripe_k = np.full((2, 1, 8), 7.0, np.float32)
    stripe_v = np.full((2, 1, 8), 9.0, np.float32)

    def job():
        time.sleep(0.05)                      # let the gather race ahead
        pool.stage(0, 5, stripe_k, stripe_v)  # token 5 -> block 1, slot 1
        pool.flush()

    w.submit(0, job)
    w.fence(0)                                # engine: fence before gather
    k, v = pool.gather(0, [1])
    np.testing.assert_array_equal(k[:, 0, 1], stripe_k[:, 0])
    np.testing.assert_array_equal(v[:, 0, 1], stripe_v[:, 0])
    w.close()


def test_lru_bookkeeping_stays_ordered_with_inflight_writeback():
    """LRU access/drop ordering is main-thread-only by design: a slow
    in-flight write-back job must not block or reorder host bookkeeping
    for OTHER layers, and drain() makes everything visible before a
    release could drop the pool."""
    w = _worker()
    events = []
    gate = threading.Event()

    def writeback(layer):
        gate.wait(timeout=5)
        events.append(("flush", layer))

    w.submit(0, writeback, 0)
    # main-thread bookkeeping proceeds while layer 0's job is in flight
    events.append(("access", 1))
    events.append(("drop", 1))
    assert w.pending(0)
    gate.set()
    w.drain()                    # iteration fence: flush before release
    events.append(("release", 0))
    assert events == [("access", 1), ("drop", 1), ("flush", 0),
                      ("release", 0)]
    w.close()


# ---------------------------------------------------------------------------
# Shutdown
# ---------------------------------------------------------------------------

def test_close_is_idempotent_and_drains():
    w = _worker()
    ran = []
    w.submit("x", ran.append, 1)
    w.close()
    assert ran == [1]            # close drained the queue first
    w.close()                    # idempotent
    with pytest.raises(HostStageError):
        w.submit("x", ran.append, 2)


def test_engine_run_closes_worker_and_step_recreates(smoke_setup):
    """run() joins the worker on exit (clean shutdown on engine release);
    a later step() lazily re-creates it."""
    cfg, params = smoke_setup("qwen2-0.5b")
    eng = ServingEngine(params, cfg, EngineConfig(chunk_size=64, r_max=4))
    assert eng.eng.stage_dispatch == "async"
    rng = np.random.default_rng(0)
    eng.submit(Request(prompt_len=48, max_new_tokens=3),
               tokens=rng.integers(4, cfg.vocab_size, 48).astype(np.int32))
    eng.run()
    assert eng._worker is None   # closed (and joined) in run()'s finally
    w = eng._stage_worker()
    assert not w.closed
    eng.close()
    assert eng._worker is None
    eng.close()                  # idempotent


# ---------------------------------------------------------------------------
# Exception propagation
# ---------------------------------------------------------------------------

def test_worker_exception_reraised_on_fence_and_fail_fast():
    w = _worker()

    def boom():
        raise ValueError("stripe out of range")

    ran = []
    w.submit(0, boom)
    w.submit(0, ran.append, "after")          # fail-fast: skipped
    with pytest.raises(HostStageError) as ei:
        w.fence(0)
    assert isinstance(ei.value.__cause__, ValueError)
    assert ran == []                          # job after the failure skipped
    w.close()


def test_hostpool_bounds_error_propagates_through_worker():
    """The real failure mode: HostPool.stage raises on an out-of-range
    stripe; staged off-thread, the error must surface on the dispatch
    thread instead of vanishing on a daemon thread."""
    geom = KVGeometry(num_layers=1, num_kv_heads=2, block_size=4,
                      head_dim=8, kv_factor=2)
    pool = HostPool(geom, num_blocks=1)       # 4-token capacity
    w = _worker()
    stripe = np.zeros((2, 1, 8), np.float32)
    w.submit(0, pool.stage, 0, 99, stripe, stripe)   # token 99: off the end
    with pytest.raises(HostStageError) as ei:
        w.drain()
    assert isinstance(ei.value.__cause__, ValueError)
    w.close()


def test_writeback_failure_fails_the_iteration(smoke_setup, monkeypatch):
    """A failing write-back job aborts the engine iteration that fenced
    on it (exception propagation from worker back to the iteration)."""
    cfg, params = smoke_setup("qwen2-0.5b")
    eng = ServingEngine(params, cfg, EngineConfig(chunk_size=64, r_max=4))
    rng = np.random.default_rng(1)
    eng.submit(Request(prompt_len=48, max_new_tokens=4),
               tokens=rng.integers(4, cfg.vocab_size, 48).astype(np.int32))

    def boom(*a, **k):
        raise ValueError("injected save failure")

    monkeypatch.setattr(eng.kv_mgr, "save_new_tokens_fused", boom)
    with pytest.raises(HostStageError) as ei:
        eng.run()
    assert isinstance(ei.value.__cause__, ValueError)
    assert eng._worker is None               # run()'s finally still closed it
