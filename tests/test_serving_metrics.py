"""serving/metrics p99 small-sample policy: below P99_MIN_SAMPLES the
tail is None (explicitly unmeasured), and meets_slo states its policy —
unmeasurable tails pass by default, fail under strict_p99."""
import numpy as np

from repro.serving.metrics import (P99_MIN_SAMPLES, _p99, compute_metrics,
                                   meets_slo)
from repro.serving.request import Request


def _finished(n_reqs, tbt=0.01, gen=5):
    """n finished requests, each with `gen` tokens at a steady `tbt`."""
    reqs = []
    for i in range(n_reqs):
        r = Request(prompt_len=8, max_new_tokens=gen, arrival_time=0.0)
        r.scheduled_time = 0.001
        r.first_token_time = 0.01
        r.token_times = [0.01 + k * tbt for k in range(gen)]
        r.finish_time = r.token_times[-1]
        r.generated = gen
        reqs.append(r)
    return reqs


class TestP99:
    def test_none_below_min_samples(self):
        assert _p99([1.0] * (P99_MIN_SAMPLES - 1)) is None
        assert _p99([]) is None

    def test_float_at_min_samples(self):
        xs = list(np.linspace(0.0, 1.0, P99_MIN_SAMPLES))
        p = _p99(xs)
        assert isinstance(p, float) and 0.9 <= p <= 1.0

    def test_compute_metrics_small_batch_has_none_tails(self):
        # 2 requests x 5 tokens = 8 TBT samples < P99_MIN_SAMPLES, and
        # 2 TTFT samples < P99_MIN_SAMPLES: both tails unmeasured
        m = compute_metrics(_finished(2), total_time=1.0)
        assert m.p99_ttft is None and m.p99_tbt is None
        assert np.isfinite(m.mean_ttft) and np.isfinite(m.mean_tbt)
        assert m.num_finished == 2

    def test_compute_metrics_large_batch_measures_tails(self):
        m = compute_metrics(_finished(12), total_time=1.0)
        assert isinstance(m.p99_ttft, float)
        assert isinstance(m.p99_tbt, float)
        assert abs(m.p99_tbt - 0.01) < 1e-12


class TestMeetsSlo:
    def test_unmeasured_tail_passes_by_default(self):
        reqs = _finished(2)                      # p99 is None
        assert meets_slo(reqs, 1.0, p99_tbt_limit=1e-9)

    def test_unmeasured_tail_fails_under_strict(self):
        reqs = _finished(2)
        assert not meets_slo(reqs, 1.0, p99_tbt_limit=1e9, strict_p99=True)

    def test_measured_violation_fails(self):
        reqs = _finished(12, tbt=0.05)
        assert not meets_slo(reqs, 1.0, p99_tbt_limit=0.02)

    def test_measured_pass(self):
        reqs = _finished(12, tbt=0.005)
        assert meets_slo(reqs, 1.0, p99_tbt_limit=0.02)
        assert meets_slo(reqs, 1.0, p99_tbt_limit=0.02, strict_p99=True)

    def test_queue_delay_gate(self):
        reqs = _finished(12)
        for r in reqs:
            r.scheduled_time = 5.0               # 5 s queue delay
        assert not meets_slo(reqs, 10.0, p99_tbt_limit=1.0,
                             mean_queue_limit=2.0)

    def test_no_finished_fails(self):
        r = Request(prompt_len=8, max_new_tokens=4)
        assert not meets_slo([r], 1.0, p99_tbt_limit=1.0)
