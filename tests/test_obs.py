"""Obs layer (src/repro/obs): tracer, metrics registry, trace analysis,
and the engine-level guarantees — disabled mode is free and does not
perturb outputs; enabled mode produces a valid Chrome trace whose
span-derived overlap agrees with the counter-derived overlap on the
SAME run; the metrics snapshot keeps a stable key surface."""
import json
import threading
import time
import tracemalloc

import numpy as np
import pytest

from repro.obs.metrics import MetricsRegistry, _prom_name
from repro.obs.trace_analysis import achieved_overlap_fraction
from repro.obs.tracing import NULL_TRACER, NullTracer, Tracer


# ---------------------------------------------------------------------------
# Tracer unit tests
# ---------------------------------------------------------------------------

class TestTracer:
    def test_complete_event_shape(self):
        tr = Tracer()
        t0 = tr.begin()
        time.sleep(0.001)
        tr.end("work", "test", t0, layer=3)
        evs = [e for e in tr.events() if e["ph"] == "X"]
        assert len(evs) == 1
        ev = evs[0]
        assert ev["name"] == "work" and ev["cat"] == "test"
        assert ev["ts"] >= 0 and ev["dur"] >= 1000   # >= 1 ms in us
        assert isinstance(ev["pid"], int) and ev["tid"] == 1
        assert ev["args"] == {"layer": 3}

    def test_complete_at_uses_caller_times_verbatim(self):
        tr = Tracer()
        t0 = time.perf_counter()
        tr.complete_at("x", "c", t0, 0.25)
        [ev] = [e for e in tr.events() if e["ph"] == "X"]
        assert ev["dur"] == pytest.approx(0.25e6)

    def test_span_context_manager(self):
        tr = Tracer()
        with tr.span("blk", "cat", k=1):
            time.sleep(0.001)
        [ev] = [e for e in tr.events() if e["ph"] == "X"]
        assert ev["name"] == "blk" and ev["dur"] >= 1000
        assert ev["args"] == {"k": 1}

    def test_thread_lanes_and_metadata(self):
        """Spans from a second thread land on their own tid with an "M"
        thread_name metadata event naming the lane."""
        tr = Tracer()
        tr.complete_at("main-span", "c", time.perf_counter(), 0.001)

        def emit():
            tr.complete_at("worker-span", "c", time.perf_counter(), 0.001)

        th = threading.Thread(target=emit, name="obs-test-worker")
        th.start()
        th.join()
        evs = tr.events()
        spans = {e["name"]: e for e in evs if e["ph"] == "X"}
        assert spans["main-span"]["tid"] != spans["worker-span"]["tid"]
        names = {e["args"]["name"]: e["tid"] for e in evs
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        assert names["obs-test-worker"] == spans["worker-span"]["tid"]

    def test_monotonic_ts_per_thread(self):
        tr = Tracer()
        for i in range(16):
            tr.complete_at(f"s{i}", "c", time.perf_counter(), 0.0)
        ts = [e["ts"] for e in tr.events() if e["ph"] == "X"]
        assert ts == sorted(ts)

    def test_chrome_trace_json_round_trip(self, tmp_path):
        tr = Tracer()
        tr.complete_at("a", "c", time.perf_counter(), 0.002, blocks=7)
        tr.instant("mark", "c")
        blob = json.dumps(tr.chrome_trace())
        back = json.loads(blob)
        assert back["displayTimeUnit"] == "ms"
        phs = {e["ph"] for e in back["traceEvents"]}
        assert {"M", "X", "i"} <= phs
        for e in back["traceEvents"]:
            assert "pid" in e and "tid" in e and "name" in e
            if e["ph"] == "X":
                assert "ts" in e and "dur" in e
        path = tmp_path / "t.trace.json"
        n = tr.dump_trace(str(path))
        assert n == len(back["traceEvents"])
        assert json.loads(path.read_text())["traceEvents"]


class TestNullTracer:
    def test_disabled_surface(self, tmp_path):
        assert NULL_TRACER.enabled is False
        assert isinstance(NULL_TRACER, NullTracer)
        NULL_TRACER.end("x", "c", 0.0)
        NULL_TRACER.complete_at("x", "c", 0.0, 1.0)
        NULL_TRACER.instant("x")
        with NULL_TRACER.span("x"):
            pass
        assert NULL_TRACER.events() == []
        assert NULL_TRACER.chrome_trace()["traceEvents"] == []
        assert NULL_TRACER.dump_trace(str(tmp_path / "x.json")) == 0

    def test_guarded_hot_path_is_allocation_free(self):
        """The per-layer pattern — `if tr.enabled: <emit>` — must not
        allocate when disabled: one attribute read and a branch."""
        tr = NULL_TRACER

        def hot(n):
            for _ in range(n):
                if tr.enabled:
                    t0 = time.perf_counter()
                    tr.end("x", "c", t0)

        hot(10)                      # warm any lazy setup
        tracemalloc.start()
        hot(10_000)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert peak < 1024           # no per-iteration allocation


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        c = reg.counter("a.count", "help")
        c.inc()
        c.inc(2)
        g = reg.gauge("a.depth", "help")
        g.set(5)
        g.inc()
        g.dec(2)
        h = reg.histogram("a.lat_s", "help")
        for v in (1.0, 3.0, 2.0):
            h.observe(v)
        s = reg.snapshot()
        assert s["a.count"] == 3
        assert s["a.depth"] == 4
        assert s["a.lat_s_count"] == 3
        assert s["a.lat_s_sum"] == pytest.approx(6.0)
        assert s["a.lat_s_min"] == 1.0 and s["a.lat_s_max"] == 3.0
        assert s["a.lat_s_mean"] == pytest.approx(2.0)

    def test_instruments_memoized(self):
        reg = MetricsRegistry()
        assert reg.counter("x", "h") is reg.counter("x", "h")
        assert reg.gauge("y", "h") is reg.gauge("y", "h")
        assert reg.histogram("z", "h") is reg.histogram("z", "h")

    def test_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("kv.h2d_calls", "fused H2D launches").inc(4)
        reg.histogram("engine.iteration_s", "iter wall").observe(0.5)
        txt = reg.prometheus_text(extra={"plane.count": 2})
        assert "# HELP kv_h2d_calls fused H2D launches" in txt
        assert "# TYPE kv_h2d_calls counter" in txt
        assert "kv_h2d_calls 4" in txt
        assert "engine_iteration_s_count 1" in txt
        assert "engine_iteration_s_sum 0.5" in txt
        assert "plane_count 2" in txt

    def test_prom_name_sanitization(self):
        assert _prom_name("a.b.c") == "a_b_c"
        assert _prom_name("9lives") == "_9lives"
        assert _prom_name("sp ace-y") == "sp_ace_y"


# ---------------------------------------------------------------------------
# Trace analysis: achieved_overlap_fraction on synthetic spans
# ---------------------------------------------------------------------------

def _ev(name, cat, ts, dur, tid=1):
    return {"ph": "X", "name": name, "cat": cat, "ts": ts, "dur": dur,
            "pid": 1, "tid": tid}


class TestTraceAnalysis:
    def test_full_overlap(self):
        """Worker busy entirely inside the iteration, no dispatch-thread
        host stage -> fraction 1.0."""
        evs = [_ev("iteration", "engine", 0, 1000),
               _ev("host-stage", "host-stage-worker", 100, 200, tid=2)]
        assert achieved_overlap_fraction(evs) == pytest.approx(1.0)

    def test_half_overlap(self):
        """Worker work == dispatch-thread host stage -> 0.5."""
        evs = [_ev("iteration", "engine", 0, 1000),
               _ev("host-stage", "host-stage-worker", 100, 300, tid=2),
               _ev("host-stage", "host-stage", 500, 300)]
        assert achieved_overlap_fraction(evs) == pytest.approx(0.5)

    def test_worker_outside_iteration_does_not_count(self):
        evs = [_ev("iteration", "engine", 0, 100),
               _ev("host-stage", "host-stage-worker", 500, 300, tid=2),
               _ev("host-stage", "host-stage", 0, 100)]
        assert achieved_overlap_fraction(evs) == pytest.approx(0.0)

    def test_none_without_spans(self):
        assert achieved_overlap_fraction([]) is None
        assert achieved_overlap_fraction(
            [_ev("iteration", "engine", 0, 100)]) is None
        assert achieved_overlap_fraction(
            {"traceEvents": []}) is None

    def test_accepts_chrome_dict(self):
        trace = {"traceEvents": [
            _ev("iteration", "engine", 0, 1000),
            _ev("host-stage", "host-stage-worker", 0, 500, tid=2)]}
        assert achieved_overlap_fraction(trace) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Worker-thread span emission (HostStageWorker + Tracer, no engine)
# ---------------------------------------------------------------------------

class TestWorkerSpans:
    def test_worker_emits_spans_on_own_tid(self):
        from repro.core.host_stage import HostStageWorker
        tr = Tracer()
        tr.complete_at("dispatch-side", "c", time.perf_counter(), 0.0)
        w = HostStageWorker(name="obs-test-hsw", tracer=tr)
        try:
            for i in range(4):
                w.submit(i % 2, time.sleep, 0.001)
            w.drain()
        finally:
            w.close()
        spans = [e for e in tr.events()
                 if e["ph"] == "X" and e["cat"] == "host-stage-worker"]
        assert len(spans) == 4
        main_tid = next(e["tid"] for e in tr.events()
                        if e["ph"] == "X" and e["name"] == "dispatch-side")
        tids = {e["tid"] for e in spans}
        assert len(tids) == 1 and main_tid not in tids
        ts = [e["ts"] for e in spans]
        assert ts == sorted(ts)                  # FIFO, monotonic lane
        assert all(e["args"]["key"] in (0, 1) for e in spans)
        # spans carry the same timing the busy_s counter accumulated
        assert sum(e["dur"] for e in spans) / 1e6 \
            == pytest.approx(w.busy_s, rel=1e-9)

    def test_worker_without_tracer_emits_nothing(self):
        from repro.core.host_stage import HostStageWorker
        w = HostStageWorker(name="obs-test-null")
        try:
            w.submit(0, time.sleep, 0.0)
            w.drain()
        finally:
            w.close()
        assert w.tracer is NULL_TRACER
        assert w.jobs_run == 1 and w.busy_s >= 0.0


# ---------------------------------------------------------------------------
# Engine-level guarantees (tiny real model)
# ---------------------------------------------------------------------------

# keys the snapshot must keep exposing — launch/serve.py, benchmarks, and
# the nightly asserts consume these; renaming one is an API break
SNAPSHOT_REQUIRED_KEYS = frozenset({
    "engine.iterations", "engine.decode_tokens", "engine.decode_step_calls",
    "engine.prefill_launches", "engine.iteration_s_count",
    "kv.h2d_calls", "kv.h2d_blocks", "kv.h2d_bytes",
    "kv.d2h_calls", "kv.d2h_blocks", "kv.d2h_bytes",
    "kv.hits", "kv.misses", "kv.evictions", "kv.hbm_used_bytes",
    "kv.hbm_budget_bytes",
    "sched.queue_depth", "sched.running",
    "plane.count", "plane.steps", "plane.host_syncs",
    "plane.dispatch_sync_s", "plane.host_stage_s",
    "worker.jobs_run", "worker.busy_s",
    "obs.enabled", "obs.trace_events",
})


def _run_workload(params, cfg, *, n=2, prompt=64, gen=8, seed=7, **eng_kw):
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request
    eng = ServingEngine(params, cfg, EngineConfig(
        chunk_size=64, r_max=4, hybrid_plane="split", **eng_kw))
    rng = np.random.default_rng(seed)
    for _ in range(n):
        eng.submit(Request(prompt_len=prompt, max_new_tokens=gen),
                   tokens=rng.integers(4, cfg.vocab_size,
                                       prompt).astype(np.int32))
    eng.run()
    return eng


@pytest.fixture(scope="module")
def obs_engines(tiny_cfg, tiny_params):
    """One obs-off and one obs-on run of the same async workload under
    eviction pressure (1-block LRU), shared across the engine tests."""
    off = _run_workload(tiny_params, tiny_cfg, obs=False,
                        hbm_blocks_per_request=1)
    on = _run_workload(tiny_params, tiny_cfg, obs=True,
                       hbm_blocks_per_request=1)
    return off, on


class TestEngineObs:
    def test_disabled_by_default_and_zero_spans(self, obs_engines):
        off, _ = obs_engines
        assert off.tracer is NULL_TRACER
        assert off.tracer.events() == []
        s = off.metrics_snapshot()
        assert s["obs.enabled"] == 0.0 and s["obs.trace_events"] == 0
        assert off.stage_overlap_from_trace() is None

    def test_obs_does_not_perturb_greedy_tokens(self, obs_engines):
        off, on = obs_engines
        toks_off = [st.out_tokens for st in off.states.values()]
        toks_on = [st.out_tokens for st in on.states.values()]
        assert toks_off == toks_on

    def test_snapshot_keys_stable(self, obs_engines):
        for eng in obs_engines:
            s = eng.metrics_snapshot()
            missing = SNAPSHOT_REQUIRED_KEYS - set(s)
            assert not missing, f"snapshot lost keys: {sorted(missing)}"
            assert all(isinstance(v, (int, float)) for v in s.values())

    def test_trace_valid_and_has_expected_lanes(self, obs_engines, tmp_path):
        _, on = obs_engines
        path = tmp_path / "run.trace.json"
        n = on.dump_trace(str(path))
        trace = json.loads(path.read_text())
        assert n == len(trace["traceEvents"]) and n > 0
        evs = trace["traceEvents"]
        spans = [e for e in evs if e["ph"] == "X"]
        by_cat = {}
        for e in spans:
            by_cat.setdefault(e["cat"], []).append(e)
        # iteration spans on the engine lane, stage + worker spans present
        assert any(e["name"] == "iteration" for e in by_cat["engine"])
        assert by_cat["stage"] and by_cat["host-stage-worker"]
        # the worker's spans live on their own tid lane
        worker_tids = {e["tid"] for e in by_cat["host-stage-worker"]}
        iter_tids = {e["tid"] for e in by_cat["engine"]}
        assert worker_tids and worker_tids.isdisjoint(iter_tids)
        # worker spans overlap iteration spans in wall time (the async
        # pipeline actually ran work concurrently with dispatch)
        iters = [(e["ts"], e["ts"] + e["dur"]) for e in by_cat["engine"]
                 if e["name"] == "iteration"]
        assert any(a < we["ts"] + we["dur"] and we["ts"] < b
                   for we in by_cat["host-stage-worker"]
                   for a, b in iters)

    def test_overlap_instruments_agree_same_run(self, obs_engines):
        """Acceptance: span-derived achieved overlap matches the
        counter-derived measured overlap within 10% on the SAME run."""
        _, on = obs_engines
        measured = on.stage_overlap_measured()
        achieved = on.stage_overlap_from_trace()
        assert measured is not None and achieved is not None
        assert abs(achieved - measured) <= max(0.02, 0.1 * measured), \
            (achieved, measured)

    def test_worker_counters_survive_close(self, obs_engines):
        _, on = obs_engines
        s = on.metrics_snapshot()
        assert s["worker.jobs_run"] > 0
        on.close()
        s2 = on.metrics_snapshot()
        assert s2["worker.jobs_run"] == s["worker.jobs_run"]

    def test_prometheus_exposition(self, obs_engines):
        _, on = obs_engines
        txt = on.metrics_prometheus()
        assert "# TYPE engine_iteration_s summary" in txt
        assert "kv_h2d_calls" in txt and "obs_enabled 1" in txt

    def test_obs_overhead_under_5_percent(self, tiny_cfg, tiny_params):
        """Tier-1 perf guard: obs-on wall clock within 5% of obs-off (plus
        an absolute epsilon for CI timer noise on sub-second runs).  Jit
        caches are warm from the module fixture, so this times the
        steady-state dispatch path."""
        def best(obs):
            return min(_best_wall(tiny_params, tiny_cfg, obs)
                       for _ in range(3))

        def _best_wall(params, cfg, obs):
            t0 = time.perf_counter()
            _run_workload(params, cfg, obs=obs, n=1, gen=6,
                          hbm_blocks_per_request=1)
            return time.perf_counter() - t0

        off = best(False)
        on = best(True)
        assert on <= off * 1.05 + 0.25, (on, off)
