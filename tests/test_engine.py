"""Real-execution serving engine integration tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Phase, Request


@pytest.fixture()
def setup(smoke_setup):
    return smoke_setup("qwen2-0.5b")


def run_engine(cfg, params, mode, n=3, prompt=96, gen=5, **kw):
    eng = ServingEngine(params, cfg, EngineConfig(
        prefill_mode=mode, chunk_size=64, r_max=4, **kw))
    for _ in range(n):
        eng.submit(Request(prompt_len=prompt, max_new_tokens=gen))
    m = eng.run()
    return eng, m


@pytest.mark.parametrize("mode", ["layer_segmented", "chunked"])
def test_engine_completes_all_requests(setup, mode):
    cfg, params = setup
    eng, m = run_engine(cfg, params, mode)
    assert m.num_finished == 3
    for st in eng.states.values():
        assert st.req.phase == Phase.FINISHED
        assert len(st.out_tokens) == st.req.max_new_tokens


def test_layer_segmented_prefill_equals_plain(setup):
    cfg, params = setup
    tokens = np.arange(7, 103, dtype=np.int32)
    lg_plain, _ = M.prefill(params, cfg,
                            {"tokens": jnp.asarray(tokens[None])}, 5,
                            cache_dtype=jnp.float32)
    eng = ServingEngine(params, cfg, EngineConfig())
    r = Request(prompt_len=96, max_new_tokens=2)
    eng.submit(r, tokens=tokens)
    while r.phase != Phase.DECODE:
        assert eng.step() is not None
    st = eng.states[r.req_id]
    np.testing.assert_allclose(np.asarray(st.last_logits),
                               np.asarray(lg_plain), rtol=1e-4, atol=1e-4)


def test_chunked_prefill_equals_plain(setup):
    cfg, params = setup
    tokens = np.arange(7, 103, dtype=np.int32)
    lg_plain, _ = M.prefill(params, cfg,
                            {"tokens": jnp.asarray(tokens[None])}, 5,
                            cache_dtype=jnp.float32)
    eng = ServingEngine(params, cfg, EngineConfig(prefill_mode="chunked",
                                                  chunk_size=32))
    r = Request(prompt_len=96, max_new_tokens=2)
    eng.submit(r, tokens=tokens)
    while r.phase != Phase.DECODE:
        assert eng.step() is not None
    st = eng.states[r.req_id]
    np.testing.assert_allclose(np.asarray(st.last_logits),
                               np.asarray(lg_plain), rtol=1e-3, atol=1e-3)


def test_both_prefill_modes_generate_same_tokens(setup):
    """End-to-end: greedy generation must not depend on the prefill mode."""
    cfg, params = setup
    outs = {}
    for mode in ["layer_segmented", "chunked"]:
        eng = ServingEngine(params, cfg, EngineConfig(
            prefill_mode=mode, chunk_size=48))
        r = Request(prompt_len=96, max_new_tokens=6)
        eng.submit(r, tokens=np.arange(7, 103, dtype=np.int32))
        eng.run()
        outs[mode] = eng.states[r.req_id].out_tokens
    assert outs["layer_segmented"] == outs["chunked"]


def test_transfer_stats_flow(setup):
    cfg, params = setup
    eng, _ = run_engine(cfg, params, "layer_segmented",
                        hbm_blocks_per_request=2)
    ts = eng.transfer_stats()
    assert ts.d2h_calls > 0           # FlashD2H saves during prefill
    assert ts.misses > 0              # tiny cache -> misses
    assert ts.h2d_bytes > 0
    assert sum(eng.loads_per_iter) > 0


def test_bigger_cache_fewer_loads(setup):
    """More HBM per request -> strictly fewer block loads (LRU locality)."""
    cfg, params = setup
    loads = {}
    for cap in (2, 64):
        eng, _ = run_engine(cfg, params, "layer_segmented",
                            hbm_blocks_per_request=cap, n=2, gen=8)
        loads[cap] = sum(eng.loads_per_iter)
    assert loads[64] < loads[2]


def test_ws_control_rejections(setup):
    """With a tiny M_avl the WS controller must reject requests."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, EngineConfig(
        ws_control=True, hbm_budget_bytes=1, r_max=4))
    for _ in range(3):
        eng.submit(Request(prompt_len=64, max_new_tokens=3))
    plan = eng.scheduler.schedule()
    assert plan.rejected > 0 or (not plan.decode_reqs
                                 and not plan.prefill_reqs)


def test_hybrid_batching(setup):
    """Decode and prefill coexist in one iteration once a request decodes."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, EngineConfig(r_max=4))
    r1 = Request(prompt_len=64, max_new_tokens=8)
    eng.submit(r1)
    # run r1 to decode
    while r1.phase != Phase.DECODE:
        eng.step()
    r2 = Request(prompt_len=64, max_new_tokens=8)
    eng.submit(r2)
    plan = eng.step()
    assert plan is not None
    assert plan.decode_reqs and plan.prefill_reqs   # hybrid batch


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "jamba-v0.1-52b",
                                  "whisper-small", "internvl2-2b",
                                  "minicpm3-4b", "kimi-k2-1t-a32b"])
def test_engine_on_nontrivial_arch_families(arch, smoke_setup):
    """The serving engine runs end-to-end on SSM / hybrid / enc-dec / VLM /
    MLA / MoE smoke variants, not just dense GQA.  Batched decode is the
    default path, so this also covers batch assembly per arch family."""
    cfg, params = smoke_setup(arch)
    eng = ServingEngine(params, cfg, EngineConfig(r_max=2))
    extra = {}
    if cfg.is_encoder_decoder:
        extra["frames"] = np.ones((1, 16, cfg.d_model), np.float32) * .01
    if cfg.frontend == "vit_patch_stub":
        extra["patch_embeds"] = np.ones(
            (1, cfg.num_patches, cfg.d_model), np.float32) * .01
    r = Request(prompt_len=64, max_new_tokens=4)
    eng.submit(r, **extra)
    m = eng.run()
    assert m.num_finished == 1
    assert len(eng.states[r.req_id].out_tokens) == 4
