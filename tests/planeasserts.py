"""Shared runtime plane assertions, backed by the plane contract.

These read the SAME metadata (``repro.core.plane_contract``) the static
analyzer (tools/analysis) checks, so the launch/trace formulas asserted
at runtime and the ones proven statically can never drift apart.  Before
this module each test file hand-rolled its own copies.
"""
from repro.core import plane_contract as pc


def assert_cache_hit_invariant(fns):
    """One XLA trace per distinct (stage, shape-signature) bucket — an
    occupancy change or a repeated bucket must be a pure cache hit."""
    assert fns.trace_count == len(fns.shape_signatures), (
        f"trace_count {fns.trace_count} != "
        f"{len(fns.shape_signatures)} shape signatures: "
        f"{sorted(fns.shape_signatures)}")


def staged_launches_per_iteration(cfg) -> int:
    """Jitted launches one staged decode iteration issues (the O(L)
    budget): embed + logits + (select+attend) per attention layer + one
    per recurrent layer."""
    return pc.staged_launches_per_iteration(cfg)


def staged_stage_kinds(cfg) -> int:
    """Distinct stage kinds in the staged pipeline — the per-shape-bucket
    trace budget."""
    return pc.staged_stage_kinds(cfg)
