"""Shared runtime plane assertions, backed by the plane contract.

These read the SAME metadata (``repro.core.plane_contract``) the static
analyzer (tools/analysis) checks, so the launch/trace formulas asserted
at runtime and the ones proven statically can never drift apart.  Before
this module each test file hand-rolled its own copies.
"""
from repro.core import plane_contract as pc


def assert_cache_hit_invariant(fns):
    """One XLA trace per distinct (stage, shape-signature) bucket — an
    occupancy change or a repeated bucket must be a pure cache hit."""
    assert fns.trace_count == len(fns.shape_signatures), (
        f"trace_count {fns.trace_count} != "
        f"{len(fns.shape_signatures)} shape signatures: "
        f"{sorted(fns.shape_signatures)}")


def staged_launches_per_iteration(cfg) -> int:
    """Jitted launches one staged decode iteration issues (the O(L)
    budget): embed + logits + (select+attend) per attention layer + one
    per recurrent layer."""
    return pc.staged_launches_per_iteration(cfg)


def staged_stage_kinds(cfg) -> int:
    """Distinct stage kinds in the staged pipeline — the per-shape-bucket
    trace budget."""
    return pc.staged_stage_kinds(cfg)


def assert_donation_contract(fns):
    """Every pool-updating stage of a live registry DECLARES buffer
    donation on its pool/cache argument, exactly as the contract's
    ``STAGED_DONATED_STAGES`` table says — on accelerator backends XLA
    then updates the pool in place instead of copying a pool-sized buffer
    per layer per iteration (on CPU the declaration is recorded but not
    armed: CPU buffers are not donatable)."""
    for stage, donate in pc.STAGED_DONATED_STAGES.items():
        if stage not in fns.donated:
            continue                     # stage absent for this arch family
        assert fns.donated[stage] == tuple(donate), (
            f"stage {stage!r} declares donate_argnums "
            f"{fns.donated[stage]}, contract says {tuple(donate)}")
    missing = set(pc.STAGED_DONATED_STAGES) - set(fns.donated)
    assert not missing & {"select"}, (
        f"pool-updating stages missing from the registry: {missing}")


def assert_host_sync_invariant(plane, iterations, cfg=None):
    """An async-mode plane's measured per-layer blocking syncs equal the
    contract formula exactly: np.asarray(selected ids) once per attention
    layer per iteration, and nothing else
    (``pc.staged_host_syncs_per_iteration``)."""
    cfg = cfg if cfg is not None else plane.cfg
    expected = pc.staged_host_syncs_per_iteration(cfg) * iterations
    assert plane.host_syncs == expected, (
        f"host_syncs {plane.host_syncs} != {expected} "
        f"({iterations} iterations)")


def assert_stripe_readback_invariant(plane, iterations, rows):
    """The FlashD2H readback stays STRIPE-sized: ``d2h_readback_bytes``
    equals rows x one token's KV per attention layer per iteration — and
    in particular is a vanishing fraction of the pool, pinning that the
    write-back path never copies pool-sized buffers to host."""
    cfg = plane.cfg
    c = plane.state["caches"][plane.pool_layers()[0]]
    itemsize = c["k"].dtype.itemsize
    kv_factor = 2 if "v" in c else 1
    Hkv = c["k"].shape[1]
    D = c["k"].shape[-1]
    stripe = rows * Hkv * D * itemsize * kv_factor
    expected = stripe * len(plane.pool_layers()) * iterations
    assert plane.d2h_readback_bytes == expected, (
        f"d2h_readback_bytes {plane.d2h_readback_bytes} != {expected}")
    assert stripe * len(plane.pool_layers()) < plane.device_bytes(), (
        "per-iteration readback is pool-sized — the write-back path must "
        "move one token's stripe per layer, not the pool")


def assert_mixed_launch_invariant(engine):
    """Contract checks over every MIXED iteration an engine ran, from its
    measured ``mixed_iter_log``:

    * exactly ONE fused FlashD2H per attention layer that had work (and
      none when write-back is off and no prefill group ran there);
    * at most ONE fused FlashH2D per layer;
    * recurrent layers never transfer;
    * measured jitted-launch total == ``mixed_launches_per_iteration``
      (O(L): decode planes x staged budget + prefill groups + finalizes),
      independent of how many rows rode the iteration."""
    assert engine.hybrid is not None, "engine is not running the mixed plane"
    log = engine.mixed_iter_log
    assert log, "no mixed iterations recorded"
    cfg = engine.cfg
    for entry in log:
        for lay, rec in entry["layers"].items():
            if rec["attn"]:
                worked = (rec["decode"] and engine.eng.decode_write_back) \
                    or rec["groups"] > 0
                assert rec["d2h"] == (1 if worked else 0), (lay, rec)
                assert rec["h2d"] <= 1, (lay, rec)
            else:
                assert rec["d2h"] == 0 and rec["h2d"] == 0, (lay, rec)
        expected = pc.mixed_launches_per_iteration(
            cfg, entry["decode_planes"], entry["groups"],
            entry["finalize"])
        assert entry["launches"] == expected, (entry, expected)
