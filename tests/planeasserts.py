"""Shared runtime plane assertions, backed by the plane contract.

These read the SAME metadata (``repro.core.plane_contract``) the static
analyzer (tools/analysis) checks, so the launch/trace formulas asserted
at runtime and the ones proven statically can never drift apart.  Before
this module each test file hand-rolled its own copies.
"""
from repro.core import plane_contract as pc


def assert_cache_hit_invariant(fns):
    """One XLA trace per distinct (stage, shape-signature) bucket — an
    occupancy change or a repeated bucket must be a pure cache hit."""
    assert fns.trace_count == len(fns.shape_signatures), (
        f"trace_count {fns.trace_count} != "
        f"{len(fns.shape_signatures)} shape signatures: "
        f"{sorted(fns.shape_signatures)}")


def staged_launches_per_iteration(cfg) -> int:
    """Jitted launches one staged decode iteration issues (the O(L)
    budget): embed + logits + (select+attend) per attention layer + one
    per recurrent layer."""
    return pc.staged_launches_per_iteration(cfg)


def staged_stage_kinds(cfg) -> int:
    """Distinct stage kinds in the staged pipeline — the per-shape-bucket
    trace budget."""
    return pc.staged_stage_kinds(cfg)


def assert_mixed_launch_invariant(engine):
    """Contract checks over every MIXED iteration an engine ran, from its
    measured ``mixed_iter_log``:

    * exactly ONE fused FlashD2H per attention layer that had work (and
      none when write-back is off and no prefill group ran there);
    * at most ONE fused FlashH2D per layer;
    * recurrent layers never transfer;
    * measured jitted-launch total == ``mixed_launches_per_iteration``
      (O(L): decode planes x staged budget + prefill groups + finalizes),
      independent of how many rows rode the iteration."""
    assert engine.hybrid is not None, "engine is not running the mixed plane"
    log = engine.mixed_iter_log
    assert log, "no mixed iterations recorded"
    cfg = engine.cfg
    for entry in log:
        for lay, rec in entry["layers"].items():
            if rec["attn"]:
                worked = (rec["decode"] and engine.eng.decode_write_back) \
                    or rec["groups"] > 0
                assert rec["d2h"] == (1 if worked else 0), (lay, rec)
                assert rec["h2d"] <= 1, (lay, rec)
            else:
                assert rec["d2h"] == 0 and rec["h2d"] == 0, (lay, rec)
        expected = pc.mixed_launches_per_iteration(
            cfg, entry["decode_planes"], entry["groups"],
            entry["finalize"])
        assert entry["launches"] == expected, (entry, expected)
