"""Distributed-path equivalence + dry-run integration tests.

These spawn SUBPROCESSES with XLA_FLAGS device-count overrides so the main
test process keeps seeing the single real CPU device (the dryrun.py
contract).  The small-mesh equivalence checks (4 placeholder devices, tiny
smoke models) are FAST and run per-PR — the CI `multi-device` job selects
them with ``-m "not slow"`` — while the 512-device dry-run compiles and the
sharded train step stay ``slow`` (nightly).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 420) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_expert_parallel_matches_dense():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import ffn as F
        cfg = get_smoke_config("kimi-k2-1t-a32b")
        p = F.init_moe_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        y_ref, _ = F.moe_apply(p, cfg, x)
        y_ep, _ = jax.jit(lambda pp, xx: F.moe_apply_ep(
            pp, cfg, xx, mesh=mesh))(p, x)
        print("MATCH" if np.allclose(np.asarray(y_ep), np.asarray(y_ref),
                                     atol=1e-4) else "MISMATCH")
    """)
    assert "MATCH" in out


def test_cp_decode_matches_reference():
    """Fused context-parallel decode (explicit PlaneMesh, ex-CP_AXES
    global) == single-device reference."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.plane_mesh import PlaneMesh
        from repro.models import model as M
        cfg = get_smoke_config("qwen2-0.5b")
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        toks = np.random.default_rng(0).integers(
            4, cfg.vocab_size, (2, 128)).astype(np.int32)
        _, state = M.prefill(params, cfg, {"tokens": jnp.asarray(toks)}, 8,
                             cache_dtype=jnp.float32)
        lg_ref, st = M.decode_step(params, cfg,
                                   jnp.asarray([5, 9], jnp.int32), state)
        lg_ref2, _ = M.decode_step(params, cfg,
                                   jnp.asarray([3, 2], jnp.int32), st)
        pm = PlaneMesh(mesh=jax.make_mesh((2, 2), ("data", "model")))
        fn = jax.jit(lambda t, s: M.decode_step(params, cfg, t, s,
                                                plane_mesh=pm))
        lg, st2 = fn(jnp.asarray([5, 9], jnp.int32), state)
        lg2, _ = fn(jnp.asarray([3, 2], jnp.int32), st2)
        ok = (np.allclose(lg_ref, lg, atol=2e-4)
              and np.allclose(lg_ref2, lg2, atol=2e-4))
        print("MATCH" if ok else "MISMATCH")
    """)
    assert "MATCH" in out


@pytest.mark.slow
def test_cp_mla_decode_matches_reference():
    """MLA (minicpm3): context-parallel latent-pool decode == reference."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.plane_mesh import PlaneMesh
        from repro.models import model as M
        cfg = get_smoke_config("minicpm3-4b")
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        toks = np.random.default_rng(0).integers(
            4, cfg.vocab_size, (2, 128)).astype(np.int32)
        _, state = M.prefill(params, cfg, {"tokens": jnp.asarray(toks)}, 8,
                             cache_dtype=jnp.float32)
        lg_ref, st = M.decode_step(params, cfg,
                                   jnp.asarray([5, 9], jnp.int32), state)
        lg_ref2, _ = M.decode_step(params, cfg,
                                   jnp.asarray([3, 2], jnp.int32), st)
        pm = PlaneMesh(mesh=jax.make_mesh((2, 2), ("data", "model")))
        fn = jax.jit(lambda t, s: M.decode_step(params, cfg, t, s,
                                                plane_mesh=pm))
        lg, st2 = fn(jnp.asarray([5, 9], jnp.int32), state)
        lg2, _ = fn(jnp.asarray([3, 2], jnp.int32), st2)
        ok = (np.allclose(lg_ref, lg, atol=2e-4)
              and np.allclose(lg_ref2, lg2, atol=2e-4))
        print("MATCH" if ok else "MISMATCH")
    """)
    assert "MATCH" in out


@pytest.mark.slow
def test_dryrun_lowers_and_compiles_multipod():
    """One real dryrun invocation per mesh proves the 512-device path."""
    out = run_py("""
        from repro.launch.dryrun import lower_one
        for mp in (False, True):
            rec = lower_one("qwen2-0.5b", "decode_32k", multi_pod=mp,
                            verbose=False)
            assert rec["chips"] == (512 if mp else 256)
            assert rec["memory"]["argument_size_in_bytes"] > 0
            assert rec["roofline"]["dominant"] in ("compute", "memory",
                                                   "collective")
        print("DRYRUN_OK")
    """, devices=512, timeout=540)
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_dryrun_optimized_variants_lower():
    out = run_py("""
        from repro.launch.dryrun import lower_one
        rec = lower_one("kimi-k2-1t-a32b", "decode_32k", moe_ep=True,
                        cp_decode=True, donate_state=True, zero_data=True,
                        verbose=False)
        assert rec["variant"] == "ep+cp+donate+zero"
        print("OPT_OK", rec["compile_s"])
    """, devices=512, timeout=540)
    assert "OPT_OK" in out


@pytest.mark.slow
def test_sharded_train_step_runs_on_local_mesh():
    """Real multi-device execution (not just lowering): 4-device train."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch import sharding as sh
        from repro.launch.mesh import make_local_mesh
        from repro.launch.steps import make_train_step
        from repro.models import model as M
        from repro.training.optimizer import AdamWConfig, init_opt_state
        cfg = get_smoke_config("qwen2.5-3b")
        mesh = make_local_mesh(model_axis=2)
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        opt = init_opt_state(params)
        ps = sh.param_shardings(jax.eval_shape(lambda: params), mesh)
        os_ = sh.opt_shardings(jax.eval_shape(lambda: opt), mesh)
        step = jax.jit(make_train_step(cfg, AdamWConfig(), remat=False),
                       in_shardings=(ps, os_, None),
                       out_shardings=(ps, os_, None))
        batch = {"tokens": jnp.ones((4, 32), jnp.int32),
                 "labels": jnp.ones((4, 32), jnp.int32)}
        with mesh:
            params = jax.device_put(params, ps)
            opt = jax.device_put(opt, os_)
            for _ in range(2):
                params, opt, m = step(params, opt, batch)
        assert bool(jnp.isfinite(m["loss"]))
        print("TRAIN_OK", float(m["loss"]))
    """)
    assert "TRAIN_OK" in out
