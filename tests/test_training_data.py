"""Training substrate + data pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DataConfig, TokenStream, eval_stream
from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, lr_at)

SET = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=1,
                      total_steps=100, schedule="constant")
    for _ in range(100):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(cfg, params, g, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.15


@given(step=st.integers(0, 9999))
@settings(**SET)
def test_lr_schedule_bounds(step):
    cfg = AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=10000)
    lr = float(lr_at(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.lr * (1 + 1e-6)   # f32 rounding headroom


def test_lr_warmup_monotone_then_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=50, total_steps=1000)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 1000, 10)]
    warm = lrs[:5]
    assert all(a <= b + 1e-12 for a, b in zip(warm, warm[1:]))
    assert lrs[-1] < max(lrs)
    assert lrs[-1] >= cfg.lr * cfg.min_lr_frac * 0.99


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=1, schedule="constant")
    huge = {"w": jnp.full(4, 1e9)}
    p2, _, m = adamw_update(cfg, params, huge, opt)
    assert float(m["grad_norm"]) > 1e8
    assert float(jnp.abs(p2["w"]).max()) < 10.0     # clipped


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, tiny_cfg, tiny_params):
    path = str(tmp_path / "ck.npz")
    opt = init_opt_state(tiny_params)
    tree = {"params": tiny_params, "opt": opt}
    save_checkpoint(path, tree, step=42)
    restored, step = restore_checkpoint(path, tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path, tiny_params):
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_stream_deterministic():
    c = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
    b1 = TokenStream(c).batch()
    b2 = TokenStream(c).batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_stream_hosts_disjoint():
    base = dict(vocab_size=1000, seq_len=32, global_batch=8, num_hosts=2,
                seed=7)
    b0 = TokenStream(DataConfig(host_id=0, **base)).batch()
    b1 = TokenStream(DataConfig(host_id=1, **base)).batch()
    assert b0["tokens"].shape == (4, 32)          # global/hosts
    assert not np.array_equal(b0["tokens"], b1["tokens"])


@given(seq=st.integers(2, 128), batch=st.integers(1, 8),
       vocab=st.integers(16, 1 << 17))
@settings(**SET)
def test_stream_shapes_and_vocab_range(seq, batch, vocab):
    c = DataConfig(vocab_size=vocab, seq_len=seq, global_batch=batch)
    b = TokenStream(c).batch()
    assert b["tokens"].shape == (batch, seq)
    assert b["labels"].shape == (batch, seq)
    assert b["tokens"].min() >= 4 and b["tokens"].max() < vocab
    # next-token structure: labels are tokens shifted by one
    full = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full[:, 1:], b["labels"])


def test_eval_stream_differs_from_train():
    c = DataConfig(vocab_size=1000, seq_len=32, global_batch=2, seed=3)
    tr = TokenStream(c).batch()
    ev = eval_stream(c, 1)[0]
    assert not np.array_equal(tr["tokens"], ev["tokens"])


def test_tiny_train_loss_decreases(tiny_cfg):
    from repro.training.trainer import TrainConfig, train
    dc = DataConfig(vocab_size=tiny_cfg.vocab_size, seq_len=48,
                    global_batch=4)
    _, hist = train(tiny_cfg,
                    TrainConfig(steps=25, log_every=5,
                                opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                                total_steps=25)),
                    dc, verbose=False)
    assert hist["loss"][-1] < hist["loss"][0]
