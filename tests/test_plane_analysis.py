"""Plane-contract analyzer (tools/analysis): every seeded-violation
fixture yields findings of EXACTLY its rule, the clean fixture and the
real tree come back empty, and intentional deviations are waived
in-source rather than silently passed."""
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from repro.core import plane_contract as pc

from tools.analysis.fixtures import FIXTURES
from tools.analysis.run import analyze

_SEEDED = sorted(n for n, (_, rule) in FIXTURES.items() if rule is not None)


@pytest.mark.parametrize("name", _SEEDED)
def test_fixture_flags_exactly_its_rule(name):
    target, rule = FIXTURES[name]
    found = analyze(target)
    assert found, f"{name}: seeded violation not detected"
    assert {f.rule for f in found} == {rule}, \
        [f.render() for f in found]
    assert all(not f.waived for f in found)


def test_fixture_rules_cover_every_rule():
    """One seeded fixture per contract rule — no rule goes untested."""
    assert {rule for _, rule in FIXTURES.values()
            if rule is not None} == set(pc.ALL_RULES)


def test_clean_mini_has_no_findings():
    target, rule = FIXTURES["clean_mini"]
    assert rule is None
    assert analyze(target) == []


def test_cli_exit_codes():
    """run.py exits non-zero on a seeded fixture, zero on a clean one."""
    from tools.analysis.run import main
    assert main(["--fixture", "bad_double_d2h"]) == 1
    assert main(["--fixture", "clean_mini"]) == 0
    assert main(["--list-fixtures"]) == 0


def test_real_hybrid_driver_clean():
    """The REAL mixed-iteration driver (hybrid_plane.HybridPlane plus the
    engine's spliced layer_cb) passes the stage-protocol pass unwaived,
    with ALL FIVE pass-1 rules active for the 'hybrid-plane' protocol —
    the static counterpart of assert_mixed_launch_invariant."""
    assert set(pc.PROTOCOL_RULES["hybrid-plane"]) == {
        pc.RULE_RESTORE_BEFORE_USE, pc.RULE_WRITEBACK_BEFORE_DROP,
        pc.RULE_FUSED_TRANSFER, pc.RULE_CTX_LIFETIME, pc.RULE_LAUNCHES}
    drivers = tuple(d for d in pc.DEFAULT_DRIVERS
                    if d.protocol == "hybrid-plane")
    assert drivers, "hybrid driver missing from the contract"
    target = pc.AnalysisTarget(name="hybrid-only", drivers=drivers)
    assert analyze(target) == []


def test_real_tree_clean(smoke_setup):
    """Full three-pass run over the real tree: zero UNWAIVED findings —
    and the legacy per-request saves are visibly waived, not silently
    accepted.  The sharding pass reuses the session-cached smoke params
    for its registry-populating engine runs."""
    found = analyze(pc.DEFAULT_TARGET, get_setup=smoke_setup)
    unwaived = [f.render() for f in found if not f.waived]
    assert unwaived == []
    assert sum(1 for f in found if f.waived) >= 2


def test_waiver_parsing_round_trip():
    src = ("x = 1\n"
           "# plane-contract: allow(fused-transfer) legacy executor\n"
           "host.save_contiguous(0, 0, k, v)\n")
    waivers = pc.collect_waivers(src)
    assert waivers == {2: ("fused-transfer", "legacy executor")}
    assert pc.waiver_for(waivers, "fused-transfer", 3) == "legacy executor"
    assert pc.waiver_for(waivers, "fused-transfer", 5) is None
    assert pc.waiver_for(waivers, "ctx-lifetime", 3) is None
