"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only dryrun.py requests 512 placeholders."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M


@pytest.fixture(scope="session")
def tiny_cfg():
    return get_smoke_config("qwen2-0.5b")


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return M.init_params(tiny_cfg, jax.random.PRNGKey(0), jnp.float32)


@pytest.fixture(scope="session")
def tiny_params_list(tiny_cfg):
    return M.init_params(tiny_cfg, jax.random.PRNGKey(0), jnp.float32,
                         stacked=False)


def make_batch(cfg, B=2, S=64, key=3):
    batch = {"tokens": jnp.full((B, S), key, jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, 32, cfg.d_model), jnp.float32) * 0.01
    if cfg.frontend == "vit_patch_stub":
        batch["patch_embeds"] = jnp.ones(
            (B, cfg.num_patches, cfg.d_model), jnp.float32) * 0.01
    return batch
