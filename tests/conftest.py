"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only dryrun.py requests 512 placeholders.

Model/param construction is session-scoped and shared across modules
(``smoke_setup``) so the tier-1 suite initializes each smoke architecture
once instead of once per module — part of keeping the CPU run under the
10-minute budget."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M


def make_abstract_mesh(sizes, names):
    """jax.sharding.AbstractMesh across jax versions: new API takes
    (axis_sizes, axis_names); 0.4.x takes ((name, size), ...)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


@pytest.fixture(scope="session")
def tiny_cfg(smoke_setup):
    return smoke_setup("qwen2-0.5b")[0]


@pytest.fixture(scope="session")
def tiny_params(smoke_setup):
    return smoke_setup("qwen2-0.5b")[1]


@pytest.fixture(scope="session")
def smoke_setup():
    """get(arch) -> (smoke cfg, float32 params), cached for the session."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            cache[arch] = (cfg, M.init_params(cfg, jax.random.PRNGKey(0),
                                              jnp.float32))
        return cache[arch]

    return get


@pytest.fixture(scope="session")
def tiny_params_list(tiny_cfg):
    return M.init_params(tiny_cfg, jax.random.PRNGKey(0), jnp.float32,
                         stacked=False)


def make_batch(cfg, B=2, S=64, key=3):
    batch = {"tokens": jnp.full((B, S), key, jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, 32, cfg.d_model), jnp.float32) * 0.01
    if cfg.frontend == "vit_patch_stub":
        batch["patch_embeds"] = jnp.ones(
            (B, cfg.num_patches, cfg.d_model), jnp.float32) * 0.01
    return batch
