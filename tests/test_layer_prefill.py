"""Layer-segmented prefill planner properties (§3.4)."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.layer_prefill import (LayerPrefillState, hbm_footprint_tokens,
                                      plan_segments)

SET = dict(max_examples=50, deadline=None)


@given(prompt=st.integers(1, 5000), layers=st.integers(1, 64),
       step=st.integers(1, 5000))
@settings(**SET)
def test_plan_covers_prompt_exactly_per_layer(prompt, layers, step):
    segs = plan_segments(prompt, layers, step)
    # every layer appears, in order, covering [0, prompt) exactly
    per_layer = {}
    for s in segs:
        per_layer.setdefault(s.layer, []).append(s)
    assert sorted(per_layer) == list(range(layers))
    for l, ss in per_layer.items():
        pos = 0
        for s in ss:
            assert s.chunk_start == pos
            pos += s.chunk_len
        assert pos == prompt
        assert ss[-1].is_last_chunk_of_layer
        assert all(not s.is_last_chunk_of_layer for s in ss[:-1])
    # exactly one terminal segment: last chunk of last layer
    lasts = [s for s in segs if s.is_last]
    assert len(lasts) == 1
    assert lasts[0].layer == layers - 1


@given(prompt=st.integers(1, 2000), layers=st.integers(1, 16),
       step=st.integers(1, 2000))
@settings(**SET)
def test_layer_order_is_outer_loop(prompt, layers, step):
    """Layer l's segments all precede layer l+1's (KV of layer l can be
    evicted before l+1 starts — the one-layer HBM bound)."""
    segs = plan_segments(prompt, layers, step)
    layer_seq = [s.layer for s in segs]
    assert layer_seq == sorted(layer_seq)


def test_cursor_state():
    segs = plan_segments(100, 3, 40)
    stt = LayerPrefillState(segments=segs)
    seen = []
    while not stt.done:
        seen.append(stt.advance())
    assert seen == segs


@given(prompt=st.integers(1, 4000), layers=st.integers(1, 64),
       done=st.integers(0, 4000))
@settings(**SET)
def test_hbm_footprint_bound(prompt, layers, done):
    done = min(done, prompt)
    chunked = hbm_footprint_tokens(prompt, "chunked", layers, done)
    seg = hbm_footprint_tokens(prompt, "layer_segmented", layers, done)
    assert seg == prompt                     # ONE layer of the whole prompt
    assert chunked == done * layers          # grows with progress
    if done == prompt and layers > 1:
        assert seg < chunked                 # the paper's Fig. 16a claim


@given(prompt=st.integers(1, 4000), layers=st.integers(1, 64),
       resident=st.integers(0, 8000))
@settings(**SET)
def test_hbm_footprint_measured_residency(prompt, layers, resident):
    """The watermark form: a measured per-row residency (the prefill
    plane's within-iteration peak of the CURRENT layer) is reported
    directly, still capped by the one-layer bound."""
    seg = hbm_footprint_tokens(prompt, "layer_segmented", layers,
                               layer_tokens_resident=resident)
    assert seg == min(resident, prompt)
    assert seg <= hbm_footprint_tokens(prompt, "layer_segmented", layers)
