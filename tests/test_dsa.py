"""DSA property tests (hypothesis): metadata soundness, selection
invariants, and the sparse≈full attention guarantee under full budget."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import dsa
from repro.models.common import DSAConfig

SET = dict(max_examples=25, deadline=None)


@given(nb=st.integers(1, 8), bs=st.integers(1, 16), d=st.integers(1, 32),
       seed=st.integers(0, 2**16))
@settings(**SET)
def test_cuboid_metadata_bounds_all_keys(nb, bs, d, seed):
    keys = jax.random.normal(jax.random.PRNGKey(seed), (nb, bs, d))
    meta = dsa.build_block_metadata(keys, "cuboid")
    mn, mx = np.asarray(meta[..., 0, :]), np.asarray(meta[..., 1, :])
    kn = np.asarray(keys)
    assert (kn >= mn[:, None, :] - 1e-6).all()
    assert (kn <= mx[:, None, :] + 1e-6).all()


@given(nb=st.integers(1, 6), bs=st.integers(2, 8), d=st.integers(1, 16),
       seed=st.integers(0, 2**16))
@settings(**SET)
def test_cuboid_score_upper_bounds_true_attention(nb, bs, d, seed):
    """Quest guarantee: cuboid score >= max_j q·k_j within the block."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    keys = jax.random.normal(k1, (1, 1, nb, bs, d))
    q = jax.random.normal(k2, (1, 2, d))         # 2 query heads, 1 kv head
    meta = dsa.build_block_metadata(keys, "cuboid")
    scores = np.asarray(dsa.score_blocks(q, meta, "cuboid"))   # (1,1,nb)
    true = np.einsum("bhd,bcnsd->bhns", np.asarray(q),
                     np.asarray(keys))            # (1,2,nb,bs)
    true_max = true.max(axis=(1, 3))              # max over heads and tokens
    assert (scores[0, 0] >= true_max[0] - 1e-4).all()


@given(seed=st.integers(0, 2**16), nb=st.integers(2, 20),
       cur_blocks=st.integers(1, 20), budget_blocks=st.integers(1, 8))
@settings(**SET)
def test_select_blocks_invariants(seed, nb, cur_blocks, budget_blocks):
    cur_blocks = min(cur_blocks, nb)
    cfg = DSAConfig(block_size=4, token_budget=budget_blocks * 4,
                    sink_blocks=1, recent_blocks=1)
    scores = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, nb))
    cur_len = jnp.array([cur_blocks * 4], jnp.int32)
    idx, valid = dsa.select_blocks(scores, cfg, cur_len)
    idx, valid = np.asarray(idx), np.asarray(valid)
    k = idx.shape[-1]
    assert k == min(cfg.top_k_blocks, nb)
    # all valid selections point at written blocks
    assert (idx[valid] < cur_blocks).all()
    # no duplicate valid selections
    sel = idx[0, 0][valid[0, 0]]
    assert len(set(sel.tolist())) == len(sel)
    # sink block 0 and the most recent block are always selected when valid
    if cur_blocks >= 1 and k >= 2:
        assert 0 in sel
        assert (cur_blocks - 1) in sel


def test_sparse_equals_full_attention_when_budget_covers_all():
    """With top-k >= all blocks, DSA output == dense attention output."""
    B, Hq, Hkv, NB, bs, D = 2, 8, 2, 6, 8, 32
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kp = jax.random.normal(ks[1], (B, Hkv, NB, bs, D))
    vp = jax.random.normal(ks[2], (B, Hkv, NB, bs, D))
    cur_len = jnp.array([NB * bs, NB * bs - 5], jnp.int32)
    cfg = DSAConfig(block_size=bs, token_budget=NB * bs)
    meta = dsa.build_block_metadata(kp, "cuboid")
    scores = dsa.score_blocks(q, meta, "cuboid")
    idx, valid = dsa.select_blocks(scores, cfg, cur_len)
    sparse = dsa.sparse_decode_attention_ref(q, kp, vp, idx, valid, cur_len)
    full = dsa.full_decode_attention_ref(q, kp, vp, cur_len)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_sparse_attention_close_to_full_under_budget():
    """Paper Table 1 rationale: with a fraction of the budget the sparse
    output stays close to full attention (top-k picks the heavy hitters)."""
    B, Hq, Hkv, NB, bs, D = 1, 4, 1, 16, 8, 32
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, D))
    # construct pools where a few blocks dominate: scale up block 3 and 11
    kp = jax.random.normal(ks[1], (B, Hkv, NB, bs, D)) * 0.05
    kp = kp.at[:, :, [3, 11]].multiply(120.0)
    vp = jax.random.normal(ks[2], (B, Hkv, NB, bs, D))
    cur_len = jnp.array([NB * bs], jnp.int32)
    cfg = DSAConfig(block_size=bs, token_budget=8 * bs)   # half the blocks
    meta = dsa.build_block_metadata(kp, "cuboid")
    scores = dsa.score_blocks(q, meta, "cuboid")
    idx, valid = dsa.select_blocks(scores, cfg, cur_len)
    sparse = np.asarray(dsa.sparse_decode_attention_ref(
        q, kp, vp, idx, valid, cur_len))
    full = np.asarray(dsa.full_decode_attention_ref(q, kp, vp, cur_len))
    rel = np.linalg.norm(sparse - full) / np.linalg.norm(full)
    assert rel < 0.05, f"sparse deviates {rel:.3f} from full"


@given(seed=st.integers(0, 2**16))
@settings(**SET)
def test_mean_metadata_is_block_mean(seed):
    keys = jax.random.normal(jax.random.PRNGKey(seed), (3, 4, 8))
    meta = dsa.build_block_metadata(keys, "mean")
    np.testing.assert_allclose(np.asarray(meta),
                               np.asarray(keys).mean(axis=1), rtol=1e-5)


def test_metadata_valid_mask():
    keys = jnp.ones((2, 4, 8))
    valid = jnp.array([[True, True, False, False],
                       [True, False, False, False]])
    meta = dsa.build_block_metadata(keys * jnp.arange(1, 5)[None, :, None],
                                    "cuboid", valid)
    mn, mx = np.asarray(meta[..., 0, :]), np.asarray(meta[..., 1, :])
    assert np.allclose(mx[0], 2.0) and np.allclose(mn[0], 1.0)
    assert np.allclose(mx[1], 1.0) and np.allclose(mn[1], 1.0)
