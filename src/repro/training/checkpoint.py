"""Checkpointing: save/restore param + optimizer pytrees to .npz.

Pytrees are flattened to (path -> array) with '/'-joined key paths; restore
rebuilds against a reference pytree (so list-of-dict layer structures round
trip exactly).  Atomic rename avoids torn checkpoints.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    flat = _flatten(tree)
    flat["__step__"] = np.asarray(step)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)      # savez keeps the name (ends in .npz)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore_checkpoint(path: str, reference: Any) -> Any:
    """Restore into the structure of `reference` (dtypes preserved)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    step = int(flat.pop("__step__", 0))
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(reference)
    new_leaves = []
    for path_k, leaf in leaves_ref:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_k)
        if key not in flat:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(reference)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
