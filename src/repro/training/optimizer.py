"""AdamW optimizer + LR schedules, pure-pytree (no optax offline).

State is a pytree mirroring params: {"m": ..., "v": ..., "step": ()}.  All
ops are elementwise jnp — shards trivially under pjit with the same sharding
as the parameters (ZeRO-style optimizer-state sharding is applied by the
launcher by sharding params over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"     # "cosine" | "linear" | "constant"


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    return cfg.lr * warm * decay


def init_opt_state(params: Any) -> Dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: Dict
                 ) -> Tuple[Any, Dict, Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decay matrices only (norms/bias excluded)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gn, "lr": lr}
