"""Training loop: jit'd train_step + host loop with checkpointing.

``make_train_step`` builds a pure (params, opt_state, batch) ->
(params, opt_state, metrics) function suitable for jax.jit with in/out
shardings from `repro.launch.sharding` — the same function the multi-pod
dry-run lowers for the train_4k input shape.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, TokenStream
from repro.models import model as M
from repro.models.common import ModelConfig
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    log_every: int = 10
    ckpt_every: int = 0             # 0 = only final
    ckpt_path: str = ""
    remat: bool = True
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    remat: bool = True) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, _ = M.forward_train(p, cfg, batch, remat=remat)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt_state2, om = adamw_update(opt_cfg, params, grads,
                                               opt_state)
        metrics = {"loss": loss, **om}
        return params2, opt_state2, metrics
    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        loss, _ = M.forward_train(params, cfg, batch, remat=False)
        return loss
    return eval_step


def train(cfg: ModelConfig, tc: TrainConfig, data_cfg: DataConfig,
          *, params=None, seed: int = 0, verbose: bool = True
          ) -> Tuple[Any, Dict[str, list]]:
    """Single-process training driver (CPU example scale)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = M.init_params(cfg, key, jnp.float32)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, tc.opt, tc.remat))
    stream = TokenStream(data_cfg)
    hist: Dict[str, list] = {"loss": [], "grad_norm": [], "lr": [],
                             "step_time": []}
    t_last = time.perf_counter()
    for step in range(tc.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch().items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (step + 1) % tc.log_every == 0 or step == 0:
            loss = float(m["loss"])
            now = time.perf_counter()
            dt = (now - t_last) / (1 if step == 0 else tc.log_every)
            t_last = now
            hist["loss"].append(loss)
            hist["grad_norm"].append(float(m["grad_norm"]))
            hist["lr"].append(float(m["lr"]))
            hist["step_time"].append(dt)
            if verbose:
                print(f"step {step+1:5d} loss {loss:7.4f} "
                      f"gnorm {float(m['grad_norm']):8.3f} "
                      f"lr {float(m['lr']):.2e} {dt*1e3:7.1f} ms/step")
        if tc.ckpt_every and tc.ckpt_path and (step + 1) % tc.ckpt_every == 0:
            save_checkpoint(tc.ckpt_path, {"params": params,
                                           "opt": opt_state}, step + 1)
    if tc.ckpt_path:
        save_checkpoint(tc.ckpt_path, {"params": params, "opt": opt_state},
                        tc.steps)
    return params, hist
