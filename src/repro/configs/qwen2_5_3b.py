"""Qwen2.5-3B — GQA kv=2, QKV bias [hf:Qwen/Qwen2.5-0.5B family]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", arch_type="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936, qkv_bias=True,
    source="hf:Qwen/Qwen2.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2.5-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, d_ff=512, vocab_size=512)
