"""LWM-7B (paper's main model; Llama2-7B architecture, MHA, 1M ctx)
[arXiv:2402.08268]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="lwm-7b", arch_type="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=32000,
    source="arXiv:2402.08268",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="lwm-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=8, d_ff=512, vocab_size=512)
