"""MiniCPM3-4B — dense with MLA [hf:openbmb/MiniCPM3-4B]."""
import dataclasses
from repro.models.common import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", arch_type="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    attention_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    source="hf:openbmb/MiniCPM3-4B",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="minicpm3-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, d_ff=512, vocab_size=512,
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32))
