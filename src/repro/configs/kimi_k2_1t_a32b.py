"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    num_experts=384, top_k_experts=8,
    source="arXiv:2501.kimi2",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="kimi-k2-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, d_ff=128, vocab_size=512, num_experts=4,
        top_k_experts=2)
