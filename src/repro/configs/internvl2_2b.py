"""InternVL2-2B — InternViT (stubbed) + InternLM2 LM backbone
[arXiv:2404.16821]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", arch_type="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    frontend="vit_patch_stub", num_patches=256,
    source="arXiv:2404.16821",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-smoke", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512, num_patches=8)
