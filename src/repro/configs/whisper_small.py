"""Whisper-small — enc-dec audio, conv/mel frontend STUBBED per assignment
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", arch_type="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    is_encoder_decoder=True, encoder_layers=12, encoder_seq_len=1500,
    frontend="audio_conv_stub",
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, encoder_layers=2,
        encoder_seq_len=64)
