"""Qwen2-0.5B — GQA kv=2, QKV bias [arXiv:2407.10671]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", arch_type="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936, qkv_bias=True,
    source="arXiv:2407.10671",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-smoke", num_layers=2, d_model=224, num_heads=7,
        num_kv_heads=1, d_ff=512, vocab_size=512)
