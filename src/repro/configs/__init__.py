"""Architecture config registry.

Every assigned architecture is a module exporting ``CONFIG`` (the exact
assigned full-scale config, source cited) and ``smoke_config()`` (a reduced
variant of the same family: <=2 layers, d_model<=512, <=4 experts) for CPU
smoke tests.  Select with ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

_ARCH_MODULES = {
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "arctic-480b": "repro.configs.arctic_480b",
    "whisper-small": "repro.configs.whisper_small",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "granite-20b": "repro.configs.granite_20b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    # the paper's own evaluation models
    "lwm-7b": "repro.configs.lwm_7b",
    "llama3-8b": "repro.configs.llama3_8b",
}

ASSIGNED_ARCHS: List[str] = list(_ARCH_MODULES)[:10]
ALL_ARCHS: List[str] = list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ALL_ARCHS}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ALL_ARCHS}")
    return importlib.import_module(_ARCH_MODULES[name]).smoke_config()
