"""Jamba v0.1 52B — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", arch_type="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    num_experts=16, top_k_experts=2, moe_layer_period=2,
    attn_layer_period=8, attn_layer_offset=4,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    source="arXiv:2403.19887",
)


def smoke_config() -> ModelConfig:
    # 2 layers: one mamba(+moe), one attention — offset 1 with period 2
    return dataclasses.replace(
        CONFIG, name="jamba-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, d_ff=512, vocab_size=512, num_experts=4,
        top_k_experts=2, moe_layer_period=2, attn_layer_period=2,
        attn_layer_offset=1)
