"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay
[arXiv:2404.05892].  DSA inapplicable (no KV cache) — DESIGN §4."""
import dataclasses
from repro.models.common import DSAConfig, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", arch_type="ssm",
    num_layers=24, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=7168, vocab_size=65536,
    attention_type="none", rwkv_head_dim=64,
    dsa=DSAConfig(enabled=False),
    source="arXiv:2404.05892",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="rwkv6-smoke", num_layers=2, d_model=256, d_ff=512,
        vocab_size=512)
