"""Snowflake Arctic 480B — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", arch_type="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, top_k_experts=2, moe_dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="arctic-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, d_ff=256, vocab_size=512, num_experts=4,
        top_k_experts=2)
