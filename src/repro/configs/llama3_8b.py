"""Llama3-8B-262k (paper's GQA model) [hf:gradientai/Llama-3-8B-Instruct-262k]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", arch_type="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
    source="hf:gradientai/Llama-3-8B-Instruct-262k",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama3-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, d_ff=512, vocab_size=512)
