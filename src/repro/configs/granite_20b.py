"""Granite-20B (code) — llama-arch with MQA (kv=1) [arXiv:2405.04324]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", arch_type="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    source="arXiv:2405.04324",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="granite-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=1, d_ff=512, vocab_size=512)
