"""Common building blocks shared by every architecture.

Everything is pure-functional: parameters are pytrees of jnp arrays, layers
are plain functions ``f(params, x, ...) -> y``.  Layer parameters are stacked
along a leading ``num_layers`` axis so the forward pass can either
``lax.scan`` over layers (train / full prefill) or dynamically index a single
layer (layer-segmented prefill, SparseServe §3.4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DSAConfig:
    """Dynamic-sparse-attention configuration (paper §2.2 / §3)."""
    enabled: bool = True
    block_size: int = 32           # tokens per KV block (paper default)
    token_budget: int = 2048       # selected tokens per step (paper default)
    metadata: str = "cuboid"       # "mean" (InfLLM) | "cuboid" (Quest/ArkVale)
    window: int = 12               # working-set history window (paper Fig. 8)
    sink_blocks: int = 1           # always-selected attention-sink blocks
    recent_blocks: int = 2         # always-selected most-recent blocks

    @property
    def top_k_blocks(self) -> int:
        return max(1, self.token_budget // self.block_size)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64

    @property
    def latent_dim(self) -> int:
        # what is cached per token: compressed KV latent + shared rope key
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- attention flavour ---
    attention_type: str = "gqa"    # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mla: Optional[MLAConfig] = None
    # --- MoE ---
    num_experts: int = 0
    top_k_experts: int = 0
    moe_dense_residual: bool = False   # Arctic: dense FFN in parallel w/ MoE
    moe_layer_period: int = 1          # apply MoE FFN every N layers
    capacity_factor: float = 1.25
    # --- hybrid (Jamba) ---
    attn_layer_period: int = 0         # 1 attention layer per N layers
    attn_layer_offset: int = 4
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # --- rwkv ---
    rwkv_head_dim: int = 64
    # --- encoder-decoder (Whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500        # whisper: 30s @ 50 Hz after conv stride
    # --- modality frontend stub (audio | vlm) ---
    frontend: str = "none"             # none | audio_conv_stub | vit_patch_stub
    num_patches: int = 256             # vlm: patch embeddings per image
    # --- norm / act ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- DSA ---
    dsa: DSAConfig = dataclasses.field(default_factory=DSAConfig)
    # --- citation (source of the config, for the assignment table) ---
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived helpers -------------------------------------------------
    @property
    def kv_cache_dim(self) -> int:
        """Per-token, per-kv-head cached dim (k and v separately, except MLA)."""
        if self.attention_type == "mla":
            assert self.mla is not None
            return self.mla.latent_dim
        return self.head_dim

    def is_attention_layer(self, layer_idx: int) -> bool:
        if self.attention_type == "none":
            return False
        if self.attn_layer_period and self.attn_layer_period > 1:
            return layer_idx % self.attn_layer_period == self.attn_layer_offset
        return True

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.num_experts <= 0:
            return False
        return layer_idx % max(1, self.moe_layer_period) == (
            self.moe_layer_period - 1 if self.moe_layer_period > 1 else 0)

    def num_attention_layers(self) -> int:
        return sum(1 for i in range(self.num_layers) if self.is_attention_layer(i))

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        n = 0
        n += v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        for i in range(self.num_layers):
            if self.is_attention_layer(i):
                if self.attention_type == "mla":
                    m = self.mla
                    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                    n += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
                    n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    n += self.num_heads * m.v_head_dim * d
                else:
                    hd = self.head_dim
                    n += d * self.num_heads * hd          # Wq
                    n += 2 * d * self.num_kv_heads * hd   # Wk, Wv
                    n += self.num_heads * hd * d          # Wo
            elif self.arch_type == "hybrid":              # mamba layer
                di = self.mamba_expand * d
                n += d * 2 * di + di * self.mamba_d_conv
                n += di * (self.mamba_d_state * 2 + 1) + di  # x_proj(B,C,dt) + dt_proj-ish
                n += di * self.mamba_d_state + di             # A, D
                n += di * d                                   # out proj
            elif self.attention_type == "none":           # rwkv time-mix
                n += 5 * d * d + 2 * d * d                # r,k,v,g,o + lora-ish decay
            if self.is_moe_layer(i):
                n += self.num_experts * 3 * d * f         # expert FFNs (swiglu)
                n += d * self.num_experts                 # router
                if self.moe_dense_residual:
                    n += 3 * d * f
            else:
                n += 3 * d * f                            # swiglu FFN
        if self.is_encoder_decoder:
            hd = self.head_dim
            per_enc = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                       + self.num_heads * hd * d + 3 * d * f)
            n += self.encoder_layers * per_enc
            # decoder cross-attn
            n += self.num_layers * (2 * d * self.num_heads * hd
                                    + 2 * d * self.num_kv_heads * hd)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.num_experts <= 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n = self.param_count()
        moe_layers = sum(1 for i in range(self.num_layers) if self.is_moe_layer(i))
        n -= moe_layers * (self.num_experts - self.top_k_experts) * 3 * d * f
        return n


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU FFN: (silu(x W_g) * (x W_u)) W_d."""
    g = jax.nn.silu(x @ w_gate)
    u = x @ w_up
    return (g * u) @ w_down


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    out = jnp.zeros((seq_len, d_model), dtype=jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(angle))
    out = out.at[:, 1::2].set(jnp.cos(angle))
    return out


# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Tuple[int, ...],
               dtype: jnp.dtype = jnp.float32, scale: Optional[float] = None
               ) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))


def stack_layers(layer_params: list) -> Any:
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def take_layer(stacked: Any, idx) -> Any:
    """Dynamically index one layer out of a stacked pytree (traced idx ok)."""
    return jax.tree.map(lambda x: jax.lax.dynamic_index_in_dim(
        x, idx, axis=0, keepdims=False), stacked)


def num_params(tree: Any) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """jax.shard_map with unchecked replication, across jax versions.

    jax >= 0.6 exposes jax.shard_map(check_vma=...); older releases only
    have jax.experimental.shard_map.shard_map(check_rep=...)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)
