"""Unified model assembly for every assigned architecture.

Public surface (all pure functions; params/state are pytrees):

    init_params(cfg, key, dtype)                 -> params
    forward_train(params, cfg, batch)            -> (loss, logits)
    prefill(params, cfg, inputs)                 -> (last_logits, DecodeState)
    prefill_layer(params, cfg, l, hidden, ...)   -> (hidden', layer_kv)   [layer-segmented]
    init_decode_state(cfg, batch, num_blocks)    -> DecodeState
    decode_step(params, cfg, token, state)       -> (logits, DecodeState)

Layer iteration is a Python loop (static unroll): it uniformly supports the
heterogeneous hybrids (Jamba attn/mamba interleave, MoE every other layer)
and gives layer-segmented prefill direct per-layer access.

DecodeState is a dict pytree:
    {"caches": [per-layer cache dict], "cur_len": (B,) int32, "extra": {...}}
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (ModelConfig, dense_init, layer_norm,
                                 rms_norm, sinusoidal_positions, split_keys)


# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------

def layer_kind(cfg: ModelConfig, i: int) -> str:
    """'attn' | 'mamba' | 'rwkv' mixer for layer i."""
    if cfg.attention_type == "none":
        return "rwkv"
    if cfg.arch_type == "hybrid" and not cfg.is_attention_layer(i):
        return "mamba"
    return "attn"


def is_homogeneous(cfg: ModelConfig) -> bool:
    """True when every layer has identical structure — enables the
    scan-over-stacked-layers fast path (one compiled layer body instead of
    num_layers copies; essential for 60+-layer configs)."""
    kinds = {layer_kind(cfg, i) for i in range(cfg.num_layers)}
    moes = {cfg.is_moe_layer(i) for i in range(cfg.num_layers)}
    return len(kinds) == 1 and len(moes) == 1


def layers_stacked(params: Dict) -> bool:
    return isinstance(params["layers"], dict)


def get_layer(params: Dict, i) -> Dict:
    """Layer i's params — list mode or stacked mode (traced i allowed)."""
    layers = params["layers"]
    if isinstance(layers, list):
        return layers[i]
    from repro.models.common import take_layer
    return take_layer(layers, i)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, i: int, key: jax.Array, dtype,
                with_cross: bool = False) -> Dict:
    ks = split_keys(key, 4)
    kind = layer_kind(cfg, i)
    p: Dict[str, Any] = {}  # NOTE: kind is derived from cfg (layer_kind),
    if kind == "rwkv":      # never stored in params (strings break pytrees)
        p["ln1"] = {"w": jnp.ones((cfg.d_model,), jnp.float32),
                    "b": jnp.zeros((cfg.d_model,), jnp.float32)}
        p["ln2"] = {"w": jnp.ones((cfg.d_model,), jnp.float32),
                    "b": jnp.zeros((cfg.d_model,), jnp.float32)}
        p["rwkv"] = rwkv_mod.init_rwkv_params(cfg, ks[0], dtype)
        return p
    p["attn_norm"] = jnp.ones((cfg.d_model,), dtype)
    p["ffn_norm"] = jnp.ones((cfg.d_model,), dtype)
    if kind == "mamba":
        p["mamba"] = mamba_mod.init_mamba_params(cfg, ks[0], dtype)
    elif cfg.attention_type == "mla":
        p["attn"] = attn.init_mla_params(cfg, ks[0], dtype)
    else:
        p["attn"] = attn.init_gqa_params(cfg, ks[0], dtype)
    if with_cross:
        p["cross_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = attn.init_gqa_params(cfg, ks[3], dtype, cross=True)
    if cfg.is_moe_layer(i):
        p["moe"] = ffn_mod.init_moe_params(cfg, ks[1], dtype)
    else:
        p["ffn"] = ffn_mod.init_ffn_params(cfg, ks[1], dtype)
    return p


def _init_whisper_encoder(cfg: ModelConfig, key: jax.Array, dtype) -> Dict:
    ks = split_keys(key, cfg.encoder_layers + 1)
    layers = []
    for i in range(cfg.encoder_layers):
        sub = split_keys(ks[i], 2)
        layers.append({
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "ffn_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.init_gqa_params(cfg, sub[0], dtype),
            "ffn": ffn_mod.init_ffn_params(cfg, sub[1], dtype),
        })
    return {"layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), dtype)}


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=jnp.bfloat16, stacked: Optional[bool] = None) -> Dict:
    """stacked=None -> auto (stack when every layer is identical).
    Stacked layers carry a leading num_layers axis and forward passes scan
    over them; list mode unrolls a Python loop (needed for heterogeneous
    hybrids like Jamba and for per-layer engine access)."""
    if stacked is None:
        stacked = is_homogeneous(cfg)
    ks = split_keys(key, cfg.num_layers + 4)
    layer_list = [
        _init_layer(cfg, i, ks[i + 1], dtype,
                    with_cross=cfg.is_encoder_decoder)
        for i in range(cfg.num_layers)
    ]
    from repro.models.common import stack_layers
    params: Dict[str, Any] = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype,
                            scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": (stack_layers(layer_list) if stacked and is_homogeneous(cfg)
                   else layer_list),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[cfg.num_layers + 1],
                                       (cfg.d_model, cfg.vocab_size), dtype,
                                       scale=0.02)
    if cfg.is_encoder_decoder:
        params["encoder"] = _init_whisper_encoder(
            cfg, ks[cfg.num_layers + 2], dtype)
    return params


# ---------------------------------------------------------------------------
# Layer forward (full sequence): train / prefill
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig, w, x):
    if isinstance(w, dict):          # rwkv / whisper layer-norm
        return layer_norm(x, w["w"], w["b"], cfg.norm_eps)
    return rms_norm(x, w, cfg.norm_eps)


def layer_forward(p: Dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, *, kind: str = "attn",
                  rec_state: Optional[Dict] = None,
                  enc_kv: Optional[Tuple] = None,
                  k_ctx=None, v_ctx=None, q_offset=0,
                  triangular: bool = False,
                  return_kv: bool = False,
                  moe_drop_free: bool = False):
    """One transformer layer over a full sequence.

    Returns (x_out, aux_loss, layer_kv_or_None, new_rec_state_or_None).
    layer_kv: for attn layers (k, v) each (B, S, Hkv, hd) — or (latent,) for
    MLA — used by prefill to populate the paged pool.

    moe_drop_free: serving prefill paths set this so MoE expert capacity
    (a TRAINING throughput knob) cannot drop tokens — capacity scales with
    the tokens in the forward, so a dropped token would make batched /
    chunked / layer-segmented prefill executions diverge from each other
    (the same convention as the decode step's drop-free MoE).
    """
    aux = jnp.zeros((), jnp.float32)
    kv_out = None
    new_rec = None

    if kind == "rwkv":
        h, new_rec = rwkv_mod.rwkv_time_mix(
            p["rwkv"], cfg, _norm(cfg, p["ln1"], x), rec_state)
        x = x + h
        h, new_rec = rwkv_mod.rwkv_channel_mix(
            p["rwkv"], _norm(cfg, p["ln2"], x), new_rec)
        return x + h, aux, None, new_rec

    h_in = _norm(cfg, p["attn_norm"], x)
    if kind == "mamba":
        if rec_state is not None:
            h, new_rec = mamba_mod.mamba_forward(p["mamba"], cfg, h_in,
                                                 rec_state, return_state=True)
        else:
            h = mamba_mod.mamba_forward(p["mamba"], cfg, h_in)
        x = x + h
    elif cfg.attention_type == "mla":
        if return_kv:
            h, latent = attn.mla_self_attention(p["attn"], cfg, h_in,
                                                positions, return_latent=True)
            kv_out = (latent,)
        else:
            h = attn.mla_self_attention(p["attn"], cfg, h_in, positions)
        x = x + h
    else:
        out = attn.gqa_self_attention(p["attn"], cfg, h_in, positions,
                                      k_ctx=k_ctx, v_ctx=v_ctx,
                                      q_offset=q_offset,
                                      triangular=triangular,
                                      return_kv=return_kv)
        if return_kv:
            h, k, v = out
            kv_out = (k, v)
        else:
            h = out
        x = x + h

    x, aux = _layer_epilogue(p, cfg, x, enc_kv, moe_drop_free)
    return x, aux, kv_out, new_rec


def _layer_epilogue(p: Dict, cfg: ModelConfig, x: jax.Array, enc_kv,
                    moe_drop_free: bool):
    """Post-mixer part of a full-sequence layer: cross-attention (whisper)
    + FFN/MoE.  One implementation shared by ``layer_forward`` and the
    sequence-sharded prefill path so their numerics can never drift.
    Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if enc_kv is not None and "cross" in p:
        h = attn.cross_attention(p["cross"], cfg,
                                 _norm(cfg, p["cross_norm"], x), *enc_kv)
        x = x + h
    h_in = _norm(cfg, p["ffn_norm"], x)
    if "moe" in p:
        h, aux = ffn_mod.moe_apply(p["moe"], cfg, h_in,
                                   drop_free=moe_drop_free)
    else:
        h = ffn_mod.ffn_apply(p["ffn"], h_in)
    return x + h, aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params: Dict, cfg: ModelConfig, inputs: Dict
                 ) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B,S,d), positions (B,S))."""
    tokens = inputs["tokens"]
    B = tokens.shape[0]
    h = params["embed"][tokens]
    if cfg.frontend == "vit_patch_stub":
        patches = inputs["patch_embeds"].astype(h.dtype)       # (B, P, d)
        h = jnp.concatenate([patches, h], axis=1)
    S = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return h, positions


def lm_head(params: Dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = _norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


def whisper_encode(params: Dict, cfg: ModelConfig, frames: jax.Array
                   ) -> jax.Array:
    """frames: (B, T_enc, d) stub embeddings (conv/mel frontend is stubbed
    per assignment). Bidirectional encoder."""
    B, T, d = frames.shape
    h = frames + sinusoidal_positions(T, d).astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    enc = params["encoder"]
    for p in enc["layers"]:
        a = attn.gqa_self_attention(p["attn"], cfg,
                                    rms_norm(h, p["attn_norm"], cfg.norm_eps),
                                    positions, causal=False)
        h = h + a
        f = ffn_mod.ffn_apply(p["ffn"],
                              rms_norm(h, p["ffn_norm"], cfg.norm_eps))
        h = h + f
    return rms_norm(h, enc["final_norm"], cfg.norm_eps)


def project_encoder_kv(params: Dict, cfg: ModelConfig, enc_out: jax.Array):
    """List mode: [(k, v)] per layer.  Stacked mode: (k, v) with leading L."""
    if layers_stacked(params):
        return jax.vmap(lambda pc: attn.project_enc_kv(pc, cfg, enc_out))(
            params["layers"]["cross"])
    return [attn.project_enc_kv(p["cross"], cfg, enc_out)
            for p in params["layers"]]


# ---------------------------------------------------------------------------
# Train forward
# ---------------------------------------------------------------------------

def _fresh_rec_state(cfg: ModelConfig, kind: str, batch: int, dtype):
    if kind == "mamba":
        return mamba_mod.init_mamba_state(cfg, batch, dtype)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_state(cfg, batch, dtype)
    return None


def _stack_enc_kvs(enc_kvs):
    """[(k,v)] * L -> (k (L,B,S,H,hd), v (L,B,S,H,hd))."""
    return (jnp.stack([k for k, _ in enc_kvs], axis=0),
            jnp.stack([v for _, v in enc_kvs], axis=0))


def _layers_scan_train(params: Dict, cfg: ModelConfig, h: jax.Array,
                       positions: jax.Array, enc_kvs, *,
                       remat: bool, triangular: bool):
    """Homogeneous-layer fast path: ONE compiled layer body via lax.scan."""
    kind = layer_kind(cfg, 0)
    B = h.shape[0]

    def body(carry, xs):
        h_, aux_ = carry
        p = xs["p"]
        enc = (xs["enc_k"], xs["enc_v"]) if "enc_k" in xs else None
        rec = _fresh_rec_state(cfg, kind, B, h_.dtype)
        h2, a, _, _ = layer_forward(p, cfg, h_, positions, kind=kind,
                                    rec_state=rec, enc_kv=enc,
                                    triangular=triangular)
        return (h2, aux_ + a), None

    if remat:
        body = jax.checkpoint(body)
    xs: Dict[str, Any] = {"p": params["layers"]}
    if enc_kvs is not None:
        xs["enc_k"], xs["enc_v"] = _stack_enc_kvs(enc_kvs) \
            if isinstance(enc_kvs, list) else enc_kvs
    (h, aux_total), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                     xs)
    return h, aux_total


def forward_train(params: Dict, cfg: ModelConfig, batch: Dict,
                  *, triangular: bool = False,
                  remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """batch: {"tokens": (B,S), "labels": (B,S) [, "frames"/"patch_embeds"]}
    Returns (loss, logits)."""
    h, positions = embed_inputs(params, cfg, batch)
    B, S, _ = h.shape

    enc_kvs = None
    if cfg.is_encoder_decoder:
        enc_out = whisper_encode(params, cfg, batch["frames"])
        enc_kvs = project_encoder_kv(params, cfg, enc_out)

    if layers_stacked(params):
        h, aux_total = _layers_scan_train(params, cfg, h, positions, enc_kvs,
                                          remat=remat, triangular=triangular)
    else:
        aux_total = jnp.zeros((), jnp.float32)
        rec_states = _init_rec_states(cfg, B, h.dtype)
        for i in range(cfg.num_layers):
            p = get_layer(params, i)
            kind = layer_kind(cfg, i)
            def run(h_, rs, p=p, kind=kind, i=i):
                return layer_forward(p, cfg, h_, positions, kind=kind,
                                     rec_state=rs,
                                     enc_kv=enc_kvs[i] if enc_kvs else None,
                                     triangular=triangular)
            if remat:
                run = jax.checkpoint(run)
            h, aux, _, new_rec = run(h, rec_states[i])
            aux_total = aux_total + aux
            rec_states[i] = new_rec

    logits = lm_head(params, cfg, h)
    labels = batch["labels"]
    if cfg.frontend == "vit_patch_stub":                      # logits cover patches too
        logits_txt = logits[:, -labels.shape[1]:, :]
    else:
        logits_txt = logits
    loss = cross_entropy(logits_txt, labels)
    return loss + 0.01 * aux_total, logits_txt


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def _init_rec_states(cfg: ModelConfig, batch: int, dtype) -> List:
    states = []
    for i in range(cfg.num_layers):
        kind = layer_kind(cfg, i)
        if kind == "mamba":
            states.append(mamba_mod.init_mamba_state(cfg, batch, dtype))
        elif kind == "rwkv":
            states.append(rwkv_mod.init_rwkv_state(cfg, batch, dtype))
        else:
            states.append(None)
    return states


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, num_blocks: int,
                      dtype=jnp.bfloat16, enc_kvs=None,
                      stacked: Optional[bool] = None) -> Dict:
    """stacked=None -> auto (stacked when layers are homogeneous).
    Stacked caches are ONE pytree with leading num_layers axis (scan path);
    list caches are per-layer (engine / heterogeneous path)."""
    if stacked is None:
        stacked = is_homogeneous(cfg)
    if stacked and is_homogeneous(cfg):
        kind = layer_kind(cfg, 0)
        if kind == "attn":
            one = attn.init_layer_kv_pool(cfg, batch, num_blocks, dtype)
        elif kind == "mamba":
            one = mamba_mod.init_mamba_state(cfg, batch, dtype)
        else:
            one = rwkv_mod.init_rwkv_state(cfg, batch, dtype)
        caches: Any = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one)
    else:
        caches = []
        for i in range(cfg.num_layers):
            kind = layer_kind(cfg, i)
            if kind == "attn":
                caches.append(attn.init_layer_kv_pool(cfg, batch, num_blocks,
                                                      dtype))
            elif kind == "mamba":
                caches.append(mamba_mod.init_mamba_state(cfg, batch, dtype))
            else:
                caches.append(rwkv_mod.init_rwkv_state(cfg, batch, dtype))
    state = {"caches": caches,
             "cur_len": jnp.zeros((batch,), jnp.int32),
             "extra": {}}
    if enc_kvs is not None:
        state["extra"]["enc_kvs"] = enc_kvs
    return state


# ---------------------------------------------------------------------------
# Prefill (plain, full prompt) — fills the paged pools
# ---------------------------------------------------------------------------

def _kv_to_pool(cfg: ModelConfig, k: jax.Array, num_blocks: int, pool_dtype):
    """(B, S, Hkv, D) -> (B, Hkv, NB, bs, D), zero-padded."""
    from repro.core import dsa as dsa_mod
    B, S, Hkv, D = k.shape
    bs = cfg.dsa.block_size
    pad = num_blocks * bs - S
    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pool = jnp.transpose(k.reshape(B, num_blocks, bs, Hkv, D), (0, 3, 1, 2, 4))
    valid = (jnp.arange(num_blocks * bs) < S).reshape(num_blocks, bs)
    valid = jnp.broadcast_to(valid, (B, Hkv, num_blocks, bs))
    meta = dsa_mod.build_block_metadata(pool, cfg.dsa.metadata, valid)
    return pool.astype(pool_dtype), meta


def _prefill_layer_caches(cfg: ModelConfig, kv_out, new_rec, num_blocks: int,
                          cache_dtype):
    if kv_out is None:
        return new_rec
    if cfg.attention_type == "mla":
        (latent,) = kv_out
        kpool, meta = _kv_to_pool(cfg, latent[:, :, None, :], num_blocks,
                                  cache_dtype)
        return {"k": kpool, "meta": meta}
    k, v = kv_out
    kpool, meta = _kv_to_pool(cfg, k, num_blocks, cache_dtype)
    vpool, _ = _kv_to_pool(cfg, v, num_blocks, cache_dtype)
    return {"k": kpool, "v": vpool, "meta": meta}


def _layers_scan_prefill(params: Dict, cfg: ModelConfig, h: jax.Array,
                         positions: jax.Array, enc_kvs, num_blocks: int,
                         cache_dtype, triangular: bool):
    kind = layer_kind(cfg, 0)
    B = h.shape[0]

    def body(h_, xs):
        p = xs["p"]
        enc = (xs["enc_k"], xs["enc_v"]) if "enc_k" in xs else None
        rec = _fresh_rec_state(cfg, kind, B, h_.dtype)
        h2, _, kv_out, new_rec = layer_forward(
            p, cfg, h_, positions, kind=kind, rec_state=rec, enc_kv=enc,
            triangular=triangular, return_kv=True)
        return h2, _prefill_layer_caches(cfg, kv_out, new_rec, num_blocks,
                                         cache_dtype)

    xs: Dict[str, Any] = {"p": params["layers"]}
    if enc_kvs is not None:
        xs["enc_k"], xs["enc_v"] = _stack_enc_kvs(enc_kvs) \
            if isinstance(enc_kvs, list) else enc_kvs
    h, caches = jax.lax.scan(body, h, xs)
    return h, caches


def prefill(params: Dict, cfg: ModelConfig, inputs: Dict, num_blocks: int,
            *, cache_dtype=jnp.bfloat16, triangular: bool = False
            ) -> Tuple[jax.Array, Dict]:
    """Plain prefill: full forward, return last-token logits + DecodeState.

    Stacked params -> scan path -> STACKED caches; list params -> per-layer
    cache list.  decode_step accepts both."""
    h, positions = embed_inputs(params, cfg, inputs)
    B, S, _ = h.shape
    enc_kvs = None
    if cfg.is_encoder_decoder:
        enc_out = whisper_encode(params, cfg, inputs["frames"])
        enc_kvs = project_encoder_kv(params, cfg, enc_out)

    if layers_stacked(params):
        h, caches = _layers_scan_prefill(params, cfg, h, positions, enc_kvs,
                                         num_blocks, cache_dtype, triangular)
    else:
        rec_states = _init_rec_states(cfg, B, h.dtype)
        caches = []
        for i in range(cfg.num_layers):
            p = get_layer(params, i)
            h, _, kv_out, new_rec = layer_forward(
                p, cfg, h, positions, kind=layer_kind(cfg, i),
                rec_state=rec_states[i],
                enc_kv=enc_kvs[i] if enc_kvs else None,
                triangular=triangular, return_kv=True)
            caches.append(_prefill_layer_caches(cfg, kv_out, new_rec,
                                                num_blocks, cache_dtype))

    logits = lm_head(params, cfg, h[:, -1:, :])[:, 0]
    state = {"caches": caches,
             "cur_len": jnp.full((B,), S, jnp.int32),
             "extra": ({"enc_kvs": enc_kvs} if enc_kvs else {})}
    return logits, state


# ---------------------------------------------------------------------------
# Layer-segmented prefill (SparseServe §3.4)
# ---------------------------------------------------------------------------

def prefill_embed(params: Dict, cfg: ModelConfig, inputs: Dict):
    """Segment 0 of layer-segmented prefill: embedding (+ encoder for A/V)."""
    h, positions = embed_inputs(params, cfg, inputs)
    enc_kvs = None
    if cfg.is_encoder_decoder:
        enc_out = whisper_encode(params, cfg, inputs["frames"])
        enc_kvs = project_encoder_kv(params, cfg, enc_out)
    return h, positions, enc_kvs


def index_enc_kvs(enc_kvs, i: int):
    """Layer i's (k, v) cross-attn cache — list or stacked form."""
    if enc_kvs is None:
        return None
    if isinstance(enc_kvs, list):
        return enc_kvs[i]
    return (enc_kvs[0][i], enc_kvs[1][i])


def prefill_layer(params: Dict, cfg: ModelConfig, layer_idx: int,
                  h: jax.Array, positions: jax.Array, *,
                  rec_state=None, enc_kv=None, triangular: bool = False,
                  moe_drop_free: bool = False):
    """Run ONE layer of prefill over the whole prompt (layer-segmented
    prefill).  The caller saves the returned per-layer KV to DRAM and evicts
    it before calling layer l+1 — bounding HBM to one layer of KV."""
    p = get_layer(params, layer_idx)
    h, _, kv_out, new_rec = layer_forward(p, cfg, h, positions,
                                          kind=layer_kind(cfg, layer_idx),
                                          rec_state=rec_state, enc_kv=enc_kv,
                                          triangular=triangular,
                                          return_kv=True,
                                          moe_drop_free=moe_drop_free)
    return h, kv_out, new_rec


def prefill_finalize(params: Dict, cfg: ModelConfig, h: jax.Array):
    """Last segment: final norm + head on the last position."""
    return lm_head(params, cfg, h[:, -1:, :])[:, 0]


# ---------------------------------------------------------------------------
# Batched layer-segmented prefill (the PrefillPlane's stage functions)
#
# The prefill plane (``repro.core.prefill_plane``) batches the SAME-layer
# segments of many requests into one jitted launch over right-padded rows.
# These functions are the masked layer bodies it jits: ``token_mask`` marks
# each row's real tokens (right padding), ``step_mask`` parks rows whose
# request is not scheduled (their hidden / recurrent state comes back
# byte-for-byte unchanged, like the decode plane's step_mask).  Exactness
# under padding:
#
# * attention — causal masking alone protects real tokens (padding sits
#   strictly AFTER every real position, so no real query ever attends to a
#   padded key); masked lanes PRESERVE their incoming residual, so right
#   padding stays at its admitted zeros and real tokens of later chunks
#   that fall inside a bucketed window keep their layer-input values;
# * recurrent (mamba/rwkv) — the masked forwards carry the recurrent state
#   THROUGH padded steps unchanged and gather shift/conv windows from each
#   row's last valid position, so the carried state equals an unpadded
#   run's (see ``mamba_forward(token_mask=...)`` / ``rwkv_time_mix``);
# * MoE — runs drop-free (expert capacity must not couple batched rows).
# ---------------------------------------------------------------------------

def prefill_attn_layer_batched(p: Dict, cfg: ModelConfig, h: jax.Array,
                               positions: jax.Array, token_mask: jax.Array,
                               step_mask: jax.Array, *,
                               k_ctx=None, v_ctx=None, q_offset=0,
                               enc_kv=None, plane_mesh=None):
    """One ATTENTION layer over a padded batch of same-layer segments.

    h: (B, T, d) — the rows' residual stream over this segment's token
    window; positions: (B, T) absolute positions; k_ctx/v_ctx: earlier
    chunks of the SAME layer (chunked layer segments; None for chunk 0);
    q_offset: the window's absolute start (scalar; traced, so distinct
    chunk starts share one compile per shape).

    plane_mesh: sequence-shard the window across the mesh's model axis
    (``_prefill_attn_layer_batched_cp``); MLA layers run replicated (no
    latent-context path to shard — same restriction as chunked segments).

    Returns (h_out, kv_out): h_out masked (masked lanes preserve the
    incoming residual, parked rows return unchanged); kv_out = (k, v) each
    (B, T, Hkv, hd) — or (latent,) (B, T, lat) for MLA — valid where
    token_mask is set.
    """
    if plane_mesh is not None and cfg.attention_type != "mla":
        return _prefill_attn_layer_batched_cp(
            p, cfg, h, positions, token_mask, step_mask, k_ctx=k_ctx,
            v_ctx=v_ctx, q_offset=q_offset, enc_kv=enc_kv, pm=plane_mesh)
    x, _, kv_out, _ = layer_forward(p, cfg, h, positions, kind="attn",
                                    enc_kv=enc_kv, k_ctx=k_ctx, v_ctx=v_ctx,
                                    q_offset=q_offset, return_kv=True,
                                    moe_drop_free=True)
    # masked lanes PRESERVE the incoming residual: right padding stays at
    # its admitted zeros, real tokens of LATER chunks inside the bucketed
    # window keep their layer-input values for their own chunk's launch,
    # and parked rows (step_mask False => token_mask all-False) come back
    # byte-for-byte unchanged
    x = jnp.where(token_mask[..., None] & step_mask[:, None, None], x, h)
    return x, kv_out


def _prefill_attn_layer_batched_cp(p: Dict, cfg: ModelConfig, h: jax.Array,
                                   positions: jax.Array,
                                   token_mask: jax.Array,
                                   step_mask: jax.Array, *,
                                   k_ctx, v_ctx, q_offset, enc_kv, pm):
    """Sequence-sharded GQA prefill layer (context-parallel prefill).

    Only the quadratic part is sharded: the window's QUERIES split across
    ``pm.model_axis`` and each shard runs blocked attention of its query
    slice against the full window K/V; the out-spec reassembles the
    attention outputs.  Projections and the layer epilogue (residual, Wo,
    cross-attn, FFN/MoE) run replicated at the SAME shapes as the
    single-device path, and every value handed onward is pinned back to
    replicated sharding (``pm.replicate``) — both deliberately, for
    exactness: per-shard matmul row counts and leaked out-spec shardings
    each perturb numerics (a leaked sequence sharding would GSPMD-partition
    a later mamba scan), which breaks the token-identical oracle bar.
    Windows that do not divide the axis are padded with causally-invisible
    tail tokens (key index > every real query position) and trimmed after.
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.common import shard_map_compat
    m = pm.model_axis
    n = pm.model_size
    B, T, _ = h.shape
    pad = (-T) % n
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0))) if pad else h
    pos_p = (jnp.pad(positions, ((0, 0), (0, pad)), mode="edge")
             if pad else positions)
    T_loc = (T + pad) // n
    scale = 1.0 / (cfg.head_dim ** 0.5)

    # replicated projections — bitwise-identical to the single-device path
    h_in = _norm(cfg, p["attn_norm"], hp)
    q, k, v = attn.gqa_project_qkv(p["attn"], cfg, h_in, pos_p)
    k_all, v_all = k, v
    if k_ctx is not None:
        k_all = jnp.concatenate([k_ctx, k_all], axis=1)
        v_all = jnp.concatenate([v_ctx, v_all], axis=1)

    def body(q_l, k_, v_, qo_):
        qo_loc = qo_ + jax.lax.axis_index(m) * T_loc
        return attn.flash_attention_jnp(q_l, k_, v_, scale=scale,
                                        causal=True, q_offset=qo_loc)

    seq4 = P(None, m, None, None)
    rep4 = P(None, None, None, None)
    fn = shard_map_compat(body, mesh=pm.mesh,
                          in_specs=(seq4, rep4, rep4, P()),
                          out_specs=seq4)
    o = pm.replicate(fn(q, k_all, v_all, jnp.asarray(q_offset, jnp.int32)))
    x = hp + o.reshape(B, T + pad, -1) @ p["attn"]["wo"]
    if pad:
        x, k, v = x[:, :T], k[:, :T], v[:, :T]
    # replicated epilogue on the full window — the SAME implementation
    # layer_forward runs, so the paths cannot drift (see docstring)
    x, _ = _layer_epilogue(p, cfg, x, enc_kv, moe_drop_free=True)
    # same lane-preserving mask as the replicated path
    x = jnp.where(token_mask[..., None] & step_mask[:, None, None], x, h)
    return pm.replicate((x, (k, v)))


def prefill_recurrent_layer_batched(p: Dict, cfg: ModelConfig, kind: str,
                                    h: jax.Array, token_mask: jax.Array,
                                    step_mask: jax.Array, rec_state):
    """One mamba/rwkv layer over a padded batch of same-layer segments.
    Returns (h_out, new_rec_state), both masked: parked rows' hidden AND
    recurrent state come back unchanged."""
    if kind == "rwkv":
        x = h
        out, st = rwkv_mod.rwkv_time_mix(p["rwkv"], cfg,
                                         _norm(cfg, p["ln1"], x), rec_state,
                                         token_mask=token_mask)
        x = x + out
        out, st = rwkv_mod.rwkv_channel_mix(p["rwkv"],
                                            _norm(cfg, p["ln2"], x), st,
                                            token_mask=token_mask)
        x = x + out
    else:
        h_in = _norm(cfg, p["attn_norm"], h)
        out, st = mamba_mod.mamba_forward(p["mamba"], cfg, h_in, rec_state,
                                          return_state=True,
                                          token_mask=token_mask)
        x = h + out
        h_in = _norm(cfg, p["ffn_norm"], x)
        if "moe" in p:
            f, _ = ffn_mod.moe_apply(p["moe"], cfg, h_in, drop_free=True)
        else:
            f = ffn_mod.ffn_apply(p["ffn"], h_in)
        x = x + f
    # same lane-preserving mask as the attention stage (see above)
    x = jnp.where(token_mask[..., None] & step_mask[:, None, None], x, h)
    st = _mask_state(st, rec_state, step_mask)
    return x, st


def prefill_logits_batched(params: Dict, cfg: ModelConfig, h: jax.Array,
                           tok_len: jax.Array) -> jax.Array:
    """Finalize stage of the prefill plane: gather each row's LAST REAL
    hidden state (h: (B, S_cap, d), tok_len: (B,)) and run the lm head.
    Returns (B, V); only finishing rows' logits are meaningful."""
    idx = jnp.maximum(tok_len - 1, 0)
    h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    return lm_head(params, cfg, h_last)[:, 0]


# ---------------------------------------------------------------------------
# Batched multi-request decode: padded-batch stack / unstack
# ---------------------------------------------------------------------------

def is_pool_cache(c: Any) -> bool:
    """True for an attn-layer paged-pool cache ({k[,v],meta})."""
    return isinstance(c, dict) and "k" in c and "meta" in c


def stack_decode_states(states: List[Dict]) -> Tuple[Dict, List[Tuple[int, List[Optional[int]]]]]:
    """Stack per-request DecodeStates into ONE padded batch state.

    The serving engine holds one DecodeState per request, with per-layer KV
    pools whose block counts differ (prompt + generation budgets differ).
    Batched decode pads every attn-layer pool along the block axis to the
    batch maximum and concatenates along batch; recurrent-layer states (and
    ``extra`` pytrees such as whisper enc_kvs) concatenate directly, so
    requests whose extra shapes differ must be grouped by the caller.

    Requires list-mode caches (the engine's representation).  Returns
    (batched_state, layout) where layout records each input's (batch_size,
    per-layer num_blocks) for ``unstack_decode_states``.
    """
    if not states:
        raise ValueError("stack_decode_states: empty batch")
    L = len(states[0]["caches"])
    layout: List[Tuple[int, List[Optional[int]]]] = []
    for s in states:
        if not isinstance(s["caches"], list):
            raise ValueError("stack_decode_states requires list-mode caches "
                             "(per-layer), not stacked scan caches")
        nbs = [s["caches"][l]["k"].shape[2] if is_pool_cache(s["caches"][l])
               else None for l in range(L)]
        layout.append((int(s["cur_len"].shape[0]), nbs))

    caches: List[Any] = []
    for l in range(L):
        parts = [s["caches"][l] for s in states]
        if is_pool_cache(parts[0]):
            nb_max = max(p["k"].shape[2] for p in parts)
            parts = [attn.pad_pool_cache(p, nb_max) for p in parts]
            caches.append({key: jnp.concatenate([p[key] for p in parts],
                                                axis=0)
                           for key in parts[0]})
        else:
            caches.append(jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *parts))
    batched = {
        "caches": caches,
        "cur_len": jnp.concatenate([s["cur_len"] for s in states], axis=0),
        "extra": (jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                               *[s["extra"] for s in states])
                  if states[0]["extra"] else {}),
    }
    return batched, layout


def unstack_decode_states(state: Dict,
                          layout: List[Tuple[int, List[Optional[int]]]]
                          ) -> List[Dict]:
    """Split a batched DecodeState back into per-request states, trimming
    each attn-layer pool to the request's own block count."""
    out: List[Dict] = []
    row = 0
    for B, nbs in layout:
        sl = slice(row, row + B)
        caches: List[Any] = []
        for l, c in enumerate(state["caches"]):
            if is_pool_cache(c):
                caches.append(attn.slice_pool_cache(
                    {key: arr[sl] for key, arr in c.items()}, nbs[l]))
            else:
                caches.append(jax.tree.map(lambda x: x[sl], c))
        out.append({
            "caches": caches,
            "cur_len": state["cur_len"][sl],
            "extra": (jax.tree.map(lambda x: x[sl], state["extra"])
                      if state["extra"] else {}),
        })
        row += B
    return out


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _mask_state(new, old, step_mask: jax.Array):
    """Per-leaf select: keep `old` wherever step_mask is False (row axis 0)."""
    def sel(n, o):
        m = step_mask.reshape((step_mask.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def _decode_epilogue(p: Dict, cfg: ModelConfig, x: jax.Array, enc_kv):
    """Post-mixer part of a decode layer: cross-attention (whisper) + FFN.
    Shared by the fused (``_decode_layer``) and staged
    (``decode_attend_layer``) paths so their numerics agree."""
    if enc_kv is not None and "cross" in p:
        h = attn.cross_decode_step(p["cross"], cfg,
                                   _norm(cfg, p["cross_norm"], x), *enc_kv)
        x = x + h
    h_in = _norm(cfg, p["ffn_norm"], x)
    if "moe" in p:
        # drop_free: expert capacity must not couple the requests of a
        # batched decode step (keeps batched == per-request decode)
        h, _ = ffn_mod.moe_apply(p["moe"], cfg, h_in[:, None, :],
                                 drop_free=True)
        h = h[:, 0]
    else:
        h = ffn_mod.ffn_apply(p["ffn"], h_in)
    return x + h


def _decode_layer(p: Dict, cfg: ModelConfig, kind: str, x: jax.Array,
                  cache, cur_len: jax.Array, enc_kv, attn_impl: str,
                  step_mask: Optional[jax.Array] = None,
                  plane_mesh=None):
    """One decode layer.  Returns (x, new_cache, sel_or_None).

    step_mask (B,) bool: rows where False must leave `cache` unchanged —
    paged pools use masked scatter at the source (``attn._append_masked``),
    recurrent states are reverted leaf-wise.
    plane_mesh: context-parallel mesh for the attention mixer (the FUSED
    shard_map path; the staged plane shards via decode_select/attend_layer
    instead); recurrent mixers always run replicated."""
    sel = None
    if kind == "rwkv":
        old = cache
        h, cache = rwkv_mod.rwkv_time_mix_step(
            p["rwkv"], cfg, _norm(cfg, p["ln1"], x), cache)
        x = x + h
        h, cache = rwkv_mod.rwkv_channel_mix_step(
            p["rwkv"], _norm(cfg, p["ln2"], x), cache)
        if step_mask is not None:
            cache = _mask_state(cache, old, step_mask)
        return x + h, cache, sel
    h_in = _norm(cfg, p["attn_norm"], x)
    if kind == "mamba":
        old = cache
        h, cache = mamba_mod.mamba_decode_step(p["mamba"], cfg, h_in, cache)
        if step_mask is not None:
            cache = _mask_state(cache, old, step_mask)
    elif cfg.attention_type == "mla":
        h, cache, sel = attn.mla_decode_step(p["attn"], cfg, h_in, cache,
                                             cur_len, attn_impl=attn_impl,
                                             plane_mesh=plane_mesh,
                                             step_mask=step_mask)
    else:
        h, cache, sel = attn.gqa_decode_step(p["attn"], cfg, h_in, cache,
                                             cur_len, attn_impl=attn_impl,
                                             plane_mesh=plane_mesh,
                                             step_mask=step_mask)
    x = x + h
    return _decode_epilogue(p, cfg, x, enc_kv), cache, sel


# ---------------------------------------------------------------------------
# Staged per-layer decode (select -> [host restore] -> attend)
#
# The staged decode plane (``repro.core.device_pool.step_staged``) runs ONE
# layer at a time so the serving engine can stage HBM-miss restores between
# a layer's DSA selection and its attention: select emits the selections
# (and appends the layer's new KV), the host lands the fused FlashH2D
# payloads in the device pool, attend then reads the restored blocks — which
# is what makes block-granular device eviction oracle-exact.  All functions
# here take the LAYER's params (``get_layer``), not the full model, so one
# jit trace serves every structurally identical layer.
# ---------------------------------------------------------------------------

def decode_embed(params: Dict, cfg: ModelConfig, tokens: jax.Array
                 ) -> jax.Array:
    """Stage 0: token embedding.  tokens (B,) -> x (B, d)."""
    return params["embed"][tokens]


def decode_select_layer(p: Dict, cfg: ModelConfig, x: jax.Array, cache,
                        cur_len: jax.Array,
                        step_mask: Optional[jax.Array] = None,
                        plane_mesh=None):
    """Select stage of one ATTENTION layer: pre-norm, project, append the
    new token's KV to the paged pool, update DSA metadata, score + top-k.
    Returns (q, new_cache, idx, valid) — idx/valid None when DSA is off.
    plane_mesh: shard the pool-touching core across the mesh
    (``attention.gqa/mla_select_step_cp``); idx/valid stay GLOBAL ids."""
    h_in = _norm(cfg, p["attn_norm"], x)
    if plane_mesh is not None:
        if cfg.attention_type == "mla":
            return attn.mla_select_step_cp(p["attn"], cfg, h_in, cache,
                                           cur_len, plane_mesh,
                                           step_mask=step_mask)
        return attn.gqa_select_step_cp(p["attn"], cfg, h_in, cache, cur_len,
                                       plane_mesh, step_mask=step_mask)
    if cfg.attention_type == "mla":
        return attn.mla_select_step(p["attn"], cfg, h_in, cache, cur_len,
                                    step_mask=step_mask)
    return attn.gqa_select_step(p["attn"], cfg, h_in, cache, cur_len,
                                step_mask=step_mask)


def decode_attend_layer(p: Dict, cfg: ModelConfig, x: jax.Array,
                        q: jax.Array, cache, cur_len: jax.Array,
                        idx, valid, enc_kv=None,
                        attn_impl: str = "ref", plane_mesh=None) -> jax.Array:
    """Compute stage of one ATTENTION layer: block-sparse attention over the
    (possibly restored) pool + residual + cross-attn + FFN.  Reads ``cache``
    but never writes it — the host may have scattered restore payloads into
    it after the select stage.  plane_mesh: run the attention core sharded
    (``attention.gqa/mla_attend_step_cp``); epilogue stays replicated."""
    if plane_mesh is not None:
        if cfg.attention_type == "mla":
            h = attn.mla_attend_step_cp(p["attn"], cfg, q, cache, cur_len,
                                        idx, valid, plane_mesh)
        else:
            h = attn.gqa_attend_step_cp(p["attn"], cfg, q, cache, cur_len,
                                        idx, valid, plane_mesh)
    elif cfg.attention_type == "mla":
        h = attn.mla_attend_step(p["attn"], cfg, q, cache, cur_len, idx,
                                 valid, attn_impl=attn_impl)
    else:
        h = attn.gqa_attend_step(p["attn"], cfg, q, cache, cur_len, idx,
                                 valid, attn_impl=attn_impl)
    return _decode_epilogue(p, cfg, x + h, enc_kv)


def decode_recurrent_layer(p: Dict, cfg: ModelConfig, kind: str,
                           x: jax.Array, cache,
                           step_mask: Optional[jax.Array] = None):
    """One mamba/rwkv layer as a single stage (no selection, no restore —
    recurrent layers hold no paged KV).  Returns (x, new_cache)."""
    dummy_len = jnp.zeros((x.shape[0],), jnp.int32)   # unused by recurrents
    x, cache, _ = _decode_layer(p, cfg, kind, x, cache, dummy_len,
                                None, "ref", step_mask=step_mask)
    return x, cache


def decode_logits(params: Dict, cfg: ModelConfig, x: jax.Array,
                  cur_len: jax.Array,
                  step_mask: Optional[jax.Array] = None):
    """Final stage: lm head + cur_len advance (masked rows stay parked).
    Returns (logits (B, V), new_cur_len (B,))."""
    logits = lm_head(params, cfg, x[:, None, :])[:, 0]
    new_len = (cur_len + 1 if step_mask is None
               else cur_len + step_mask.astype(jnp.int32))
    return logits, new_len


def _decode_scan(params: Dict, cfg: ModelConfig, x: jax.Array, state: Dict,
                 attn_impl: str, plane_mesh=None):
    """Scan path over stacked layers + stacked caches."""
    kind = layer_kind(cfg, 0)
    cur_len = state["cur_len"]
    enc_kvs = state["extra"].get("enc_kvs")

    def body(x_, xs):
        enc = (xs["enc_k"], xs["enc_v"]) if "enc_k" in xs else None
        x2, new_cache, sel = _decode_layer(xs["p"], cfg, kind, x_,
                                           xs["cache"], cur_len, enc,
                                           attn_impl,
                                           plane_mesh=plane_mesh)
        ys = {"cache": new_cache}
        if sel is not None:
            ys["sel"] = sel
        return x2, ys

    xs: Dict[str, Any] = {"p": params["layers"], "cache": state["caches"]}
    if enc_kvs is not None:
        xs["enc_k"], xs["enc_v"] = enc_kvs
    x, ys = jax.lax.scan(body, x, xs)
    sel_stacked = ys.get("sel")
    return x, ys["cache"], sel_stacked


def decode_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                state: Dict, *, attn_impl: str = "ref",
                return_info: bool = False,
                step_mask: Optional[jax.Array] = None,
                plane_mesh=None):
    """tokens: (B,) int32 — one new token per request.

    With return_info=True also returns {"selected": {layer: (B,Hkv,K)}} —
    the DSA block selections the serving engine feeds to the LRU cache and
    the working-set estimator.  Stacked caches take the scan fast path.

    step_mask: optional (B,) bool.  Rows where False are "parked": their
    caches (paged pools, metadata, recurrent states) and cur_len come back
    byte-for-byte unchanged, while the forward still runs at the full padded
    batch shape.  This is what lets the persistent device plane
    (``repro.core.device_pool``) jit ONE bucketed batch shape and step an
    arbitrary subset of resident requests per iteration.  Only supported
    with list-mode caches (the serving engine's representation).

    plane_mesh: ``launch.plane_mesh.PlaneMesh`` — fused context-parallel
    decode over block-sharded pools (what ``launch/dryrun.py`` lowers;
    formerly the ``attention.CP_AXES`` module global)."""
    B = tokens.shape[0]
    cur_len = state["cur_len"]
    x = params["embed"][tokens]                              # (B, d)
    enc_kvs = state["extra"].get("enc_kvs")

    info: Dict[str, Any] = {"selected": {}}
    if isinstance(state["caches"], dict):                    # stacked/scan
        if step_mask is not None:
            raise ValueError("step_mask requires list-mode caches")
        x, new_caches, sel_stacked = _decode_scan(params, cfg, x, state,
                                                  attn_impl,
                                                  plane_mesh=plane_mesh)
        if sel_stacked is not None and return_info:
            for i in range(cfg.num_layers):
                info["selected"][i] = sel_stacked[i]
    else:
        new_caches = []
        for i in range(cfg.num_layers):
            p = get_layer(params, i)
            kind = layer_kind(cfg, i)
            x, cache, sel = _decode_layer(
                p, cfg, kind, x, state["caches"][i], cur_len,
                index_enc_kvs(enc_kvs, i), attn_impl, step_mask=step_mask,
                plane_mesh=plane_mesh)
            if sel is not None:
                info["selected"][i] = sel
            new_caches.append(cache)

    logits = lm_head(params, cfg, x[:, None, :])[:, 0]
    new_len = (cur_len + 1 if step_mask is None
               else cur_len + step_mask.astype(jnp.int32))
    new_state = {"caches": new_caches, "cur_len": new_len,
                 "extra": state["extra"]}
    if return_info:
        return logits, new_state, info
    return logits, new_state
