"""Mamba (selective SSM) block — Jamba's recurrent layer [arXiv:2403.19887].

Train/prefill run a ``lax.scan`` over the sequence; decode carries an O(1)
recurrent state (conv window + SSM state), which is why hybrid/SSM archs run
``long_500k`` natively (DESIGN §4).  No KV cache -> the paper's DSA machinery
does not apply to these layers; the working-set estimator counts them as 0.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys


def _dims(cfg: ModelConfig):
    di = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, dt_rank, cfg.mamba_d_state, cfg.mamba_d_conv


def init_mamba_params(cfg: ModelConfig, key: jax.Array, dtype) -> Dict:
    d = cfg.d_model
    di, dt_rank, ds, dc = _dims(cfg)
    ks = split_keys(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (di, dc), dtype, scale=1.0 / math.sqrt(dc)),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * ds), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, di), dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 left: jax.Array = None) -> jax.Array:
    """Depthwise causal conv1d.  x: (B, S, di); w: (di, dc).

    left: optional (B, dc-1, di) context — the last dc-1 inputs of the
    PRECEDING chunk (chunked layer-segmented prefill continues a layer
    mid-sequence).  Zeros (the default) reproduce a sequence start."""
    B, S, di = x.shape
    dc = w.shape[1]
    xp = (jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0))) if left is None
          else jnp.concatenate([left.astype(x.dtype), x], axis=1))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(dc):
        out = out + xp[:, j:j + S, :].astype(jnp.float32) * w[:, j]
    return (out + b).astype(x.dtype)


def _ssm_scan(xc: jax.Array, dt: jax.Array, B_ssm: jax.Array, C_ssm: jax.Array,
              A: jax.Array, D: jax.Array, h0: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Selective scan.  xc/dt: (B,S,di); B_ssm/C_ssm: (B,S,ds); A: (di,ds).
    h0: (B, di, ds).  Returns (y (B,S,di), h_final)."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * A)                    # (B,di,ds)
        dBx = dt_t[..., None] * b_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y = jnp.sum(h * c_t[:, None, :], axis=-1) + D * x_t  # (B,di)
        return h, y

    xs = (jnp.swapaxes(xc, 0, 1).astype(jnp.float32),
          jnp.swapaxes(dt, 0, 1).astype(jnp.float32),
          jnp.swapaxes(B_ssm, 0, 1).astype(jnp.float32),
          jnp.swapaxes(C_ssm, 0, 1).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.swapaxes(ys, 0, 1), h


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, _, ds, dc = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, ds), jnp.float32),
    }


def _project(p: Dict, cfg: ModelConfig, xc: jax.Array):
    di, dt_rank, ds, _ = _dims(cfg)
    xdb = xc @ p["x_proj"]
    dt = jax.nn.softplus(xdb[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    B_ssm = xdb[..., dt_rank:dt_rank + ds]
    C_ssm = xdb[..., dt_rank + ds:]
    return dt, B_ssm, C_ssm


def mamba_forward(p: Dict, cfg: ModelConfig, x: jax.Array,
                  state: Dict = None, return_state: bool = False,
                  token_mask: jax.Array = None):
    """x: (B, S, d) -> (B, S, d).  Full-sequence (train / prefill).

    state: optional recurrent carry.  ``state["ssm"]`` seeds the selective
    scan and ``state["conv"]`` is the causal-conv left context, so a layer
    can be continued mid-sequence (chunked layer-segmented prefill); a
    zero-initialised state reproduces a sequence start exactly.

    token_mask: optional (B, S) bool for right-padded batched prefill.
    Masked positions contribute NOTHING to the recurrence (their dt is
    zeroed, so dA = exp(0) = 1 carries the SSM state through unchanged) and
    the returned conv window is gathered from the last valid inputs per
    row — the returned state equals the state of an unpadded run.  Masked
    positions' outputs are garbage; callers mask them out."""
    di, dt_rank, ds, dc = _dims(cfg)
    B, S, d = x.shape
    xz = x @ p["in_proj"]
    x_in, z = xz[..., :di], xz[..., di:]
    left = state["conv"] if state is not None else None
    xc = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"], left=left))
    dt, B_ssm, C_ssm = _project(p, cfg, xc)
    if token_mask is not None:
        dt = dt * token_mask[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"])
    h0 = (state["ssm"] if state is not None
          else jnp.zeros((B, di, ds), jnp.float32))
    y, h = _ssm_scan(xc, dt, B_ssm, C_ssm, A, p["D"], h0)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        # conv window = last dc-1 VALID inputs, with the carried left
        # context covering rows whose valid span is shorter than dc-1
        full = jnp.concatenate(
            [left.astype(x_in.dtype) if left is not None
             else jnp.zeros((B, dc - 1, di), x_in.dtype), x_in], axis=1)
        if token_mask is None:
            new_conv = full[:, S:, :]
        else:
            n_valid = jnp.sum(token_mask.astype(jnp.int32), axis=1)  # (B,)
            idx = n_valid[:, None] + jnp.arange(dc - 1)[None, :]
            new_conv = jnp.take_along_axis(full, idx[..., None], axis=1)
        new_state = {"conv": new_conv, "ssm": h}
        return out, new_state
    return out


def mamba_decode_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict
                      ) -> Tuple[jax.Array, Dict]:
    """One token.  x: (B, d)."""
    di, dt_rank, ds, dc = _dims(cfg)
    B, d = x.shape
    xz = x @ p["in_proj"]
    x_in, z = xz[..., :di], xz[..., di:]
    # conv over cached window + current token
    window = jnp.concatenate([state["conv"], x_in[:, None, :]], axis=1)  # (B,dc,di)
    xc = jnp.sum(window.astype(jnp.float32)
                 * jnp.swapaxes(p["conv_w"], 0, 1)[None], axis=1) + p["conv_b"]
    xc = jax.nn.silu(xc.astype(x.dtype))                     # (B, di)
    dt, B_ssm, C_ssm = _project(p, cfg, xc)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)
    h = dA * state["ssm"] + (dt[..., None] * B_ssm[:, None, :]
                             * xc[..., None]).astype(jnp.float32)
    y = jnp.sum(h * C_ssm[:, None, :].astype(jnp.float32), axis=-1) + p["D"] * xc
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv": window[:, 1:, :], "ssm": h}
