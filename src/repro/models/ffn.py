"""FFN modules: dense SwiGLU and Mixture-of-Experts.

MoE uses top-k routing with capacity-bounded scatter dispatch:
instead of the classic GShard one-hot *einsum* dispatch (whose FLOPs grow
O(T^2)), tokens are scattered into per-expert capacity slots with
``.at[slot].add`` — O(T·k·d) memory traffic and zero matmul FLOPs.  The
one-hot rank cumsum (O(T·E) int ops) is the remaining overhead; a sort-based
variant is provided as a §Perf alternative (``impl="sort"``).

Expert weights carry a leading E axis — sharded over the ``model`` mesh axis
(expert parallelism); XLA inserts the all-to-all at the dispatch/combine
boundaries.

Arctic-style ``moe_dense_residual`` runs a dense FFN in parallel and sums.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys, swiglu

# Expert-parallel execution axes, set by the launcher (e.g. (("data",),
# "model") or (("pod", "data"), "model")).  None -> dense GSPMD path.
# The dense path is the paper-faithful baseline; GSPMD replicates its
# scatter-dispatch einsums on every device (measured: per-device MoE flops
# == GLOBAL flops).  The shard_map expert-parallel path is the §Perf
# optimization: tokens stay on their data shard, experts live on their
# model shard, and the combine is ONE psum over `model` — per-device flops
# drop to global/(data*model).
EP_AXES: Optional[Tuple[Tuple[str, ...], str]] = None
EP_MESH = None           # jax Mesh for shard_map (set with EP_AXES)
EP_IMPL = "onehot"       # dispatch-rank impl: "onehot" | "sort"


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def init_ffn_params(cfg: ModelConfig, key: jax.Array, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), dtype),
        "w_up": dense_init(ks[1], (d, f), dtype),
        "w_down": dense_init(ks[2], (f, d), dtype),
    }


def ffn_apply(p: Dict, x: jax.Array) -> jax.Array:
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe_params(cfg: ModelConfig, key: jax.Array, dtype) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), dtype),
        "w_up": dense_init(ks[2], (E, d, f), dtype),
        "w_down": dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.moe_dense_residual:
        p["dense"] = init_ffn_params(cfg, ks[4], dtype)
    return p


def _dispatch_ranks_onehot(expert_flat: jax.Array, E: int) -> jax.Array:
    """rank of each (token,slot) within its expert via one-hot cumsum."""
    oh = jax.nn.one_hot(expert_flat, E, dtype=jnp.int32)      # (Tk, E)
    ranks = jnp.cumsum(oh, axis=0) - 1                        # (Tk, E)
    return jnp.take_along_axis(ranks, expert_flat[:, None], axis=1)[:, 0]


def _dispatch_ranks_sort(expert_flat: jax.Array, E: int) -> jax.Array:
    """O(Tk log Tk) sort-based ranks — §Perf alternative to one-hot cumsum."""
    Tk = expert_flat.shape[0]
    order = jnp.argsort(expert_flat, stable=True)             # tokens grouped by expert
    sorted_e = expert_flat[order]
    # position within the expert group = idx - first idx of the group
    idx = jnp.arange(Tk, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_e[1:] != sorted_e[:-1]])
    group_start = jnp.maximum.accumulate(jnp.where(is_start, idx, 0))
    rank_sorted = idx - group_start
    ranks = jnp.zeros((Tk,), jnp.int32).at[order].set(rank_sorted)
    return ranks


def moe_apply(p: Dict, cfg: ModelConfig, x: jax.Array, *,
              impl: str = "onehot",
              drop_free: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar).

    drop_free: capacity covers every (token, slot) assignment — used by the
    decode path, where capacity is a prefill throughput knob and must not
    couple requests in a batched decode step (a dropped token would make
    batched decode diverge from per-request decode)."""
    if EP_AXES is not None:
        return moe_apply_ep(p, cfg, x, dp_axes=EP_AXES[0],
                            model_axis=EP_AXES[1], mesh=EP_MESH,
                            drop_free=drop_free)
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k_experts
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"])           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32),
                  axis=0)
    aux = E * jnp.sum(me * ce)

    cap = int(cfg.capacity_factor * T * k / E) + 1
    cap = max(4, -(-cap // 4) * 4)                            # round up to 4
    if drop_free:
        cap = max(cap, T * k)                 # worst case: all to one expert

    ef = expert_idx.reshape(T * k).astype(jnp.int32)
    if impl == "sort":
        ranks = _dispatch_ranks_sort(ef, E)
    else:
        ranks = _dispatch_ranks_onehot(ef, E)
    ok = ranks < cap
    slot = jnp.where(ok, ef * cap + ranks, E * cap)           # overflow row
    xin = jnp.zeros((E * cap + 1, d), x.dtype)
    xin = xin.at[slot].add(jnp.repeat(xf, k, axis=0))
    xin = xin[:-1].reshape(E, cap, d)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xin, p["w_up"])
    yout = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])     # (E, cap, d)

    yflat = jnp.concatenate(
        [yout.reshape(E * cap, d), jnp.zeros((1, d), yout.dtype)], axis=0)
    gathered = yflat[slot].reshape(T, k, d)
    out = jnp.sum(gathered * gate_vals[..., None].astype(x.dtype), axis=1)
    out = out.reshape(B, S, d)

    if cfg.moe_dense_residual:
        out = out + ffn_apply(p["dense"], x)
    return out, aux


# ---------------------------------------------------------------------------
# Expert-parallel MoE (shard_map) — §Perf optimization
# ---------------------------------------------------------------------------

def _moe_local(p: Dict, cfg: ModelConfig, x: jax.Array, model_axis: str,
               dp_axes: Tuple[str, ...] = ("data",), impl: str = "onehot",
               drop_free: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Per-shard body: x (B_loc, S, d) replicated over `model`; expert
    weights hold E_loc local experts.  Computes the local experts'
    contribution to every local token; caller psums over `model`."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k_experts
    E_loc = p["w_gate"].shape[0]                 # E / num model shards
    shard = jax.lax.axis_index(model_axis)
    first = shard * E_loc

    T = B * S
    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ p["router"]            # router replicated
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k) identical
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # on every shard

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32),
                  axis=0)
    aux = E * jnp.sum(me * ce)
    aux = jax.lax.pmean(aux, dp_axes)        # replicate across data shards

    # keep only (token, slot) pairs routed to LOCAL experts
    ef = expert_idx.reshape(T * k).astype(jnp.int32)
    local = (ef >= first) & (ef < first + E_loc)
    ef_loc = jnp.where(local, ef - first, E_loc)             # E_loc = drop row

    cap = int(cfg.capacity_factor * T * k / E) + 1
    cap = max(4, -(-cap // 4) * 4)
    if drop_free:
        cap = max(cap, T * k)
    rank_fn = (_dispatch_ranks_sort if impl == "sort"
               else _dispatch_ranks_onehot)
    ranks = rank_fn(jnp.where(local, ef_loc, E_loc), E_loc + 1)
    ok = local & (ranks < cap)
    slot = jnp.where(ok, ef_loc * cap + ranks, E_loc * cap)
    xin = jnp.zeros((E_loc * cap + 1, d), x.dtype)
    xin = xin.at[slot].add(jnp.repeat(xf, k, axis=0))
    xin = xin[:-1].reshape(E_loc, cap, d)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xin, p["w_up"])
    yout = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])

    yflat = jnp.concatenate(
        [yout.reshape(E_loc * cap, d), jnp.zeros((1, d), yout.dtype)], axis=0)
    gathered = yflat[slot].reshape(T, k, d)
    y = jnp.sum(gathered * gate_vals[..., None].astype(x.dtype), axis=1)

    # local experts contributed their share; sum shares across shards
    y = jax.lax.psum(y, model_axis)
    if cfg.moe_dense_residual:
        # dense residual weights are model-sharded column-wise is NOT set up
        # here: the dense FFN stays outside (replicated weights per shard)
        y = y + ffn_apply(p["dense"], xf)
    return y.reshape(B, S, d), aux


def moe_apply_ep(p: Dict, cfg: ModelConfig, x: jax.Array, *,
                 dp_axes: Tuple[str, ...] = ("data",),
                 model_axis: str = "model", mesh=None,
                 drop_free: bool = False) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert-parallel MoE: batch over `dp_axes`, experts over
    `model_axis`; ONE psum over `model` as the combine collective."""
    from jax.sharding import PartitionSpec as P
    from repro.models.common import shard_map_compat

    # drop batch sharding when B doesn't divide the dp axes (e.g. batch=1
    # long-context decode — experts still parallel over `model`)
    n_dp = 1
    for a in dp_axes:
        n_dp *= dict(mesh.shape)[a] if mesh is not None else 1
    if n_dp > 1 and x.shape[0] % n_dp == 0:
        dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    else:
        dp = None
    x_spec = P(dp, None, None)
    w_spec = {"router": P(None, None),
              "w_gate": P(model_axis, None, None),
              "w_up": P(model_axis, None, None),
              "w_down": P(model_axis, None, None)}
    if "dense" in p:
        w_spec["dense"] = {"w_gate": P(None, None), "w_up": P(None, None),
                           "w_down": P(None, None)}

    fn = shard_map_compat(
        lambda pp, xx: _moe_local(pp, cfg, xx, model_axis, dp_axes, EP_IMPL,
                                  drop_free),
        mesh=mesh,
        in_specs=(w_spec, x_spec),
        out_specs=(x_spec, P()))
    return fn(p, x)
