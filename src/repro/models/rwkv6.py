"""RWKV-6 "Finch" block — attention-free, data-dependent decay [arXiv:2404.05892].

Time-mix with per-channel data-dependent decay ``w_t`` (low-rank MLP on the
token-shifted input) and a per-head matrix state ``S ∈ R^{hd×hd}``:

    y_t   = (S_t + (u ⊙ k_t) v_tᵀ)ᵀ r_t
    S_t+1 = diag(w_t) S_t + k_t v_tᵀ

Decode carries ``S`` plus the single-token shift — O(1) state, no KV cache,
hence DSA is *inapplicable* (DESIGN §4) and ``long_500k`` runs natively.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys


def _dims(cfg: ModelConfig):
    hd = cfg.rwkv_head_dim
    H = cfg.d_model // hd
    return H, hd


def init_rwkv_params(cfg: ModelConfig, key: jax.Array, dtype) -> Dict:
    d = cfg.d_model
    H, hd = _dims(cfg)
    lora = max(32, d // 32)
    ks = split_keys(key, 12)
    return {
        # time-mix
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_g": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "w_r": dense_init(ks[0], (d, d), dtype),
        "w_k": dense_init(ks[1], (d, d), dtype),
        "w_v": dense_init(ks[2], (d, d), dtype),
        "w_g": dense_init(ks[3], (d, d), dtype),
        "w_o": dense_init(ks[4], (d, d), dtype),
        # data-dependent decay lora: w = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((d,), -2.0, jnp.float32),
        "decay_A": dense_init(ks[5], (d, lora), dtype),
        "decay_B": dense_init(ks[6], (lora, d), dtype, scale=0.01),
        "bonus_u": dense_init(ks[7], (H, hd), jnp.float32, scale=0.1),
        "ln_x_w": jnp.ones((d,), jnp.float32),
        "ln_x_b": jnp.zeros((d,), jnp.float32),
        # channel-mix
        "cmu_r": jnp.full((d,), 0.5, dtype), "cmu_k": jnp.full((d,), 0.5, dtype),
        "cw_r": dense_init(ks[8], (d, d), dtype),
        "cw_k": dense_init(ks[9], (d, cfg.d_ff), dtype),
        "cw_v": dense_init(ks[10], (cfg.d_ff, d), dtype),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    H, hd = _dims(cfg)
    d = cfg.d_model
    return {
        "shift_t": jnp.zeros((batch, d), dtype),   # time-mix token shift
        "shift_c": jnp.zeros((batch, d), dtype),   # channel-mix token shift
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


def _group_norm(x: jax.Array, H: int, w, b, eps=1e-5) -> jax.Array:
    """Per-head groupnorm on (B, d) with d = H*hd."""
    B, d = x.shape
    xh = x.reshape(B, H, d // H).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, d) * w + b).astype(x.dtype)


def _time_mix_projections(p: Dict, x: jax.Array, xx: jax.Array):
    """x, xx (prev token): (..., d) -> r,k,v,g,w."""
    def mix(mu):
        return x + (xx - x) * mu
    r = mix(p["mu_r"]) @ p["w_r"]
    k = mix(p["mu_k"]) @ p["w_k"]
    v = mix(p["mu_v"]) @ p["w_v"]
    g = jax.nn.silu(mix(p["mu_g"]) @ p["w_g"])
    xw = mix(p["mu_w"])
    w = jnp.exp(-jnp.exp(p["decay_w0"]
                         + (jnp.tanh(xw @ p["decay_A"]) @ p["decay_B"]
                            ).astype(jnp.float32)))
    return r, k, v, g, w


def _wkv_step(S, r, k, v, w, u, H, hd):
    """S: (B,H,hd,hd); r,k,v: (B,H,hd); w: (B,H,hd); u: (H,hd)."""
    kv = k[..., :, None] * v[..., None, :]                    # (B,H,hd,hd)
    y = jnp.einsum("bhij,bhi->bhj", S + u[None, :, :, None] * kv, r)
    S_new = w[..., :, None] * S + kv
    return S_new, y


def _last_valid(x: jax.Array, token_mask: jax.Array) -> jax.Array:
    """Per-row gather of x at the last valid position.  x: (B, S, d);
    token_mask: (B, S) bool (right-padded).  Returns (B, d)."""
    n_valid = jnp.sum(token_mask.astype(jnp.int32), axis=1)
    idx = jnp.maximum(n_valid - 1, 0)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def rwkv_time_mix(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict,
                  token_mask: jax.Array = None) -> Tuple[jax.Array, Dict]:
    """Full-sequence time-mix.  x: (B, S, d).

    token_mask: optional (B, S) bool for right-padded batched prefill —
    masked positions write nothing into the wkv state (their k is zeroed
    and their decay forced to 1, so ``S`` passes through unchanged) and the
    token-shift state is gathered from the last VALID position per row, so
    the returned state equals an unpadded run's.  Masked positions' outputs
    are garbage; callers mask them out."""
    H, hd = _dims(cfg)
    B, S, d = x.shape
    xx = jnp.concatenate([state["shift_t"][:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, g, w = _time_mix_projections(p, x, xx)
    rh = r.reshape(B, S, H, hd).astype(jnp.float32)
    kh = k.reshape(B, S, H, hd).astype(jnp.float32)
    vh = v.reshape(B, S, H, hd).astype(jnp.float32)
    wh = w.reshape(B, S, H, hd)
    if token_mask is not None:
        tm = token_mask[:, :, None, None]
        kh = kh * tm.astype(kh.dtype)
        wh = jnp.where(tm, wh, 1.0)

    def step(Scur, inp):
        r_t, k_t, v_t, w_t = inp
        S_new, y = _wkv_step(Scur, r_t, k_t, v_t, w_t, p["bonus_u"], H, hd)
        return S_new, y

    xs = tuple(jnp.swapaxes(a, 0, 1) for a in (rh, kh, vh, wh))
    S_fin, ys = jax.lax.scan(step, state["S"], xs)
    y = jnp.swapaxes(ys, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = jax.vmap(lambda yt: _group_norm(yt, H, p["ln_x_w"], p["ln_x_b"]),
                 in_axes=1, out_axes=1)(y)
    out = (y * g) @ p["w_o"]
    shift = (x[:, -1, :] if token_mask is None else _last_valid(x, token_mask))
    new_state = dict(state, shift_t=shift, S=S_fin)
    return out, new_state


def rwkv_channel_mix(p: Dict, x: jax.Array, state: Dict,
                     token_mask: jax.Array = None) -> Tuple[jax.Array, Dict]:
    xx = jnp.concatenate([state["shift_c"][:, None, :], x[:, :-1, :]], axis=1)
    xr = x + (xx - x) * p["cmu_r"]
    xk = x + (xx - x) * p["cmu_k"]
    r = jax.nn.sigmoid(xr @ p["cw_r"])
    k = jnp.square(jax.nn.relu(xk @ p["cw_k"]))
    out = r * (k @ p["cw_v"])
    shift = (x[:, -1, :] if token_mask is None else _last_valid(x, token_mask))
    return out, dict(state, shift_c=shift)


def rwkv_time_mix_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict
                       ) -> Tuple[jax.Array, Dict]:
    """One-token decode.  x: (B, d)."""
    H, hd = _dims(cfg)
    B, d = x.shape
    r, k, v, g, w = _time_mix_projections(p, x, state["shift_t"])
    S_new, y = _wkv_step(state["S"],
                         r.reshape(B, H, hd).astype(jnp.float32),
                         k.reshape(B, H, hd).astype(jnp.float32),
                         v.reshape(B, H, hd).astype(jnp.float32),
                         w.reshape(B, H, hd), p["bonus_u"], H, hd)
    y = _group_norm(y.reshape(B, d).astype(x.dtype), H,
                    p["ln_x_w"], p["ln_x_b"])
    out = (y * g) @ p["w_o"]
    return out, dict(state, shift_t=x, S=S_new)


def rwkv_channel_mix_step(p: Dict, x: jax.Array, state: Dict
                          ) -> Tuple[jax.Array, Dict]:
    xx = state["shift_c"]
    xr = x + (xx - x) * p["cmu_r"]
    xk = x + (xx - x) * p["cmu_k"]
    r = jax.nn.sigmoid(xr @ p["cw_r"])
    k = jnp.square(jax.nn.relu(xk @ p["cw_k"]))
    out = r * (k @ p["cw_v"])
    return out, dict(state, shift_c=x)
