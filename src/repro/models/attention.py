"""Attention modules: GQA (MHA/MQA as special cases) and MLA.

Three execution paths per flavour:

* ``*_train``   — full causal self-attention over a sequence (training and
  plain/layer-segmented prefill).  Uses memory-bounded blocked ("flash
  style") attention in pure jnp; the Pallas ``flash_prefill`` kernel mirrors
  the inner loop for TPU.
* ``*_decode_step`` — one new token against the paged KV pool with DSA
  block selection (SparseServe decode path).
* ``cross_attention`` — Whisper decoder cross-attention over cached encoder
  keys/values.

KV pool layout is the paper's head-major (H, N, D): ``(B, Hkv, NB, bs, D)``
so per-head block selection touches contiguous memory (§3.2, Fig. 5).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dsa
from repro.models.common import (DSAConfig, ModelConfig, apply_rope, dense_init, rms_norm, shard_map_compat, split_keys)

NEG_INF = -1e30

# Cost-calibration mode (roofline/calibrate.py): forces single-trip scans in
# blocked attention so XLA's cost analysis (which counts while-loop bodies
# ONCE, not x trip-count) reports exact FLOPs.  Never used on real runs.
EXACT_COST_MODE = False

# Context-parallel decode (shard_map) — §Perf optimization.  Baseline GSPMD
# all-gathers the block-sharded KV pool for the DSA gather (GBs per step);
# the CP paths keep pool data on its shard: in BLOCK mode only the (small)
# block SCORES are all-gathered, the global top-k is computed redundantly
# per shard, each shard attends over its LOCAL selected blocks, and
# partials merge with a logsumexp psum; in HEAD mode (staged plane,
# Hkv % n == 0) even that is unnecessary — selection and attention are
# per-kv-head-local and only the selected ids / per-head outputs cross the
# mesh.  The mesh arrives as an explicit ``launch.plane_mesh.PlaneMesh``
# threaded through every entry point (None -> single-device path); the
# former ``CP_AXES`` module global is gone.


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_gqa_params(cfg: ModelConfig, key: jax.Array, dtype,
                    cross: bool = False) -> Dict[str, jax.Array]:
    d, Hq, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, Hq * hd), dtype),
        "wk": dense_init(ks[1], (d, Hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, Hkv * hd), dtype),
        "wo": dense_init(ks[3], (Hq * hd, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((Hq * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    return p


def init_mla_params(cfg: ModelConfig, key: jax.Array, dtype) -> Dict[str, jax.Array]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = split_keys(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank,
                                   H * (m.qk_nope_head_dim + m.qk_rope_head_dim)), dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_kr": dense_init(ks[3], (d, m.qk_rope_head_dim), dtype),
        "w_uk": dense_init(ks[4], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "w_uv": dense_init(ks[5], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "wo": dense_init(ks[6], (H * m.v_head_dim, d), dtype),
    }


# ---------------------------------------------------------------------------
# Blocked ("flash-style") causal attention — memory bounded, pure jnp
# ---------------------------------------------------------------------------

def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: float, causal: bool = True, q_offset=0,
                        q_chunk: int = 512, k_chunk: int = 512,
                        triangular: bool = False) -> jax.Array:
    """Online-softmax blocked attention.

    q: (B, Sq, Hq, D);  k/v: (B, Sk, Hkv, Dk/Dv).  GQA via head grouping.
    q_offset: absolute position of q[0] (chunked prefill continuation).
    triangular: skip fully-masked key chunks (halves causal FLOPs;
        §Perf optimization — unrolls the q-chunk loop in Python).
    Returns (B, Sq, Hq, Dv).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dk = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    if EXACT_COST_MODE:          # single-trip scans -> exact XLA flop count
        q_chunk, k_chunk = Sq, Sk
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    # pad to multiples
    pq = (-Sq) % q_chunk
    pk = (-Sk) % k_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = (Sq + pq) // q_chunk
    nk = (Sk + pk) // k_chunk
    qr = q.reshape(B, nq, q_chunk, Hkv, G, D)
    kr = k.reshape(B, nk, k_chunk, Hkv, Dk)
    vr = v.reshape(B, nk, k_chunk, Hkv, Dv)
    kpos = jnp.arange(Sk + pk).reshape(nk, k_chunk)
    k_valid = (kpos < Sk)

    def one_q_chunk(iq, q_i, n_kv):
        # q_i: (B, q_chunk, Hkv, G, D)
        qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def body(carry, j):
            m, l, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(kr, j, axis=1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vr, j, axis=1, keepdims=False)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            kp = j * k_chunk + jnp.arange(k_chunk)
            mask = k_valid[j][None, :] if not causal else (
                (qpos[:, None] >= kp[None, :]) & k_valid[j][None, :])
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      jnp.arange(n_kv, dtype=jnp.int32))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        # (B,Hkv,G,q_chunk,Dv) -> (B, q_chunk, Hkv, G, Dv)
        return jnp.transpose(o, (0, 3, 1, 2, 4))

    if triangular and causal:
        # python loop: static per-chunk kv bound -> no masked-out compute
        outs = []
        for iq in range(nq):
            q_i = qr[:, iq]
            hi = min(nk, (q_offset + (iq + 1) * q_chunk + k_chunk - 1) // k_chunk)
            outs.append(one_q_chunk(iq, q_i, max(hi, 1)))
        o = jnp.stack(outs, axis=1)
    else:
        o = jax.vmap(lambda iq, q_i: one_q_chunk(iq, q_i, nk),
                     in_axes=(0, 1), out_axes=1)(
            jnp.arange(nq, dtype=jnp.int32), qr)
    o = o.reshape(B, nq * q_chunk, Hq, Dv)[:, :Sq]
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA: train / prefill path
# ---------------------------------------------------------------------------

def gqa_project_qkv(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array):
    """x: (B, S, d) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with RoPE applied."""
    B, S, _ = x.shape
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_self_attention(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                       positions: jax.Array, *,
                       k_ctx: Optional[jax.Array] = None,
                       v_ctx: Optional[jax.Array] = None,
                       causal: bool = True, q_offset=0,
                       triangular: bool = False,
                       return_kv: bool = False):
    """Full (train / prefill) self-attention.  Optional dense context
    ``k_ctx/v_ctx`` (B, S_past, Hkv, hd) supports chunked prefill."""
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    if k_ctx is not None:
        k_all = jnp.concatenate([k_ctx, k], axis=1)
        v_all = jnp.concatenate([v_ctx, v], axis=1)
    else:
        k_all, v_all = k, v
    scale = 1.0 / (cfg.head_dim ** 0.5)
    o = flash_attention_jnp(q, k_all, v_all, scale=scale, causal=causal,
                            q_offset=q_offset, triangular=triangular)
    B, S = x.shape[:2]
    out = o.reshape(B, S, -1) @ p["wo"]
    if return_kv:
        return out, k, v
    return out


def cross_attention(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                    k_enc: jax.Array, v_enc: jax.Array):
    """Whisper decoder cross-attention; k_enc/v_enc: (B, S_enc, Hkv, hd)
    (already projected + cached once per request)."""
    B, S, _ = x.shape
    Hq, hd = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, Hq, hd)
    scale = 1.0 / (hd ** 0.5)
    o = flash_attention_jnp(q, k_enc, v_enc, scale=scale, causal=False)
    return o.reshape(B, S, -1) @ p["wo"]


def project_enc_kv(p: Dict[str, jax.Array], cfg: ModelConfig, enc: jax.Array):
    B, S, _ = enc.shape
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (enc @ p["wv"]).reshape(B, S, Hkv, hd)
    return k, v


# ---------------------------------------------------------------------------
# Paged KV pool (decode)
# ---------------------------------------------------------------------------

def init_layer_kv_pool(cfg: ModelConfig, batch: int, num_blocks: int,
                       dtype) -> Dict[str, jax.Array]:
    """Per-layer paged pool + DSA metadata (zeros; filled by prefill/decode)."""
    bs = cfg.dsa.block_size
    if cfg.attention_type == "mla":
        m = cfg.mla
        lat = m.latent_dim
        return {
            # latent cache acts as a single-kv-head pool; k==v==latent
            "k": jnp.zeros((batch, 1, num_blocks, bs, lat), dtype),
            "meta": jnp.zeros(dsa.metadata_shape(cfg.dsa, num_blocks, lat,
                                                 (batch, 1)), jnp.float32),
        }
    hd = cfg.head_dim
    Hkv = cfg.num_kv_heads
    return {
        "k": jnp.zeros((batch, Hkv, num_blocks, bs, hd), dtype),
        "v": jnp.zeros((batch, Hkv, num_blocks, bs, hd), dtype),
        "meta": jnp.zeros(dsa.metadata_shape(cfg.dsa, num_blocks, hd,
                                             (batch, Hkv)), jnp.float32),
    }


def pad_pool_cache(cache: Dict[str, jax.Array], num_blocks: int
                   ) -> Dict[str, jax.Array]:
    """Zero-pad an attn-layer pool cache ({k[,v],meta}) along the block axis
    (axis 2 for every component) to `num_blocks` — the padded-batch
    abstraction batched decode uses to stack requests with heterogeneous
    pool sizes.  Padded blocks sit beyond every request's ``cur_len`` so DSA
    selection masks them out (select_blocks' n_valid bound)."""
    nb = cache["k"].shape[2]
    if nb == num_blocks:
        return cache
    if nb > num_blocks:
        raise ValueError(f"cannot pad pool of {nb} blocks down to "
                         f"{num_blocks}")
    pad = num_blocks - nb
    return {
        key: jnp.pad(arr, ((0, 0), (0, 0), (0, pad))
                     + ((0, 0),) * (arr.ndim - 3))
        for key, arr in cache.items()
    }


def slice_pool_cache(cache: Dict[str, jax.Array], num_blocks: int
                     ) -> Dict[str, jax.Array]:
    """Inverse of ``pad_pool_cache``: trim the block axis back to the
    request's own pool size after a batched decode step."""
    if cache["k"].shape[2] == num_blocks:
        return cache
    return {key: arr[:, :, :num_blocks] for key, arr in cache.items()}


def _append_to_pool(pool: jax.Array, new: jax.Array, cur_len: jax.Array,
                    block_size: int) -> jax.Array:
    """pool: (B, H, NB, bs, D); new: (B, H, D); cur_len: (B,)."""
    B, H = new.shape[0], new.shape[1]
    blk = cur_len // block_size
    slot = cur_len % block_size
    bidx = jnp.arange(B)[:, None]
    hidx = jnp.arange(H)[None, :]
    return pool.at[bidx, hidx, blk[:, None], slot[:, None]].set(
        new.astype(pool.dtype))


def _update_meta(meta: jax.Array, new_k: jax.Array, cur_len: jax.Array,
                 dsa_cfg: DSAConfig) -> jax.Array:
    """Incrementally update block metadata for the block receiving new_k.

    meta mean:   (B,H,NB,D);  cuboid: (B,H,NB,2,D).  new_k: (B,H,D)."""
    B, H, _ = new_k.shape
    bs = dsa_cfg.block_size
    blk = cur_len // bs
    slot = cur_len % bs
    bidx = jnp.arange(B)[:, None]
    hidx = jnp.arange(H)[None, :]
    kf = new_k.astype(jnp.float32)
    if dsa_cfg.metadata == "mean":
        old = meta[bidx, hidx, blk[:, None]]              # (B,H,D)
        cnt = slot[:, None, None].astype(jnp.float32)
        new_mean = (old * cnt + kf) / (cnt + 1.0)
        return meta.at[bidx, hidx, blk[:, None]].set(new_mean)
    old = meta[bidx, hidx, blk[:, None]]                  # (B,H,2,D)
    fresh = slot[:, None, None] == 0                      # new block starts
    old_mn = jnp.where(fresh, jnp.inf, old[..., 0, :])
    old_mx = jnp.where(fresh, -jnp.inf, old[..., 1, :])
    mn = jnp.minimum(old_mn, kf)
    mx = jnp.maximum(old_mx, kf)
    return meta.at[bidx, hidx, blk[:, None]].set(jnp.stack([mn, mx], axis=-2))


# ---------------------------------------------------------------------------
# Context-parallel decode attention (shard_map over the pool's block axis)
# ---------------------------------------------------------------------------

def _append_masked(pool, new, lblk, slot, mine):
    """Scatter `new` (B,H,D) into pool at (lblk, slot) only where mine (B,)."""
    B, H = new.shape[0], new.shape[1]
    bidx = jnp.arange(B)[:, None]
    hidx = jnp.arange(H)[None, :]
    old = pool[bidx, hidx, lblk[:, None], slot[:, None]]         # (B,H,D)
    val = jnp.where(mine[:, None, None], new.astype(pool.dtype), old)
    return pool.at[bidx, hidx, lblk[:, None], slot[:, None]].set(val)


def _update_meta_masked(meta, new_k, lblk, slot, mine, dsa_cfg):
    B, H, _ = new_k.shape
    bidx = jnp.arange(B)[:, None]
    hidx = jnp.arange(H)[None, :]
    kf = new_k.astype(jnp.float32)
    old = meta[bidx, hidx, lblk[:, None]]
    if dsa_cfg.metadata == "mean":
        cnt = slot[:, None, None].astype(jnp.float32)
        upd = (old * cnt + kf) / (cnt + 1.0)
    else:
        fresh = slot[:, None, None] == 0
        old_mn = jnp.where(fresh, jnp.inf, old[..., 0, :])
        old_mx = jnp.where(fresh, -jnp.inf, old[..., 1, :])
        upd = jnp.stack([jnp.minimum(old_mn, kf),
                         jnp.maximum(old_mx, kf)], axis=-2)
    sel = mine[:, None, None] if dsa_cfg.metadata == "mean" \
        else mine[:, None, None, None]
    upd = jnp.where(sel, upd, old)
    return meta.at[bidx, hidx, lblk[:, None]].set(upd)


def _cp_decode_local(cfg: ModelConfig, q, k, v, kpool, vpool, meta, cur_len,
                     model_axis: str):
    """Per-shard body: pools hold NB_loc local blocks."""
    bs = cfg.dsa.block_size
    NB_loc = kpool.shape[2]
    shard = jax.lax.axis_index(model_axis)
    offset = shard * NB_loc

    blk = cur_len // bs
    slot = cur_len % bs
    mine = (blk >= offset) & (blk < offset + NB_loc)
    lblk = jnp.clip(blk - offset, 0, NB_loc - 1)
    kpool = _append_masked(kpool, k, lblk, slot, mine)
    vpool = _append_masked(vpool, v, lblk, slot, mine)
    meta = _update_meta_masked(meta, k, lblk, slot, mine, cfg.dsa)
    new_len = cur_len + 1

    # local scores -> all-gather the SCORES (tiny), not the pool
    scores_loc = dsa.score_blocks(q, meta, cfg.dsa.metadata)     # (B,Hkv,NBl)
    scores = jax.lax.all_gather(scores_loc, model_axis, axis=2, tiled=True)
    idx, valid = dsa.select_blocks(scores, cfg.dsa, new_len)     # global ids
    loc_valid = valid & (idx >= offset) & (idx < offset + NB_loc)
    lidx = jnp.clip(idx - offset, 0, NB_loc - 1)
    acc, m, l = dsa.sparse_decode_attention_partial(
        q, kpool, vpool, lidx, loc_valid, new_len, offset)
    # logsumexp merge across shards
    m_g = jax.lax.pmax(m, model_axis)
    corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g), 0.0)
    l_g = jax.lax.psum(l * corr, model_axis)
    acc_g = jax.lax.psum(acc * corr[..., None], model_axis)
    o = (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q.dtype)
    return o, kpool, vpool, meta, idx


def _cp_mla_decode_local(cfg: ModelConfig, q_eff, latent, kpool, meta,
                         cur_len, model_axis: str):
    """MLA variant: ONE latent head, k_pool doubles as v_pool (value = the
    first kv_lora_rank dims, sliced by the caller)."""
    bs = cfg.dsa.block_size
    NB_loc = kpool.shape[2]
    shard = jax.lax.axis_index(model_axis)
    offset = shard * NB_loc

    blk = cur_len // bs
    slot = cur_len % bs
    mine = (blk >= offset) & (blk < offset + NB_loc)
    lblk = jnp.clip(blk - offset, 0, NB_loc - 1)
    lat1 = latent[:, None, :]                       # (B, 1, lat)
    kpool = _append_masked(kpool, lat1, lblk, slot, mine)
    meta = _update_meta_masked(meta, lat1, lblk, slot, mine, cfg.dsa)
    new_len = cur_len + 1

    scores_loc = dsa.score_blocks(q_eff, meta, cfg.dsa.metadata)
    scores = jax.lax.all_gather(scores_loc, model_axis, axis=2, tiled=True)
    idx, valid = dsa.select_blocks(scores, cfg.dsa, new_len)
    loc_valid = valid & (idx >= offset) & (idx < offset + NB_loc)
    lidx = jnp.clip(idx - offset, 0, NB_loc - 1)
    m_cfg = cfg.mla
    scale = 1.0 / ((m_cfg.qk_nope_head_dim + m_cfg.qk_rope_head_dim) ** 0.5)
    acc, m, l = dsa.sparse_decode_attention_partial(
        q_eff, kpool, kpool, lidx, loc_valid, new_len, offset, scale=scale)
    m_g = jax.lax.pmax(m, model_axis)
    corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g), 0.0)
    l_g = jax.lax.psum(l * corr, model_axis)
    acc_g = jax.lax.psum(acc * corr[..., None], model_axis)
    o_lat = (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q_eff.dtype)
    return o_lat, kpool, meta, idx


def cp_mla_decode_attention(cfg: ModelConfig, q_eff, latent, cache, cur_len,
                            *, pm):
    """Context-parallel MLA decode (latent pool block-sharded over the
    model axis).  pm: ``launch.plane_mesh.PlaneMesh``."""
    from jax.sharding import PartitionSpec as P
    # drop batch sharding when B doesn't divide the dp axes (e.g. batch=1
    # long-context decode: pure context parallelism over the model axis)
    dp = pm.dp_entry(q_eff.shape[0])
    model_axis = pm.model_axis
    vec = P(dp, None, None)
    lat_s = P(dp, None)
    pool_s = P(dp, None, model_axis, None, None)
    meta_s = P(*([dp, None, model_axis] + [None] * (cache["meta"].ndim - 3)))
    fn = shard_map_compat(
        lambda q_, lt_, kp_, mt_, cl_: _cp_mla_decode_local(
            cfg, q_, lt_, kp_, mt_, cl_, model_axis),
        mesh=pm.mesh,
        in_specs=(vec, lat_s, pool_s, meta_s, P(dp)),
        out_specs=(vec, pool_s, meta_s, vec))
    o_lat, kpool, meta, idx = fn(q_eff, latent, cache["k"], cache["meta"],
                                 cur_len)
    return o_lat, {"k": kpool, "meta": meta}, idx


def cp_decode_attention(cfg: ModelConfig, q, k, v, cache, cur_len, *, pm):
    """shard_map context-parallel select-then-compute decode attention
    (fused form: the whole select+attend in ONE shard_map; the staged
    plane uses the split ``gqa_select_step_cp``/``gqa_attend_step_cp``).

    q (B,Hq,hd); k/v (B,Hkv,hd) new-token projections; cache pools sharded
    (dp, None, model, None, None).  pm: ``launch.plane_mesh.PlaneMesh``.
    Returns (o, new_cache, selected)."""
    from jax.sharding import PartitionSpec as P
    dp = pm.dp_entry(q.shape[0])
    model_axis = pm.model_axis
    vec = P(dp, None, None)
    pool_s = P(dp, None, model_axis, None, None)
    meta_s = P(*([dp, None, model_axis]
                 + [None] * (cache["meta"].ndim - 3)))
    fn = shard_map_compat(
        lambda q_, k_, v_, kp_, vp_, mt_, cl_: _cp_decode_local(
            cfg, q_, k_, v_, kp_, vp_, mt_, cl_, model_axis),
        mesh=pm.mesh,
        in_specs=(vec, vec, vec, pool_s, pool_s, meta_s, P(dp)),
        out_specs=(vec, pool_s, pool_s, meta_s, vec))
    o, kpool, vpool, meta, idx = fn(q, k, v, cache["k"], cache["v"],
                                    cache["meta"], cur_len)
    return o, {"k": kpool, "v": vpool, "meta": meta}, idx


# ---------------------------------------------------------------------------
# Context-parallel STAGED decode stages (the sharded plane's select/attend)
#
# Mirrors of ``gqa/mla_select_step`` and ``gqa/mla_attend_step`` whose
# pool-touching core runs under shard_map so the plane's persistent pool
# slots live sharded across ``pm.model_axis``.  Two layouts (see
# ``PlaneMesh.pool_shard_mode``):
#
# * "heads" — pool sharded on the KV-HEAD axis.  Scoring, top-k and
#   block-sparse attention are per-kv-head-local, so select and attend run
#   with ZERO pool communication; the out_specs' reassembly of the selected
#   ids (select) and the per-head outputs (attend) is the only data that
#   crosses the model axis.
# * "blocks" — pool sharded on the BLOCK axis (MLA latent pools; head
#   counts that don't divide).  Select all-gathers only the block SCORES
#   (B,Hkv,NB fp32) and computes the global top-k redundantly per shard;
#   attend computes flash partials over the LOCAL selected blocks and
#   merges with a logsumexp psum.
#
# Either way the selections handed back to the host are GLOBAL block ids,
# so the engine's LRU / FlashD2H / FlashH2D staging is layout-agnostic.
# Projections (q/k/v, output) and the layer epilogue stay replicated
# outside the shard_map.
# ---------------------------------------------------------------------------


def gqa_select_step_cp(p: Dict[str, jax.Array], cfg: ModelConfig,
                       x: jax.Array, cache: Dict[str, jax.Array],
                       cur_len: jax.Array, pm, *,
                       step_mask: Optional[jax.Array] = None):
    """Sharded select stage: append new KV + update metadata + score +
    top-k with the pool sharded per ``pm``.  Returns (q, new_cache, idx,
    valid) exactly like ``gqa_select_step``; idx/valid are GLOBAL."""
    from jax.sharding import PartitionSpec as P
    if not cfg.dsa.enabled:
        raise NotImplementedError("sharded planes require DSA "
                                  "(cfg.dsa.enabled)")
    bs = cfg.dsa.block_size
    q, k, v = _gqa_project_decode(p, cfg, x, cur_len)
    B, Hq, hd = q.shape
    Hkv = cache["k"].shape[1]
    G = Hq // Hkv
    mask = (step_mask if step_mask is not None
            else jnp.ones((B,), dtype=bool))
    dp = pm.dp_entry(B)
    m = pm.model_axis
    mode = pm.pool_shard_mode(cfg)
    vec = P(dp)

    if mode == "heads":
        pool_s = P(dp, m, None, None, None)
        meta_s = P(*([dp, m] + [None] * (cache["meta"].ndim - 2)))
        hvec = P(dp, m, None)

        def body(q4_, k_, v_, kp_, vp_, mt_, cl_, mk_):
            blk, slot = cl_ // bs, cl_ % bs
            kp_ = _append_masked(kp_, k_, blk, slot, mk_)
            vp_ = _append_masked(vp_, v_, blk, slot, mk_)
            mt_ = _update_meta_masked(mt_, k_, blk, slot, mk_, cfg.dsa)
            Bl, Hl = q4_.shape[0], q4_.shape[1]
            qh = q4_.reshape(Bl, Hl * G, q4_.shape[-1])
            scores = dsa.score_blocks(qh, mt_, cfg.dsa.metadata)
            idx_, valid_ = dsa.select_blocks(scores, cfg.dsa, cl_ + 1)
            return kp_, vp_, mt_, idx_, valid_

        fn = shard_map_compat(
            body, mesh=pm.mesh,
            in_specs=(P(dp, m, None, None), hvec, hvec, pool_s, pool_s,
                      meta_s, vec, vec),
            out_specs=(pool_s, pool_s, meta_s, P(dp, m, None),
                       P(dp, m, None)))
        kp, vp, mt, idx, valid = fn(q.reshape(B, Hkv, G, hd), k, v,
                                    cache["k"], cache["v"], cache["meta"],
                                    cur_len, mask)
        # pools STAY sharded; the ids handed to the host / attend go back
        # to replicated so their sharding cannot leak into later stages
        idx, valid = pm.replicate((idx, valid))
        return q, {"k": kp, "v": vp, "meta": mt}, idx, valid

    pool_s = P(dp, None, m, None, None)
    meta_s = P(*([dp, None, m] + [None] * (cache["meta"].ndim - 3)))

    def body(q_, k_, v_, kp_, vp_, mt_, cl_, mk_):
        NB_loc = kp_.shape[2]
        offset = jax.lax.axis_index(m) * NB_loc
        blk, slot = cl_ // bs, cl_ % bs
        mine = (blk >= offset) & (blk < offset + NB_loc) & mk_
        lblk = jnp.clip(blk - offset, 0, NB_loc - 1)
        kp_ = _append_masked(kp_, k_, lblk, slot, mine)
        vp_ = _append_masked(vp_, v_, lblk, slot, mine)
        mt_ = _update_meta_masked(mt_, k_, lblk, slot, mine, cfg.dsa)
        # all-gather the SCORES (tiny), never the pool: global top-k is
        # computed redundantly per shard -> replicated GLOBAL ids
        scores_loc = dsa.score_blocks(q_, mt_, cfg.dsa.metadata)
        scores = jax.lax.all_gather(scores_loc, m, axis=2, tiled=True)
        idx_, valid_ = dsa.select_blocks(scores, cfg.dsa, cl_ + 1)
        return kp_, vp_, mt_, idx_, valid_

    fn = shard_map_compat(
        body, mesh=pm.mesh,
        in_specs=(P(dp, None, None), P(dp, None, None), P(dp, None, None),
                  pool_s, pool_s, meta_s, vec, vec),
        out_specs=(pool_s, pool_s, meta_s, P(dp, None, None),
                   P(dp, None, None)))
    kp, vp, mt, idx, valid = fn(q, k, v, cache["k"], cache["v"],
                                cache["meta"], cur_len, mask)
    idx, valid = pm.replicate((idx, valid))
    return q, {"k": kp, "v": vp, "meta": mt}, idx, valid


def gqa_attend_step_cp(p: Dict[str, jax.Array], cfg: ModelConfig,
                       q: jax.Array, cache: Dict[str, jax.Array],
                       cur_len: jax.Array, idx: jax.Array,
                       valid: jax.Array, pm) -> jax.Array:
    """Sharded compute stage: block-sparse attention over the sharded
    (possibly host-restored) pool + output projection.  Read-only on
    ``cache``; uses the reference attention inside shard_map."""
    from jax.sharding import PartitionSpec as P
    B, Hq, hd = q.shape
    Hkv = cache["k"].shape[1]
    G = Hq // Hkv
    dp = pm.dp_entry(B)
    m = pm.model_axis
    new_len = cur_len + 1

    if pm.pool_shard_mode(cfg) == "heads":
        pool_s = P(dp, m, None, None, None)

        def body(q4_, kp_, vp_, nl_, idx_, valid_):
            Bl, Hl = q4_.shape[0], q4_.shape[1]
            qh = q4_.reshape(Bl, Hl * G, q4_.shape[-1])
            o = dsa.sparse_decode_attention_ref(qh, kp_, vp_, idx_, valid_,
                                                nl_)
            return o.reshape(Bl, Hl, G, o.shape[-1])

        fn = shard_map_compat(
            body, mesh=pm.mesh,
            in_specs=(P(dp, m, None, None), pool_s, pool_s, P(dp),
                      P(dp, m, None), P(dp, m, None)),
            out_specs=P(dp, m, None, None))
        o = pm.replicate(fn(q.reshape(B, Hkv, G, hd), cache["k"],
                            cache["v"], new_len, idx, valid))
        return o.reshape(B, Hq * o.shape[-1]) @ p["wo"]

    pool_s = P(dp, None, m, None, None)

    def body(q_, kp_, vp_, nl_, idx_, valid_):
        NB_loc = kp_.shape[2]
        offset = jax.lax.axis_index(m) * NB_loc
        loc_valid = valid_ & (idx_ >= offset) & (idx_ < offset + NB_loc)
        lidx = jnp.clip(idx_ - offset, 0, NB_loc - 1)
        acc, mx, l = dsa.sparse_decode_attention_partial(
            q_, kp_, vp_, lidx, loc_valid, nl_, offset)
        m_g = jax.lax.pmax(mx, m)
        corr = jnp.where(jnp.isfinite(mx), jnp.exp(mx - m_g), 0.0)
        l_g = jax.lax.psum(l * corr, m)
        acc_g = jax.lax.psum(acc * corr[..., None], m)
        return (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q_.dtype)

    fn = shard_map_compat(
        body, mesh=pm.mesh,
        in_specs=(P(dp, None, None), pool_s, pool_s, P(dp),
                  P(dp, None, None), P(dp, None, None)),
        out_specs=P(dp, None, None))
    o = pm.replicate(fn(q, cache["k"], cache["v"], new_len, idx, valid))
    return o.reshape(B, Hq * o.shape[-1]) @ p["wo"]


def mla_select_step_cp(p: Dict[str, jax.Array], cfg: ModelConfig,
                       x: jax.Array, cache: Dict[str, jax.Array],
                       cur_len: jax.Array, pm, *,
                       step_mask: Optional[jax.Array] = None):
    """MLA sharded select stage (latent pool: ONE kv head -> always block
    mode).  Returns (q_eff, new_cache, idx, valid); idx GLOBAL."""
    from jax.sharding import PartitionSpec as P
    if not cfg.dsa.enabled:
        raise NotImplementedError("sharded planes require DSA "
                                  "(cfg.dsa.enabled)")
    bs = cfg.dsa.block_size
    q_eff, latent = _mla_project_decode(p, cfg, x, cur_len)
    B = q_eff.shape[0]
    mask = (step_mask if step_mask is not None
            else jnp.ones((B,), dtype=bool))
    dp = pm.dp_entry(B)
    m = pm.model_axis
    pool_s = P(dp, None, m, None, None)
    meta_s = P(*([dp, None, m] + [None] * (cache["meta"].ndim - 3)))

    def body(q_, lat_, kp_, mt_, cl_, mk_):
        NB_loc = kp_.shape[2]
        offset = jax.lax.axis_index(m) * NB_loc
        blk, slot = cl_ // bs, cl_ % bs
        mine = (blk >= offset) & (blk < offset + NB_loc) & mk_
        lblk = jnp.clip(blk - offset, 0, NB_loc - 1)
        lat1 = lat_[:, None, :]
        kp_ = _append_masked(kp_, lat1, lblk, slot, mine)
        mt_ = _update_meta_masked(mt_, lat1, lblk, slot, mine, cfg.dsa)
        scores_loc = dsa.score_blocks(q_, mt_, cfg.dsa.metadata)
        scores = jax.lax.all_gather(scores_loc, m, axis=2, tiled=True)
        idx_, valid_ = dsa.select_blocks(scores, cfg.dsa, cl_ + 1)
        return kp_, mt_, idx_, valid_

    fn = shard_map_compat(
        body, mesh=pm.mesh,
        in_specs=(P(dp, None, None), P(dp, None), pool_s, meta_s,
                  P(dp), P(dp)),
        out_specs=(pool_s, meta_s, P(dp, None, None), P(dp, None, None)))
    kp, mt, idx, valid = fn(q_eff, latent, cache["k"], cache["meta"],
                            cur_len, mask)
    idx, valid = pm.replicate((idx, valid))
    return q_eff, {"k": kp, "meta": mt}, idx, valid


def mla_attend_step_cp(p: Dict[str, jax.Array], cfg: ModelConfig,
                       q_eff: jax.Array, cache: Dict[str, jax.Array],
                       cur_len: jax.Array, idx: jax.Array,
                       valid: jax.Array, pm) -> jax.Array:
    """MLA sharded compute stage: latent block-sparse attention partials
    over the local shard + logsumexp merge + value/output projection."""
    from jax.sharding import PartitionSpec as P
    mc = cfg.mla
    B = q_eff.shape[0]
    H = cfg.num_heads
    dn, dr, dv, lat = (mc.qk_nope_head_dim, mc.qk_rope_head_dim,
                       mc.v_head_dim, mc.kv_lora_rank)
    scale = 1.0 / ((dn + dr) ** 0.5)
    dp = pm.dp_entry(B)
    m = pm.model_axis
    pool_s = P(dp, None, m, None, None)

    def body(q_, kp_, nl_, idx_, valid_):
        NB_loc = kp_.shape[2]
        offset = jax.lax.axis_index(m) * NB_loc
        loc_valid = valid_ & (idx_ >= offset) & (idx_ < offset + NB_loc)
        lidx = jnp.clip(idx_ - offset, 0, NB_loc - 1)
        acc, mx, l = dsa.sparse_decode_attention_partial(
            q_, kp_, kp_, lidx, loc_valid, nl_, offset, scale=scale)
        m_g = jax.lax.pmax(mx, m)
        corr = jnp.where(jnp.isfinite(mx), jnp.exp(mx - m_g), 0.0)
        l_g = jax.lax.psum(l * corr, m)
        acc_g = jax.lax.psum(acc * corr[..., None], m)
        return (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q_.dtype)

    fn = shard_map_compat(
        body, mesh=pm.mesh,
        in_specs=(P(dp, None, None), pool_s, P(dp), P(dp, None, None),
                  P(dp, None, None)),
        out_specs=P(dp, None, None))
    o_lat = pm.replicate(fn(q_eff, cache["k"], cur_len + 1,
                            idx, valid))[..., :lat]
    w_uv = p["w_uv"].reshape(lat, H, dv)
    o = jnp.einsum("bhl,lhd->bhd", o_lat.astype(jnp.float32),
                   w_uv.astype(jnp.float32)).astype(q_eff.dtype)
    return o.reshape(B, H * dv) @ p["wo"]


# ---------------------------------------------------------------------------
# GQA decode step (DSA select-then-compute)
#
# The decode forward is split into two stage functions so the serving
# engine's STAGED decode plane can interleave host work between them:
#
#   gqa_select_step : project q/k/v, append the new KV to the paged pool,
#                     update DSA metadata, score + top-k select.
#   gqa_attend_step : block-sparse attention over the (possibly restored)
#                     pool + output projection.  Cache is READ-ONLY here —
#                     the host may have scattered H2D restore payloads into
#                     it between the two stages.
#
# ``gqa_decode_step`` composes the two in one trace (the fused plane); the
# staged plane jits each stage separately, so a fused FlashH2D restore of
# HBM-evicted blocks can land between select and attend — before use.
# ---------------------------------------------------------------------------

def _gqa_project_decode(p: Dict[str, jax.Array], cfg: ModelConfig,
                        x: jax.Array, cur_len: jax.Array):
    """Decode-token q/k/v projections with RoPE at position cur_len.
    x: (B, d) -> q (B,Hq,hd), k/v (B,Hkv,hd)."""
    B, _ = x.shape
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, Hq, hd)
    k = k.reshape(B, 1, Hkv, hd)
    v = v.reshape(B, 1, Hkv, hd)
    q = apply_rope(q, cur_len[:, None], cfg.rope_theta)[:, 0]   # (B,Hq,hd)
    k = apply_rope(k, cur_len[:, None], cfg.rope_theta)[:, 0]
    return q, k, v[:, 0]


def gqa_select_step(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                    cache: Dict[str, jax.Array], cur_len: jax.Array,
                    *, step_mask: Optional[jax.Array] = None):
    """Select stage: append new KV, update metadata, score + top-k.

    Returns (q, new_cache, idx, valid); idx/valid are None when DSA is
    disabled (the attend stage then runs dense attention over the pool).
    step_mask: rows where False keep pool/meta byte-for-byte unchanged."""
    bs = cfg.dsa.block_size
    q, k, v = _gqa_project_decode(p, cfg, x, cur_len)
    if step_mask is None:
        k_pool = _append_to_pool(cache["k"], k, cur_len, bs)
        v_pool = _append_to_pool(cache["v"], v, cur_len, bs)
        meta = _update_meta(cache["meta"], k, cur_len, cfg.dsa)
    else:
        blk, slot = cur_len // bs, cur_len % bs
        k_pool = _append_masked(cache["k"], k, blk, slot, step_mask)
        v_pool = _append_masked(cache["v"], v, blk, slot, step_mask)
        meta = _update_meta_masked(cache["meta"], k, blk, slot, step_mask,
                                   cfg.dsa)
    idx = valid = None
    if cfg.dsa.enabled:
        scores = dsa.score_blocks(q, meta, cfg.dsa.metadata)
        idx, valid = dsa.select_blocks(scores, cfg.dsa, cur_len + 1)
    return q, {"k": k_pool, "v": v_pool, "meta": meta}, idx, valid


def gqa_attend_step(p: Dict[str, jax.Array], cfg: ModelConfig, q: jax.Array,
                    cache: Dict[str, jax.Array], cur_len: jax.Array,
                    idx: Optional[jax.Array], valid: Optional[jax.Array],
                    *, attn_impl: str = "ref") -> jax.Array:
    """Compute stage: block-sparse attention over the selected blocks of the
    (possibly restored) pool, then the output projection.  Pure read of
    ``cache`` — never mutates it."""
    B, Hq, hd = q.shape
    new_len = cur_len + 1
    if idx is None:
        o = dsa.full_decode_attention_ref(q, cache["k"], cache["v"], new_len)
    elif attn_impl == "kernel":
        from repro.kernels import ops as kops
        o = kops.sparse_decode_attention(q, cache["k"], cache["v"], idx,
                                         valid, new_len)
    else:
        o = dsa.sparse_decode_attention_ref(q, cache["k"], cache["v"], idx,
                                            valid, new_len)
    return o.reshape(B, Hq * hd) @ p["wo"]


def gqa_decode_step(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                    cache: Dict[str, jax.Array], cur_len: jax.Array,
                    *, attn_impl: str = "ref",
                    plane_mesh=None,
                    step_mask: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode token, select and attend FUSED in one trace.
    x: (B, d); cur_len: (B,) tokens already cached.

    Select-then-compute (paper Fig. 2): write new KV -> update metadata ->
    score blocks -> top-k -> block-sparse attention.
    plane_mesh: ``launch.plane_mesh.PlaneMesh`` — context-parallel decode
    over a block-sharded pool (one fused shard_map) — or None.
    step_mask: optional (B,) bool — rows where False keep their pool/meta
    byte-for-byte unchanged (the persistent device plane steps a padded
    batch whose inactive rows must not mutate; attention still computes
    garbage for those rows, which the caller discards).
    """
    B, _ = x.shape
    Hq, hd = cfg.num_heads, cfg.head_dim

    if plane_mesh is not None and cfg.dsa.enabled:
        if step_mask is not None:
            raise NotImplementedError(
                "fused context-parallel decode does not support step_mask "
                "(the sharded PLANES use the staged select/attend split; "
                "sharding the fused persistent plane is a follow-up)")
        q, k, v = _gqa_project_decode(p, cfg, x, cur_len)
        o, new_cache, sel = cp_decode_attention(cfg, q, k, v, cache,
                                                cur_len, pm=plane_mesh)
        out = o.reshape(B, Hq * hd) @ p["wo"]
        return out, new_cache, sel

    q, new_cache, idx, valid = gqa_select_step(p, cfg, x, cache, cur_len,
                                               step_mask=step_mask)
    out = gqa_attend_step(p, cfg, q, new_cache, cur_len, idx, valid,
                          attn_impl=attn_impl)
    return out, new_cache, idx


def cross_decode_step(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                      k_enc: jax.Array, v_enc: jax.Array) -> jax.Array:
    """Whisper decoder cross-attn for one token; x: (B, d)."""
    out = cross_attention(p, cfg, x[:, None, :], k_enc, v_enc)
    return out[:, 0]


# ---------------------------------------------------------------------------
# MLA — MiniCPM3 / DeepSeek-V2 latent attention
# ---------------------------------------------------------------------------

def mla_self_attention(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                       positions: jax.Array, *, return_latent: bool = False):
    """Train / prefill MLA (non-absorbed form).  x: (B, S, d)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    qall = (cq @ p["w_uq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = qall[..., :dn], qall[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = x @ p["w_dkv"]                                  # (B,S,lat)
    c_kv_n = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]           # (B,S,dr) shared
    k_nope = (c_kv_n @ p["w_uk"]).reshape(B, S, H, dn)
    vfull = (c_kv_n @ p["w_uv"]).reshape(B, S, H, dv)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))],
                        axis=-1)
    scale = 1.0 / ((dn + dr) ** 0.5)
    o = flash_attention_jnp(q, k, vfull, scale=scale, causal=True)
    out = o.reshape(B, S, H * dv) @ p["wo"]
    if return_latent:
        latent = jnp.concatenate([c_kv_n, k_rope], axis=-1)  # (B,S,lat+dr)
        return out, latent
    return out


def _mla_project_decode(p: Dict[str, jax.Array], cfg: ModelConfig,
                        x: jax.Array, cur_len: jax.Array):
    """Absorbed-form decode projections: effective query in latent space and
    the new token's latent KV.  x: (B, d) -> (q_eff (B,H,lat+dr),
    latent (B, lat+dr))."""
    m = cfg.mla
    B, _ = x.shape
    H = cfg.num_heads
    dn, dr, lat = m.qk_nope_head_dim, m.qk_rope_head_dim, m.kv_lora_rank

    cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    qall = (cq @ p["w_uq"]).reshape(B, H, dn + dr)
    q_nope, q_rope = qall[..., :dn], qall[..., dn:]
    q_rope = apply_rope(q_rope[:, None], cur_len[:, None], cfg.rope_theta)[:, 0]

    # absorb W_UK into the query: q_abs[h] = q_nope[h] @ W_UK[:, h, :].T
    w_uk = p["w_uk"].reshape(lat, H, dn)
    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32)).astype(x.dtype)
    q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)       # (B,H,lat+dr)

    c_kv = x @ p["w_dkv"]
    c_kv_n = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope((x @ p["w_kr"])[:, None, None, :], cur_len[:, None],
                        cfg.rope_theta)[:, 0, 0]
    latent = jnp.concatenate([c_kv_n, k_rope], axis=-1)     # (B, lat+dr)
    return q_eff, latent


def mla_select_step(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                    cache: Dict[str, jax.Array], cur_len: jax.Array,
                    *, step_mask: Optional[jax.Array] = None):
    """MLA select stage (mirror of ``gqa_select_step`` over the latent
    pool).  Returns (q_eff, new_cache, idx, valid)."""
    bs = cfg.dsa.block_size
    q_eff, latent = _mla_project_decode(p, cfg, x, cur_len)
    if step_mask is None:
        k_pool = _append_to_pool(cache["k"], latent[:, None, :], cur_len, bs)
        meta = _update_meta(cache["meta"], latent[:, None, :], cur_len,
                            cfg.dsa)
    else:
        blk, slot = cur_len // bs, cur_len % bs
        k_pool = _append_masked(cache["k"], latent[:, None, :], blk, slot,
                                step_mask)
        meta = _update_meta_masked(cache["meta"], latent[:, None, :], blk,
                                   slot, step_mask, cfg.dsa)
    idx = valid = None
    if cfg.dsa.enabled:
        scores = dsa.score_blocks(q_eff, meta, cfg.dsa.metadata)
        idx, valid = dsa.select_blocks(scores, cfg.dsa, cur_len + 1)
    return q_eff, {"k": k_pool, "meta": meta}, idx, valid


def mla_attend_step(p: Dict[str, jax.Array], cfg: ModelConfig,
                    q_eff: jax.Array, cache: Dict[str, jax.Array],
                    cur_len: jax.Array, idx: Optional[jax.Array],
                    valid: Optional[jax.Array], *,
                    attn_impl: str = "ref") -> jax.Array:
    """MLA compute stage: latent block-sparse attention over the (possibly
    restored) pool, value up-projection, output projection.  Read-only on
    ``cache``."""
    m = cfg.mla
    B = q_eff.shape[0]
    H = cfg.num_heads
    dn, dr, dv, lat = (m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim,
                       m.kv_lora_rank)
    scale = 1.0 / ((dn + dr) ** 0.5)
    new_len = cur_len + 1
    k_pool = cache["k"]
    if idx is None:
        o_lat = dsa.full_decode_attention_ref(q_eff, k_pool, k_pool, new_len,
                                              scale=scale)
    else:
        o_lat = dsa.sparse_decode_attention_ref(q_eff, k_pool, k_pool, idx,
                                                valid, new_len, scale=scale)
    # o_lat: (B, H, lat+dr); value part is the first `lat` dims
    o_lat = o_lat[..., :lat]
    w_uv = p["w_uv"].reshape(lat, H, dv)
    o = jnp.einsum("bhl,lhd->bhd", o_lat.astype(jnp.float32),
                   w_uv.astype(jnp.float32)).astype(q_eff.dtype)
    return o.reshape(B, H * dv) @ p["wo"]


def mla_decode_step(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                    cache: Dict[str, jax.Array], cur_len: jax.Array,
                    *, attn_impl: str = "ref", plane_mesh=None,
                    step_mask: Optional[jax.Array] = None):
    """Absorbed-form MLA decode, select and attend FUSED in one trace (see
    the GQA stage split above): the latent cache behaves as a single KV head
    with key dim (kv_lora_rank + rope) and value = latent (kv_lora_rank).
    DSA metadata lives in latent space — beyond-paper extension (DESIGN §4).
    plane_mesh: see ``gqa_decode_step`` (latent pool block-sharded).
    step_mask: see ``gqa_decode_step`` — False rows leave the cache unchanged.
    """
    m = cfg.mla
    B, _ = x.shape
    H = cfg.num_heads
    dv, lat = m.v_head_dim, m.kv_lora_rank

    if plane_mesh is not None and cfg.dsa.enabled:
        if step_mask is not None:
            raise NotImplementedError(
                "fused context-parallel decode does not support step_mask "
                "(see gqa_decode_step)")
        q_eff, latent = _mla_project_decode(p, cfg, x, cur_len)
        o_lat, new_cache, sel = cp_mla_decode_attention(
            cfg, q_eff, latent, cache, cur_len, pm=plane_mesh)
        o_lat = o_lat[..., :lat]
        w_uv = p["w_uv"].reshape(lat, H, dv)
        o = jnp.einsum("bhl,lhd->bhd", o_lat.astype(jnp.float32),
                       w_uv.astype(jnp.float32)).astype(x.dtype)
        out = o.reshape(B, H * dv) @ p["wo"]
        return out, new_cache, sel

    q_eff, new_cache, idx, valid = mla_select_step(p, cfg, x, cache, cur_len,
                                                   step_mask=step_mask)
    out = mla_attend_step(p, cfg, q_eff, new_cache, cur_len, idx, valid,
                          attn_impl=attn_impl)
    return out, new_cache, idx
