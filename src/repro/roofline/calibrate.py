"""Two-point cost calibration for scan-over-layers programs.

XLA's ``cost_analysis()`` counts a ``while``-loop body ONCE, not x
trip-count (verified empirically — an 8-trip scan of matmuls reports 1/8 the
unrolled flops).  The production step functions scan over stacked layers, so
their raw dry-run costs undercount by ~num_layers.

Calibration: compile UNROLLED (list-mode) variants of the same config with
u and 2u layers at FULL tensor dimensions, where u is the layer-pattern
period (1 for homogeneous models; 8 for Jamba's attn:mamba 1:7 + MoE-every-2
interleave).  With per-unit cost ``b`` and layer-independent overhead ``a``:

    F(u) = a + b,  F(2u) = a + 2b  =>  b = F(2u) - F(u),  a = F(u) - b
    corrected(L) = a + (L/u) * b

Inner sequence scans in blocked attention are eliminated during calibration
via ``attention.EXACT_COST_MODE`` (single-trip scans are counted exactly).
Remaining limitation: mamba/rwkv token-recurrence bodies (tiny elementwise
FLOPs vs the projection matmuls, <2-3 %) stay undercounted; noted in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax

from repro.models.common import ModelConfig
from repro.roofline.analysis import collective_bytes_from_hlo, extract_cost


def calib_unit(cfg: ModelConfig) -> int:
    """Smallest layer-pattern period that tiles the model."""
    from repro.models.model import is_homogeneous
    if is_homogeneous(cfg):
        return 1
    p = cfg.attn_layer_period if cfg.attn_layer_period > 1 else 1
    q = cfg.moe_layer_period if cfg.moe_layer_period > 1 else 1
    return math.lcm(p, q)


def _reduced(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    return dataclasses.replace(cfg, num_layers=n_layers)


def _measure(cfg: ModelConfig, shape_name: str, mesh, remat: bool
             ) -> Dict[str, float]:
    """Lower+compile the UNROLLED variant in exact-cost mode; return
    per-device (flops, bytes, collective bytes)."""
    from repro.launch import sharding as sh
    from repro.launch.steps import step_and_specs
    from repro.models import attention as attn_mod

    attn_mod.EXACT_COST_MODE = True
    try:
        fn, args, kind = step_and_specs(cfg, shape_name, remat=remat,
                                        stacked=False)
        if kind == "train":
            in_sh = (sh.param_shardings(args[0], mesh),
                     sh.opt_shardings(args[1], mesh),
                     sh.batch_shardings(args[2], mesh))
            out_sh = (in_sh[0], in_sh[1], None)
        elif kind == "prefill":
            in_sh = (sh.param_shardings(args[0], mesh),
                     sh.batch_shardings(args[1], mesh))
            out_sh = None
        else:
            state_s = sh.state_shardings(args[2], mesh)
            in_sh = (sh.param_shardings(args[0], mesh),
                     sh.tokens_sharding(args[1].shape[0], mesh), state_s)
            out_sh = (None, state_s)
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*args).compile()
        cost = extract_cost(compiled)
        coll = collective_bytes_from_hlo(compiled.as_text())
        return {"flops": cost.get("flops", 0.0),
                "bytes": cost.get("bytes accessed", 0.0),
                "coll": float(coll.get("bytes_per_device", 0))}
    finally:
        attn_mod.EXACT_COST_MODE = False


def calibrated_cost(cfg: ModelConfig, shape_name: str, mesh,
                    *, remat: bool = True) -> Dict[str, Any]:
    """Per-device calibrated (flops, bytes, collective-bytes) for the FULL
    config, derived purely from compiled XLA artifacts.  Honors the module
    globals for the §Perf variants (ffn.EP_AXES etc.)."""
    u = calib_unit(cfg)
    L = cfg.num_layers
    assert L % u == 0, (cfg.name, L, u)
    m1 = _measure(_reduced(cfg, u), shape_name, mesh, remat)
    m2 = _measure(_reduced(cfg, 2 * u), shape_name, mesh, remat)
    out: Dict[str, Any] = {"unit_layers": u}
    for k in ("flops", "bytes", "coll"):
        b = m2[k] - m1[k]
        a = m1[k] - b
        out[k] = max(a + (L // u) * b, 0.0)
        out[f"{k}_per_unit"] = b
        out[f"{k}_overhead"] = a
    return out
