"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute    = HLO_FLOPs_global   / (chips × 197e12 FLOP/s)
    memory     = HLO_bytes_global   / (chips × 819e9  B/s)
    collective = coll_bytes_per_dev / 50e9 B/s per link

``compiled.cost_analysis()`` reports the PARTITIONED (per-device) module —
we normalise to global by ×chips.  Collective bytes are parsed from the
optimized HLO: the sum of operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (post-SPMD shapes are
per-device, which is exactly the per-chip link traffic we need).
"""
from __future__ import annotations

import re
from typing import Any, Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
HBM_CAP = 16e9               # bytes

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape like bf16[128,64,8]{2,1,0} or f32[] — capture dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*[a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(\(?[a-z0-9_]+\[[^=]*)")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*")
_OPERAND_RE = re.compile(r"%([\w.-]+)")


def _split_type_and_op(rest: str):
    """'(f32[2], u32[]) all-reduce-start(%x), ...' -> (type, opcode, after).

    Handles tuple result types whose parentheses would confuse a regex."""
    rest = rest.lstrip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    typ = rest[:i + 1]
                    tail = rest[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        typ, tail = rest[:sp], rest[sp + 1:].lstrip()
    par = tail.find("(")
    if par < 0:
        return None
    return typ, tail[:par], tail[par + 1:]


def _type_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuple types)."""
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(type_str))


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in optimized (post-SPMD) HLO.

    Optimized HLO references operands by %name, so first build a symbol
    table of instruction result types, then resolve each collective's
    operand list.  ``-done`` ops are skipped (bytes counted at ``-start``).
    Post-SPMD shapes are per-partition — exactly per-chip link traffic.
    """
    defs: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            # result type = everything between '=' and the op's '(' — for
            # tuple results the whole "(t1, t2)" region precedes the opcode.
            typ = m.group(2)
            cut = typ.find("(", 1) if typ.startswith("(") else typ.find("(")
            if typ.startswith("("):
                # tuple type: up to matching ')'
                depth = 0
                for i, ch in enumerate(typ):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            cut = i + 1
                            break
            typ = typ[:cut] if cut > 0 else typ
            defs[m.group(1)] = _type_bytes(typ)
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ma = _ASSIGN_RE.match(line)
        if not ma:
            continue
        parsed = _split_type_and_op(line[ma.end():])
        if parsed is None:
            continue
        _, opcode, inner = parsed
        kind = None
        for c in _COLLECTIVES:
            if opcode == c or opcode == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        # operands: %names inside the call parens
        depth, buf = 1, []
        for ch in inner:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        nbytes = 0
        for name in _OPERAND_RE.findall("".join(buf)):
            nbytes += defs.get(name, 0)
        per_kind[kind] += nbytes
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"bytes_per_device": total,
            "per_kind_bytes": {k: v for k, v in per_kind.items() if v},
            "counts": {k: v for k, v in counts.items() if v}}


def extract_cost(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:   # noqa: BLE001
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k in ("flops", "bytes accessed", "transcendentals",
              "optimal_seconds", "utilization"):
        if k in ca:
            keep[k] = float(ca[k])
    # also fold in bytes accessed operand breakdown totals if present
    return keep


def extract_memory(compiled) -> Dict[str, int]:
    try:
        ma = compiled.memory_analysis()
    except Exception:   # noqa: BLE001
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS = 6·N_active·D (train: fwd+bwd ≈ 6ND; inference: 2ND)."""
    n = cfg.active_param_count()
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n * tokens
    # decode: one token per request
    return 2.0 * n * global_batch


def roofline_report(cfg, rec: Dict[str, Any], chips: int) -> Dict[str, Any]:
    """Compute the three roofline terms + dominant bottleneck for a record."""
    cost = rec.get("cost", {})
    coll = rec.get("collectives", {})
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    flops_global = flops_dev * chips
    bytes_global = bytes_dev * chips
    coll_dev = coll.get("bytes_per_device", 0)

    t_compute = flops_global / (chips * PEAK_FLOPS) if flops_global else 0.0
    t_memory = bytes_global / (chips * HBM_BW) if bytes_global else 0.0
    t_coll = coll_dev / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get) if any(terms.values()) else "n/a"

    sp_kind = rec.get("kind", "train")
    from repro.launch.steps import SHAPES
    sp = SHAPES[rec["shape"]]
    mf = model_flops(cfg, sp_kind, sp.seq_len, sp.global_batch)
    useful = mf / flops_global if flops_global else 0.0
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_global,
        "useful_flops_ratio": useful,
        "hbm_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
    }
