"""jit'd public wrappers for the Pallas kernels.

``INTERPRET`` defaults to True (this container is CPU-only; interpret mode
executes the kernel bodies in Python for correctness validation).  On a real
TPU deployment set ``REPRO_KERNEL_INTERPRET=0`` to compile via Mosaic.
"""
from __future__ import annotations

import os


from repro.kernels.block_score import block_score as _block_score
from repro.kernels.flash_prefill import flash_prefill as _flash_prefill
from repro.kernels.gather_blocks import gather_blocks as _gather_blocks
from repro.kernels.gather_blocks import gather_blocks_hkv as _gather_blocks_hkv
from repro.kernels.quant_blocks import dequantize_blocks as _dequantize_blocks
from repro.kernels.quant_blocks import (
    dequantize_scatter_blocks as _dequantize_scatter_blocks)
from repro.kernels.quant_blocks import quantize_blocks as _quantize_blocks
from repro.kernels.scatter_blocks import scatter_blocks as _scatter_blocks
from repro.kernels.scatter_blocks import (
    scatter_blocks_hkv as _scatter_blocks_hkv)
from repro.kernels.sparse_decode_attention import (
    sparse_decode_attention as _sparse_decode_attention)

INTERPRET = os.environ.get("REPRO_KERNEL_INTERPRET", "1") != "0"


def gather_blocks(pool, idx):
    return _gather_blocks(pool, idx, interpret=INTERPRET)


def scatter_blocks(pool, new_kv, dest_blocks):
    return _scatter_blocks(pool, new_kv, dest_blocks, interpret=INTERPRET)


def gather_blocks_hkv(pool, idx):
    return _gather_blocks_hkv(pool, idx, interpret=INTERPRET)


def scatter_blocks_hkv(pool, new_kv, dest_blocks):
    return _scatter_blocks_hkv(pool, new_kv, dest_blocks, interpret=INTERPRET)


def quantize_blocks(blocks):
    return _quantize_blocks(blocks, interpret=INTERPRET)


def dequantize_blocks(q, scales):
    return _dequantize_blocks(q, scales, interpret=INTERPRET)


def dequantize_scatter_blocks(pool, q, scales, dest_blocks):
    return _dequantize_scatter_blocks(pool, q, scales, dest_blocks,
                                      interpret=INTERPRET)


def block_score(q, meta_min, meta_max, nb_tile: int = 128):
    return _block_score(q, meta_min, meta_max, nb_tile=nb_tile,
                        interpret=INTERPRET)


def sparse_decode_attention(q, k_pool, v_pool, block_idx, sel_valid, cur_len,
                            scale=None):
    return _sparse_decode_attention(q, k_pool, v_pool, block_idx, sel_valid,
                                    cur_len, scale=scale, interpret=INTERPRET)


def flash_prefill(q, k, v, scale=None, q_offset: int = 0,
                  q_tile: int = 128, k_tile: int = 128):
    return _flash_prefill(q, k, v, scale=scale, q_offset=q_offset,
                          q_tile=q_tile, k_tile=k_tile, interpret=INTERPRET)
