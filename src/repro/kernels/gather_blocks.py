"""FlashH2D TPU analogue: fused fragmented KV-block gather (paper §3.2.1).

The paper's FlashH2D fuses many small per-block HBM<-DRAM copies into ONE
GPU kernel via CUDA UVA.  The TPU-native equivalent is a single Pallas
program whose *scalar-prefetched* index map drives one block-granular DMA
per grid step: the block ids arrive in SMEM before the body runs, so the
memory system streams all K fragmented blocks back-to-back — one launch,
full link utilisation, no per-copy descriptor overhead.

On a real deployment the source pool lives in host memory
(``jax.device_put(pool, ...memory_kind="pinned_host")``) and the same index
map expresses the H2D stream; here the kernel is validated in interpret
mode against ``ref.gather_blocks``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, pool_ref, out_ref):
    # pool_ref is the (1, bs, D) block selected by the index map — one DMA.
    out_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_blocks(pool: jax.Array, idx: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """pool: (NB, bs, D); idx: (K,) int32 -> (K, bs, D)."""
    NB, bs, D = pool.shape
    K = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, bs, D), lambda i, idx_ref: (idx_ref[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, D), lambda i, idx_ref: (i, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, bs, D), pool.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), pool)


def _gather_hkv_kernel(idx_ref, pool_ref, out_ref):
    out_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_blocks_hkv(pool: jax.Array, idx: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    """Head-major fused gather: pool (H, NB, bs, D); idx (K,) int32 ->
    (H, K, bs, D).

    The per-head variant the persistent device plane
    (``repro.core.device_pool``) uses for one batch row: the paper's
    (H, N, D) layout (§3.2, Fig. 5) keeps each head's blocks contiguous, so
    the grid streams one (head, block) DMA per step — all H*K fragmented
    blocks in ONE launch."""
    H, NB, bs, D = pool.shape
    K = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(H, K),
        in_specs=[
            pl.BlockSpec((1, 1, bs, D),
                         lambda h, i, idx_ref: (h, idx_ref[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bs, D),
                               lambda h, i, idx_ref: (h, i, 0, 0)),
    )
    return pl.pallas_call(
        _gather_hkv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((H, K, bs, D), pool.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), pool)
