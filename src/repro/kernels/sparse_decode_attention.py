"""Flagship kernel: block-sparse paged decode attention (DSA compute phase).

One decode token attends to its top-K selected KV blocks.  The selected
block ids are *scalar-prefetched* so the BlockSpec index map DMAs exactly
the K fragmented blocks out of the paged pool — the same fused-transfer
idea as FlashH2D, applied to the attention read itself
(select-then-compute, paper Fig. 2).

Grid: (B, Hkv, K) with K innermost.  Online-softmax state (m, l, acc) lives
in VMEM scratch across the K steps of one (b, h) pair; the output tile is
written on the last step.  Tile shapes: q (G, D) — the GQA group — and
(bs, D) per KV block; D and bs are MXU/VPU-aligned (128 / 32) for the
assigned configs.

Validated in interpret mode against ``ref.sparse_decode_attention``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(scale: float, bs: int, K: int):
    def kernel(idx_ref, valid_ref, lens_ref,   # scalar prefetch (SMEM)
               q_ref, k_ref, v_ref,            # VMEM tiles
               out_ref,                        # output tile
               m_ref, l_ref, acc_ref):         # VMEM scratch
        b = pl.program_id(0)
        h = pl.program_id(1)
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
        k = k_ref[0, 0, 0].astype(jnp.float32)               # (bs, D)
        v = v_ref[0, 0, 0].astype(jnp.float32)               # (bs, Dv)

        blk = idx_ref[b, h, j]
        ok = valid_ref[b, h, j]
        cur = lens_ref[b]

        s = (q @ k.T) * scale                                # (G, bs)
        pos = blk * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        mask = (pos < cur) & (ok > 0)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ v
        m_ref[...] = m_new

        @pl.when(j == K - 1)
        def _finalize():
            l = jnp.maximum(l_ref[...], 1e-30)
            out_ref[0, 0] = (acc_ref[...] / l).astype(out_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def sparse_decode_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_idx: jax.Array,
                            sel_valid: jax.Array, cur_len: jax.Array, *,
                            scale: Optional[float] = None,
                            interpret: bool = True) -> jax.Array:
    """q: (B, Hq, D); pools: (B, Hkv, NB, bs, D[v]); block_idx/sel_valid:
    (B, Hkv, K); cur_len: (B,) int32.  Returns (B, Hq, Dv)."""
    B, Hq, D = q.shape
    _, Hkv, NB, bs, _ = k_pool.shape
    Dv = v_pool.shape[-1]
    K = block_idx.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, G, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, K),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, idx, val, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, bs, D),
                         lambda b, h, j, idx, val, lens:
                         (b, h, idx[b, h, j], 0, 0)),
            pl.BlockSpec((1, 1, 1, bs, Dv),
                         lambda b, h, j, idx, val, lens:
                         (b, h, idx[b, h, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv),
                               lambda b, h, j, idx, val, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # m
            pltpu.VMEM((G, 1), jnp.float32),   # l
            pltpu.VMEM((G, Dv), jnp.float32),  # acc
        ],
    )
    out = pl.pallas_call(
        _make_kernel(scale, bs, K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
    )(block_idx.astype(jnp.int32), sel_valid.astype(jnp.int32),
      cur_len.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(B, Hq, Dv)
