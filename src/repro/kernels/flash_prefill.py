"""Blocked causal flash attention for prefill segments.

Layer-segmented prefill (paper §3.4) runs ONE layer over the whole prompt
per batch; its attention is a standard causal flash kernel.  Tiling:
grid (B, Hkv, nQ, nK) with the key axis innermost; online-softmax scratch
(m, l, acc) persists across the nK steps of a query tile.  Causal skip:
key tiles strictly above the diagonal are masked (the j-loop upper bound
is handled by masking — the triangular-schedule variant is the §Perf
optimized path at the jnp level).

Validated in interpret mode against ``ref.flash_prefill``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(scale: float, q_tile: int, k_tile: int, nK: int,
                 q_offset: int, Sk: int):
    def kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref):
        iq = pl.program_id(2)
        jk = pl.program_id(3)

        @pl.when(jk == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0, 0, 0].astype(jnp.float32)       # (G*q_tile, D) flattened
        k = k_ref[0, 0].astype(jnp.float32)          # (k_tile, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (k_tile, Dv)
        G = q.shape[0] // q_tile

        s = (q @ k.T) * scale                        # (G*q_tile, k_tile)
        qpos = (q_offset + iq * q_tile
                + jax.lax.broadcasted_iota(jnp.int32, (G, q_tile, 1), 1))
        kpos = jk * k_tile + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, k_tile), 2)
        mask = ((qpos >= kpos) & (kpos < Sk)).reshape(G * q_tile, k_tile)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ v
        m_ref[...] = m_new

        @pl.when(jk == nK - 1)
        def _finalize():
            l = jnp.maximum(l_ref[...], 1e-30)
            out_ref[0, 0, 0] = (acc_ref[...] / l).astype(out_ref.dtype)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("scale", "q_tile", "k_tile", "q_offset",
                                    "interpret"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  scale: Optional[float] = None, q_offset: int = 0,
                  q_tile: int = 128, k_tile: int = 128,
                  interpret: bool = True) -> jax.Array:
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D).  Causal.  -> (B, Sq, Hq, Dv).

    GQA groups are folded into the query tile: the kernel sees
    (G*q_tile, D) query tiles so one key tile serves the whole group."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    q_tile = min(q_tile, Sq)
    k_tile = min(k_tile, Sk)
    pq = (-Sq) % q_tile
    pk = (-Sk) % k_tile
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nQ = (Sq + pq) // q_tile
    nK = (Sk + pk) // k_tile
    # (B, Hkv, nQ, G*q_tile, D): group-major query tiles
    qt = (qp.reshape(B, nQ, q_tile, Hkv, G, D)
          .transpose(0, 3, 1, 4, 2, 5)
          .reshape(B, Hkv, nQ * 1, G * q_tile, D))
    kt = kp.transpose(0, 2, 1, 3)                    # (B, Hkv, Skp, D)
    vt = vp.transpose(0, 2, 1, 3)

    kernel = _make_kernel(scale, q_tile, k_tile, nK, q_offset, Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nQ, nK),
        in_specs=[
            pl.BlockSpec((1, 1, 1, G * q_tile, D),
                         lambda b, h, i, j: (b, h, i, 0, 0)),
            pl.BlockSpec((1, 1, k_tile, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, k_tile, Dv), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, G * q_tile, Dv),
                               lambda b, h, i, j: (b, h, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, nQ, G * q_tile, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * q_tile, 1), jnp.float32),
            pltpu.VMEM((G * q_tile, 1), jnp.float32),
            pltpu.VMEM((G * q_tile, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qt.reshape(B, Hkv, nQ, G * q_tile, D), kt, vt)
    # back to (B, Sq, Hq, Dv)
    out = (out.reshape(B, Hkv, nQ, G, q_tile, Dv)
           .transpose(0, 2, 4, 1, 3, 5)
           .reshape(B, nQ * q_tile, Hq, Dv))
    return out[:, :Sq]
