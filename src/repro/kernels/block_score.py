"""Block-criticality scoring kernel (DSA select phase, paper §2.2).

Computes the Quest/ArkVale cuboid upper bound for every KV block and every
GQA group, reduced (max) over the query heads of the group:

    score[b, h, n] = max_g  sum_d  max(q[b,h,g,d] * mn[b,h,n,d],
                                       q[b,h,g,d] * mx[b,h,n,d])

Grid: (B, Hkv, NB / nb_tile).  Each step loads the group's query tile and a
tile of block metadata into VMEM; the two einsums hit the MXU with the
block axis as the 128-aligned minor-most dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(q_ref, mn_ref, mx_ref, out_ref):
    # q: (1, 1, G, D); mn/mx: (1, 1, NBt, D); out: (1, 1, NBt)
    q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
    mn = mn_ref[0, 0].astype(jnp.float32)                # (NBt, D)
    mx = mx_ref[0, 0].astype(jnp.float32)
    pos = jnp.maximum(q, 0.0)
    neg = jnp.minimum(q, 0.0)
    s = pos @ mx.T + neg @ mn.T                          # (G, NBt)
    out_ref[0, 0] = jnp.max(s, axis=0)


@functools.partial(jax.jit, static_argnames=("nb_tile", "interpret"))
def block_score(q: jax.Array, meta_min: jax.Array, meta_max: jax.Array, *,
                nb_tile: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, Hq, D); meta_min/max: (B, Hkv, NB, D) -> scores (B, Hkv, NB)."""
    B, Hq, D = q.shape
    _, Hkv, NB, _ = meta_min.shape
    G = Hq // Hkv
    nb_tile = min(nb_tile, NB)
    pad = (-NB) % nb_tile
    if pad:
        # padded blocks score against zero cuboids -> finite; callers mask
        meta_min = jnp.pad(meta_min, ((0, 0), (0, 0), (0, pad), (0, 0)))
        meta_max = jnp.pad(meta_max, ((0, 0), (0, 0), (0, pad), (0, 0)))
    NBp = NB + pad
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, NBp // nb_tile)
    out = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, n: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, nb_tile, D), lambda b, h, n: (b, h, n, 0)),
            pl.BlockSpec((1, 1, nb_tile, D), lambda b, h, n: (b, h, n, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, nb_tile), lambda b, h, n: (b, h, n)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, NBp), jnp.float32),
        interpret=interpret,
    )(qg, meta_min, meta_max)
    return out[:, :, :NB]
