"""FlashD2H TPU analogue: contiguous KV flush -> paged pool scatter (§3.2.2).

The paper's FlashD2H saves newly generated KV in two phases: (1) one big
contiguous D2H memcpy into a DRAM staging buffer, (2) CPU threads scatter
the buffer into the per-head KV blocks asynchronously.  Phase (1) maps to a
single contiguous device->host DMA on TPU; phase (2) — placing contiguous
data into scattered pool blocks — is expressed here as one Pallas program
whose *output* index map scatters whole blocks, with the pool aliased
in-place (``input_output_aliases``) so untouched blocks are preserved.

Validated in interpret mode against ``ref.scatter_blocks``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(dest_ref, new_ref, pool_in_ref, pool_out_ref):
    del pool_in_ref  # aliased with pool_out_ref; unvisited blocks persist
    pool_out_ref[...] = new_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_blocks(pool: jax.Array, new_kv: jax.Array, dest_blocks: jax.Array,
                   *, interpret: bool = True) -> jax.Array:
    """pool: (NB, bs, D); new_kv: (T, D) with T = n_new*bs (contiguous);
    dest_blocks: (n_new,) int32.  Returns updated pool."""
    NB, bs, D = pool.shape
    n_new = dest_blocks.shape[0]
    assert new_kv.shape[0] == n_new * bs
    new_blk = new_kv.reshape(n_new, bs, D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_new,),
        in_specs=[
            pl.BlockSpec((1, bs, D), lambda i, dref: (i, 0, 0)),        # new
            pl.BlockSpec((1, bs, D), lambda i, dref: (dref[i], 0, 0)),  # pool in
        ],
        out_specs=pl.BlockSpec((1, bs, D), lambda i, dref: (dref[i], 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},  # pool (arg idx incl. prefetch) -> out 0
        interpret=interpret,
    )(dest_blocks.astype(jnp.int32), new_blk, pool)


def _scatter_hkv_kernel(dest_ref, new_ref, pool_in_ref, pool_out_ref):
    del pool_in_ref  # aliased with pool_out_ref; unvisited blocks persist
    pool_out_ref[...] = new_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_blocks_hkv(pool: jax.Array, new_kv: jax.Array,
                       dest_blocks: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """Head-major block scatter: pool (H, NB, bs, D); new_kv (H, K, bs, D);
    dest_blocks (K,) int32.  Returns the updated pool (aliased in place).

    The per-head variant the persistent device plane uses to land fused
    FlashH2D payloads (``KVCacheManager.load_blocks_fused``) — and to zero
    HBM-evicted blocks — directly in a batch row's device slots: one grid
    step per (head, block), whole-block granularity, untouched blocks
    preserved via ``input_output_aliases``."""
    H, NB, bs, D = pool.shape
    K = dest_blocks.shape[0]
    assert new_kv.shape == (H, K, bs, D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(H, K),
        in_specs=[
            pl.BlockSpec((1, 1, bs, D),
                         lambda h, i, dref: (h, i, 0, 0)),        # new
            pl.BlockSpec((1, 1, bs, D),
                         lambda h, i, dref: (h, dref[i], 0, 0)),  # pool in
        ],
        out_specs=pl.BlockSpec((1, 1, bs, D),
                               lambda h, i, dref: (h, dref[i], 0, 0)),
    )
    return pl.pallas_call(
        _scatter_hkv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},  # pool (arg idx incl. prefetch) -> out 0
        interpret=interpret,
    )(dest_blocks.astype(jnp.int32), new_kv, pool)
