"""Pure-jnp oracles for every Pallas kernel (per-kernel allclose tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dsa import score_blocks, sparse_decode_attention_ref

NEG_INF = -1e30


def gather_blocks(pool: jax.Array, idx: jax.Array) -> jax.Array:
    """FlashH2D oracle: pool (NB, bs, D), idx (K,) -> (K, bs, D)."""
    return pool[idx]


def gather_blocks_hkv(pool: jax.Array, idx: jax.Array) -> jax.Array:
    """Head-major FlashH2D oracle: pool (H, NB, bs, D), idx (K,) ->
    (H, K, bs, D)."""
    return pool[:, idx]


def scatter_blocks_hkv(pool: jax.Array, new_kv: jax.Array,
                       dest_blocks: jax.Array) -> jax.Array:
    """Head-major block-scatter oracle: pool (H, NB, bs, D);
    new_kv (H, K, bs, D); dest_blocks (K,)."""
    return pool.at[:, dest_blocks].set(new_kv)


def scatter_blocks(pool: jax.Array, new_kv: jax.Array,
                   dest_blocks: jax.Array) -> jax.Array:
    """FlashD2H oracle.

    pool: (NB, bs, D); new_kv: (T, D) contiguous, T = n_new_blocks * bs;
    dest_blocks: (n_new_blocks,) destination block ids.
    Returns the pool with new blocks placed (whole-block granularity — the
    paper flushes blocks when they fill)."""
    nb, bs, D = pool.shape
    n_new = dest_blocks.shape[0]
    blocks = new_kv.reshape(n_new, bs, D)
    return pool.at[dest_blocks].set(blocks)


def quantize_blocks(blocks: jax.Array) -> tuple[jax.Array, jax.Array]:
    """int8 offload-quant oracle: blocks (H, K, bs, D) fp ->
    (q (H, K, bs, D) int8, scales (H, K) f32).

    Symmetric per-(head, block) quantization: scale = amax/127 over each
    (bs, D) tile, q = clip(rint(x/scale), -127, 127).  All-zero blocks get
    scale 0 (and quantize to 0) — dequant maps them back to exact zeros."""
    x = blocks.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=(-2, -1))
    scales = amax / 127.0
    # reciprocal-multiply, NOT x/scale — keeps exact .5 rounding
    # boundaries identical across the kernel / ref / numpy paths (XLA
    # rewrites division inconsistently between compilation contexts)
    inv = jnp.where(scales > 0.0,
                    1.0 / jnp.where(scales > 0.0, scales, 1.0), 1.0)
    q = jnp.clip(jnp.rint(x * inv[..., None, None]), -127.0, 127.0)
    return q.astype(jnp.int8), scales


def dequantize_blocks(q: jax.Array, scales: jax.Array) -> jax.Array:
    """Inverse oracle: q (H, K, bs, D) int8, scales (H, K) ->
    (H, K, bs, D) f32."""
    return q.astype(jnp.float32) * scales[..., None, None]


def dequantize_scatter_blocks(pool: jax.Array, q: jax.Array,
                              scales: jax.Array, dest_blocks: jax.Array
                              ) -> jax.Array:
    """Fused dequant-restore oracle: pool (H, NB, bs, D); q (H, K, bs, D)
    int8; scales (H, K); dest_blocks (K,).  Returns pool with the
    dequantized blocks placed (quantized ``scatter_blocks_hkv``)."""
    new = dequantize_blocks(q, scales).astype(pool.dtype)
    return pool.at[:, dest_blocks].set(new)


def block_score(q: jax.Array, meta_min: jax.Array, meta_max: jax.Array
                ) -> jax.Array:
    """Quest cuboid upper-bound scores, group-max over GQA query heads.

    q: (B, Hq, D); meta_min/max: (B, Hkv, NB, D) -> (B, Hkv, NB) f32."""
    meta = jnp.stack([meta_min, meta_max], axis=-2)
    return score_blocks(q, meta, method="cuboid", group_reduce="max")


def sparse_decode_attention(q, k_pool, v_pool, block_idx, sel_valid, cur_len,
                            scale: Optional[float] = None):
    """(B,Hq,D) x pools (B,Hkv,NB,bs,D) + selection -> (B,Hq,Dv)."""
    return sparse_decode_attention_ref(q, k_pool, v_pool, block_idx,
                                       sel_valid, cur_len, scale)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  scale: Optional[float] = None, q_offset: int = 0
                  ) -> jax.Array:
    """Causal full attention oracle.  q: (B,Sq,Hq,D); k/v: (B,Sk,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = qpos[:, None] >= kpos[None, :]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, Dv).astype(q.dtype)
