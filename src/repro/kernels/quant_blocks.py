"""int8 KV-block (de)quantization for the DRAM offload tier (§3.2 + ROADMAP
"quantized DRAM tier").

The quantized tier stores offloaded KV blocks as symmetric int8 with ONE
f32 scale per (kv-head, block): ``scale = amax(block)/127``,
``q = clip(round(x/scale), -127, 127)``, ``dequant = q * scale``.  Per-head
scales matter because K/V magnitude varies strongly across kv heads; a
per-tensor scale would crush small-magnitude heads' resolution.

Three kernels, mirroring the fp transfer pair:

- ``quantize_blocks``  — fuses into the FlashD2H save path: the gathered
  per-head block stripe quantizes on the way to the DRAM staging buffer,
  so the D2H DMA moves ~1/dtype_bytes of the fp payload plus 4 B/head/block
  of scales.
- ``dequantize_blocks`` — the FlashH2D inverse: int8 payload + scales back
  to the compute dtype after the (now smaller) H2D DMA.
- ``dequantize_scatter_blocks`` — ``scatter_blocks_hkv`` with the dequant
  fused in: lands int8 restore payloads straight into a request's fp
  device slots in one launch (restore-before-use stays a single fused op).

All are validated in interpret mode against the ``ref.py`` oracles.
Rounding is ``jnp.rint`` (round-half-to-even) so the numpy host-pool path
(``np.rint``) is bit-identical to the kernel path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(blk_ref, q_ref, scale_ref):
    x = blk_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = amax / 127.0
    # explicit reciprocal-multiply (not x/scale): XLA rewrites division to
    # reciprocal-multiply in some contexts and not others, which flips
    # exact .5 rounding boundaries — this keeps the kernel, the jnp ref
    # oracle and the numpy host-pool path bit-identical
    inv = jnp.where(scale > 0.0, 1.0 / jnp.where(scale > 0.0, scale, 1.0),
                    1.0)
    scale_ref[...] = jnp.full(scale_ref.shape, scale, jnp.float32)
    q_ref[...] = jnp.clip(jnp.rint(x * inv), -127.0, 127.0).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_blocks(blocks: jax.Array, *, interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array]:
    """blocks: (H, K, bs, D) fp -> (q (H, K, bs, D) int8, scales (H, K) f32).

    One grid step per (kv-head, block); the amax reduction and the
    divide/round run on the VPU over the (bs, D) tile.  int8 tiles want
    (32, 128) alignment on real TPUs — block_size >= 32 and head_dim a
    multiple of 128 satisfy it; interpret mode accepts any shape."""
    H, K, bs, D = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(H, K),
        in_specs=[pl.BlockSpec((1, 1, bs, D), lambda h, i: (h, i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, bs, D), lambda h, i: (h, i, 0, 0)),
            pl.BlockSpec((1, 1), lambda h, i: (h, i)),
        ],
    )
    return pl.pallas_call(
        _quant_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((H, K, bs, D), jnp.int8),
            jax.ShapeDtypeStruct((H, K), jnp.float32),
        ],
        interpret=interpret,
    )(blocks)


def _dequant_kernel(q_ref, scale_ref, out_ref):
    scale = scale_ref[0, 0]
    out_ref[...] = q_ref[...].astype(jnp.float32) * scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_blocks(q: jax.Array, scales: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    """q: (H, K, bs, D) int8, scales: (H, K) f32 -> (H, K, bs, D) f32."""
    H, K, bs, D = q.shape
    assert scales.shape == (H, K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(H, K),
        in_specs=[
            pl.BlockSpec((1, 1, bs, D), lambda h, i: (h, i, 0, 0)),
            pl.BlockSpec((1, 1), lambda h, i: (h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bs, D), lambda h, i: (h, i, 0, 0)),
    )
    return pl.pallas_call(
        _dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((H, K, bs, D), jnp.float32),
        interpret=interpret,
    )(q, scales.astype(jnp.float32))


def _dequant_scatter_kernel(dest_ref, q_ref, scale_ref, pool_in_ref,
                            pool_out_ref):
    del pool_in_ref  # aliased with pool_out_ref; unvisited blocks persist
    scale = scale_ref[0, 0]
    pool_out_ref[...] = (q_ref[...].astype(jnp.float32) * scale
                         ).astype(pool_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_scatter_blocks(pool: jax.Array, q: jax.Array,
                              scales: jax.Array, dest_blocks: jax.Array, *,
                              interpret: bool = True) -> jax.Array:
    """Fused dequant + head-major block scatter (quantized FlashH2D restore).

    pool: (H, NB, bs, D) fp device slots; q: (H, K, bs, D) int8 payload;
    scales: (H, K) f32; dest_blocks: (K,) int32 destination block ids.
    Returns the updated pool (aliased in place) — the int8 H2D payload
    dequantizes on the VPU as each (head, block) tile lands, so the
    restore window still sees exactly one fused launch per layer."""
    H, NB, bs, D = pool.shape
    K = dest_blocks.shape[0]
    assert q.shape == (H, K, bs, D)
    assert scales.shape == (H, K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(H, K),
        in_specs=[
            pl.BlockSpec((1, 1, bs, D),
                         lambda h, i, dref: (h, i, 0, 0)),        # q
            pl.BlockSpec((1, 1),
                         lambda h, i, dref: (h, i)),              # scales
            pl.BlockSpec((1, 1, bs, D),
                         lambda h, i, dref: (h, dref[i], 0, 0)),  # pool in
        ],
        out_specs=pl.BlockSpec((1, 1, bs, D),
                               lambda h, i, dref: (h, dref[i], 0, 0)),
    )
    return pl.pallas_call(
        _dequant_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={3: 0},  # pool (arg idx incl. prefetch) -> out 0
        interpret=interpret,
    )(dest_blocks.astype(jnp.int32), q, scales.astype(jnp.float32), pool)
