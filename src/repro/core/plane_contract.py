"""The plane contract — machine-readable invariants of the serving planes.

PRs 2-5 built the staged decode plane and the prefill plane around a small
set of invariants the paper's design depends on (restore-before-use, one
fused FlashD2H/H2D per (layer, group), the one-layer prefill ctx lifetime,
O(L) launches per iteration, bounded retraces, and a fixed collective /
replication layout per sharded stage).  Until now each invariant lived
twice: implicitly in the driver code and explicitly in hand-written test
assertions.  This module is the single declarative home for all of them:

* the **effect vocabulary** (``EFFECT_OF_CALL``) names every data-plane
  call a driver may make and classifies it (launch / d2h / h2d / restore /
  drop / LRU touch / ctx read / layer evict);
* **driver specs** (``DEFAULT_DRIVERS``) name the stage-loop drivers and
  the engine callbacks spliced into them, plus which protocol's rules
  apply to each;
* **registry specs** (``DEFAULT_REGISTRIES``) name the per-stage jit
  registries and the shape-relevant fields their cache keys must cover;
* **sharding rules** (``sharding_rules``) list, per (stage, shard mode),
  the collectives a lowered stage jit may contain and the output tree
  paths allowed to stay sharded (everything else must be pinned
  replicated, e.g. via ``PlaneMesh.replicate``);
* **launch-budget helpers** (``staged_launches_per_iteration`` ...) that
  both ``tests/planeasserts.py`` and the analyzer read, so the runtime
  assertions and the static checks can never drift apart.

``tools/analysis/run.py`` consumes all of the above; see
docs/architecture.md §8 for the prose version of the contract.

Waivers: an intentional deviation is annotated in-source as

    # plane-contract: allow(<rule>) <reason>

on the offending line or the line directly above it.  ``collect_waivers``
parses them; the analyzer reports waived findings but does not fail on
them.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Rule ids
# ---------------------------------------------------------------------------

# pass 1 — stage protocol
RULE_RESTORE_BEFORE_USE = "restore-before-use"
RULE_WRITEBACK_BEFORE_DROP = "writeback-before-drop"
RULE_FUSED_TRANSFER = "fused-transfer"
RULE_CTX_LIFETIME = "ctx-lifetime"
RULE_LAUNCHES = "launches-per-iteration"
RULE_NO_SYNC_IN_DISPATCH_WINDOW = "no-sync-in-dispatch-window"
# pass 2 — retrace hazards
RULE_TRACED_BRANCH = "traced-branch"
RULE_TRACER_COERCION = "tracer-coercion"
RULE_NP_IN_JIT = "np-in-jit"
RULE_OBS_IN_JIT = "no-obs-in-jit"
RULE_UNHASHABLE_KEY = "unhashable-key"
RULE_KEY_MISSING_FIELD = "key-missing-field"
# pass 3 — sharding
RULE_COLLECTIVE = "collective-not-allowed"
RULE_SHARDING_LEAK = "sharding-leak"

ALL_RULES = (
    RULE_RESTORE_BEFORE_USE, RULE_WRITEBACK_BEFORE_DROP,
    RULE_FUSED_TRANSFER, RULE_CTX_LIFETIME, RULE_LAUNCHES,
    RULE_NO_SYNC_IN_DISPATCH_WINDOW,
    RULE_TRACED_BRANCH, RULE_TRACER_COERCION, RULE_NP_IN_JIT,
    RULE_OBS_IN_JIT,
    RULE_UNHASHABLE_KEY, RULE_KEY_MISSING_FIELD,
    RULE_COLLECTIVE, RULE_SHARDING_LEAK,
)

# Roots that identify an obs-layer object in source (pass 2's
# no-obs-in-jit): a call like ``tracer.end(...)`` / ``self.metrics.inc()``
# inside a jitted stage body is a host side effect that fires once at
# trace time and never again — spans silently vanish, counters undercount.
# Instrumentation belongs in the drivers, around the stage launches.
OBS_ROOT_NAMES = frozenset({
    "tracer", "_tracer", "obs", "_obs", "metrics", "_metrics",
    "metrics_registry", "registry",
})

# ---------------------------------------------------------------------------
# Effect vocabulary (pass 1)
# ---------------------------------------------------------------------------

# callee name (the attribute/function a driver calls) -> (kind, sub).
# Kinds: "launch" (jitted stage launch), "d2h" (FlashD2H save; sub "fused"
# or "unfused"), "lru" (KVCacheManager residency touch), "h2d" (fused
# FlashH2D DRAM gather), "restore" (scatter of H2D payloads into device
# slots), "drop" (physical device drop of evicted blocks), "pool-read"
# (device->host readback of freshly appended KV), "ctx-read" (read of the
# one-layer prefill ctx buffer), "layer-evict" (HBM drop of a finished
# prefill layer).
EFFECT_OF_CALL: Dict[str, Tuple[str, str]] = {
    # jitted stage launches (staged decode plane)
    "embed": ("launch", "embed"),
    "select": ("launch", "select"),
    "attend": ("launch", "attend"),
    "_recurrent": ("launch", "recurrent"),
    "logits": ("launch", "logits"),
    # jitted stage launches (prefill plane)
    "attn": ("launch", "prefill-attn"),
    "rec": ("launch", "prefill-rec"),
    "finalize": ("launch", "finalize"),
    "_run_group": ("launch", "prefill-group"),
    # mixed-iteration walk (core/hybrid_plane.py): the hybrid driver runs
    # a layer's prefill groups / the shared finalize through these
    "run_layer": ("launch", "prefill-group"),
    "finish_iteration": ("launch", "finalize"),
    # FlashD2H
    "save_new_tokens_fused": ("d2h", "fused"),
    "save_contiguous": ("d2h", "unfused"),
    # LRU / FlashH2D / device restore
    "access_layer": ("lru", ""),
    "load_blocks_fused": ("h2d", "fused"),
    "restore_blocks_fused": ("restore", "fused"),
    "restore_blocks": ("restore", "unfused"),
    # quantized offload tier (kernels/quant_blocks.py).  The (de)quant
    # kernels are PART of their fused transfer, not transfers themselves —
    # kind "quant" is deliberately outside the d2h/h2d/restore kinds the
    # fused-transfer window counter sums, so a driver fusing
    # quantize into its one FlashD2H save (or dequantize into its one
    # FlashH2D restore) still shows exactly one fused op per layer.
    # dequantize_scatter_blocks IS the restore (quantized
    # scatter_blocks_hkv), so it counts like restore_blocks_fused: a
    # driver issuing both in one window is a double restore.
    "quantize_blocks": ("quant", "d2h"),
    "dequantize_blocks": ("quant", "h2d"),
    "dequantize_scatter_blocks": ("restore", "fused"),
    # eviction
    "drop_blocks": ("drop", "direct"),
    "_drop_pending_evictions": ("drop", "deferred"),
    "drop_layer": ("layer-evict", ""),
    # readbacks.  sub "" = BLOCKING (np.asarray inside), "async" = the
    # dispatch-only variant (returns device arrays / a finisher for the
    # HostStageWorker), "view" = a device-slice view with no transfer
    "new_token_kv": ("pool-read", ""),
    "new_token_kv_async": ("pool-read", "async"),
    "read_group_kv": ("ctx-read", ""),
    "read_group_kv_async": ("ctx-read", "async"),
    "layer_ctx": ("ctx-read", "view"),
    # async write-back staging: the fused FlashD2H is DISPATCHED here (the
    # conversion + save_new_tokens_fused run on the host-stage worker); for
    # ordering rules it sequences exactly like the sync fused save
    "_stage_writeback_async": ("d2h", "fused"),
    "_stage_writeback_async_merged": ("d2h", "fused"),
    # explicit host-blocking device syncs — forbidden inside an async
    # dispatch window (RULE_NO_SYNC_IN_DISPATCH_WINDOW)
    "asarray": ("sync", "host"),
    "block_until_ready": ("sync", "host"),
    "device_get": ("sync", "host"),
    # blocking obs exports (file I/O / full-registry walks / event-list
    # copies) — fine between iterations, forbidden inside an async
    # dispatch window for the same reason (they re-serialize the overlap
    # the pipeline exists to create).  Guarded `tracer.enabled` span
    # emission is NOT in this table: it never blocks.
    "dump_trace": ("sync", "obs"),
    "chrome_trace": ("sync", "obs"),
    "metrics_snapshot": ("sync", "obs"),
    "metrics_prometheus": ("sync", "obs"),
    "prometheus_text": ("sync", "obs"),
}

# ---------------------------------------------------------------------------
# Driver specs (pass 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CallbackSpec:
    """A host callback spliced into a driver's stage loop at its call
    site: ``local_name`` is the parameter the driver calls; file/qualname
    locate the engine-side body the checker inlines there."""
    local_name: str
    file: str
    qualname: str


@dataclasses.dataclass(frozen=True)
class DriverSpec:
    """One stage-loop driver the protocol checker linearizes.

    protocol selects the rule set (see ``PROTOCOL_RULES``);
    batch_iterables are loop-iterable names that range over REQUESTS —
    a jitted launch inside such a loop breaks the O(L) launch budget."""
    name: str
    file: str
    qualname: str
    protocol: str
    callbacks: Tuple[CallbackSpec, ...] = ()
    batch_iterables: Tuple[str, ...] = ()


PROTOCOL_RULES: Dict[str, Tuple[str, ...]] = {
    # the staged decode window: select -> [cb: d2h, lru, h2d, restore,
    # protected drop] -> attend, per attention layer
    "staged-decode": (RULE_RESTORE_BEFORE_USE, RULE_WRITEBACK_BEFORE_DROP,
                      RULE_FUSED_TRANSFER, RULE_LAUNCHES),
    # the prefill (layer, chunk) group window: launch -> [cb: ctx read,
    # fused d2h, end-of-layer pool build + HBM evict]
    "prefill-plane": (RULE_WRITEBACK_BEFORE_DROP, RULE_FUSED_TRANSFER,
                      RULE_CTX_LIFETIME, RULE_LAUNCHES),
    # the single batched launch that executes one group
    "prefill-group": (RULE_FUSED_TRANSFER, RULE_LAUNCHES),
    # the mixed iteration (decode rows + prefill segments in ONE layer
    # walk): the staged-decode window rules AND the prefill ctx/writeback
    # rules apply together — every pass-1 rule covers this driver
    "hybrid-plane": (RULE_RESTORE_BEFORE_USE, RULE_WRITEBACK_BEFORE_DROP,
                     RULE_FUSED_TRANSFER, RULE_CTX_LIFETIME, RULE_LAUNCHES),
    # the ASYNC dispatch windows (stage_dispatch="async", the default):
    # the base window rules still hold — the d2h is dispatched in the
    # same order, fenced at the gather — PLUS nothing in the callback may
    # block on the device (the driver's np.asarray(idx) is the one
    # allowed per-layer sync, and it happens before the callback runs)
    "staged-decode-async": (RULE_RESTORE_BEFORE_USE,
                            RULE_WRITEBACK_BEFORE_DROP,
                            RULE_FUSED_TRANSFER, RULE_LAUNCHES,
                            RULE_NO_SYNC_IN_DISPATCH_WINDOW),
    "hybrid-plane-async": (RULE_RESTORE_BEFORE_USE,
                           RULE_WRITEBACK_BEFORE_DROP,
                           RULE_FUSED_TRANSFER, RULE_CTX_LIFETIME,
                           RULE_LAUNCHES,
                           RULE_NO_SYNC_IN_DISPATCH_WINDOW),
    # fused decode plane: transfers are per-layer fused, but restores land
    # after the forward (restore-before-use deliberately does NOT apply;
    # that is exactly why drop_evicted_device_blocks needs the staged plane)
    "fused-decode": (RULE_FUSED_TRANSFER, RULE_LAUNCHES),
    # legacy per-request executors: only the fusion rule applies (their
    # per-request saves are waived in-source, never silently accepted)
    "legacy": (RULE_FUSED_TRANSFER,),
}


DEFAULT_DRIVERS: Tuple[DriverSpec, ...] = (
    DriverSpec(
        name="staged-decode",
        file="src/repro/core/device_pool.py",
        qualname="DevicePoolPlane.step_staged",
        protocol="staged-decode",
        callbacks=(CallbackSpec(
            "stage_cb", "src/repro/serving/engine.py",
            "ServingEngine._decode_batch_staged.stage_cb_sync"),),
        batch_iterables=("token_by_req", "req_ids", "sts", "rids"),
    ),
    DriverSpec(
        name="staged-decode-async",
        file="src/repro/core/device_pool.py",
        qualname="DevicePoolPlane.step_staged",
        protocol="staged-decode-async",
        callbacks=(CallbackSpec(
            "stage_cb", "src/repro/serving/engine.py",
            "ServingEngine._decode_batch_staged.stage_cb_async"),),
        batch_iterables=("token_by_req", "req_ids", "sts", "rids"),
    ),
    DriverSpec(
        name="prefill-plane",
        file="src/repro/core/prefill_plane.py",
        qualname="PrefillPlane.run_iteration",
        protocol="prefill-plane",
        callbacks=(CallbackSpec(
            "group_cb", "src/repro/serving/engine.py",
            "ServingEngine._prefill_plane_iteration.group_cb"),),
        batch_iterables=("allow", "rids", "req_ids", "g.req_ids"),
    ),
    DriverSpec(
        name="prefill-group",
        file="src/repro/core/prefill_plane.py",
        qualname="PrefillPlane._run_group",
        protocol="prefill-group",
        batch_iterables=("rids", "req_ids"),
    ),
    DriverSpec(
        name="hybrid-plane",
        file="src/repro/core/hybrid_plane.py",
        qualname="HybridPlane.run_iteration",
        protocol="hybrid-plane",
        callbacks=(CallbackSpec(
            "layer_cb", "src/repro/serving/engine.py",
            "ServingEngine._mixed_iteration.layer_cb_sync"),),
        batch_iterables=("token_by_req", "req_ids", "rids", "sts",
                         "allow"),
    ),
    DriverSpec(
        name="hybrid-plane-async",
        file="src/repro/core/hybrid_plane.py",
        qualname="HybridPlane.run_iteration",
        protocol="hybrid-plane-async",
        callbacks=(CallbackSpec(
            "layer_cb", "src/repro/serving/engine.py",
            "ServingEngine._mixed_iteration.layer_cb_async"),),
        batch_iterables=("token_by_req", "req_ids", "rids", "sts",
                         "allow"),
    ),
    DriverSpec(
        name="hybrid-prefill-layer",
        file="src/repro/core/prefill_plane.py",
        qualname="PrefillPlane.run_layer",
        protocol="prefill-group",
        batch_iterables=("rids", "req_ids", "allow"),
    ),
    DriverSpec(
        name="fused-decode-selections",
        file="src/repro/serving/engine.py",
        qualname="ServingEngine._account_selections",
        protocol="fused-decode",
        batch_iterables=("sts", "req_ids"),
    ),
    DriverSpec(
        name="fused-decode-writeback",
        file="src/repro/serving/engine.py",
        qualname="ServingEngine._write_back_new_kv",
        protocol="fused-decode",
        batch_iterables=("sts", "req_ids"),
    ),
    DriverSpec(
        name="legacy-layer-segment",
        file="src/repro/serving/engine.py",
        qualname="ServingEngine._run_layer_segment",
        protocol="legacy",
    ),
    DriverSpec(
        name="legacy-chunked-prefill",
        file="src/repro/serving/engine.py",
        qualname="ServingEngine._run_chunked_prefill",
        protocol="legacy",
    ),
)

# ---------------------------------------------------------------------------
# Registry specs (pass 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegistrySpec:
    """A per-stage jit registry factory whose cache key must (a) cover
    every shape-relevant factory parameter and (b) never key a config /
    mesh object by identity or unhashable value.  ``wrap_required``
    params must not appear as BARE elements of the key (use repr(cfg),
    plane_mesh.key(), ...)."""
    file: str
    factory: str
    required_params: Tuple[str, ...]
    wrap_required: Tuple[str, ...]


DEFAULT_REGISTRIES: Tuple[RegistrySpec, ...] = (
    RegistrySpec("src/repro/core/device_pool.py", "decode_fn_for",
                 ("cfg", "attn_impl"), ("cfg",)),
    RegistrySpec("src/repro/core/device_pool.py", "staged_fns_for",
                 ("cfg", "attn_impl", "plane_mesh"), ("cfg", "plane_mesh")),
    RegistrySpec("src/repro/core/prefill_plane.py", "prefill_fns_for",
                 ("cfg", "plane_mesh"), ("cfg", "plane_mesh")),
    RegistrySpec("src/repro/core/prefill_plane.py", "admit_embed_fns_for",
                 ("cfg",), ("cfg",)),
    RegistrySpec("src/repro/core/hybrid_plane.py", "hybrid_fns_for",
                 ("cfg", "attn_impl", "plane_mesh"), ("cfg", "plane_mesh")),
)

# files whose jit-wrapped stage bodies pass 2 lints (wrap(...)/jax.jit(...)
# call sites); params bound via a defaulted argument (kind=kind) or named
# here are STATIC — everything else is traced inside the body
DEFAULT_JIT_FILES: Tuple[str, ...] = (
    "src/repro/core/device_pool.py",
    "src/repro/core/prefill_plane.py",
    # composes the two registries above — no jit sites of its own today,
    # listed so any future wrap()/jax.jit added there is linted
    "src/repro/core/hybrid_plane.py",
)
STATIC_PARAM_NAMES = frozenset({"self", "cfg", "kind", "stage"})

# ---------------------------------------------------------------------------
# Sharding rules (pass 3)
# ---------------------------------------------------------------------------

# communication primitives; axis_index is positional, not communication,
# and is always allowed
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmax", "pmin", "all_gather", "all_to_all", "ppermute",
    "reduce_scatter", "pbroadcast",
})


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """What a lowered stage jit may do on the mesh: which collectives its
    jaxpr may contain, and which output tree paths may remain sharded
    (every other output must be pinned replicated)."""
    allowed_collectives: frozenset
    sharded_out_paths: Tuple[str, ...]


_NO_COMM = ShardingRules(frozenset(), ())
# the pool cache leaves a sharded select hands back stay sharded by design
_POOL_PATHS = ("'k'", "'v'", "'meta'")

_SHARDING_RULES: Dict[Tuple[str, str], ShardingRules] = {
    # decode select: head mode is communication-free; block mode
    # all-gathers the (tiny) block scores for the redundant global top-k
    ("select", "heads"): ShardingRules(frozenset(), _POOL_PATHS),
    ("select", "blocks"): ShardingRules(frozenset({"all_gather"}),
                                        _POOL_PATHS),
    # decode attend: head mode local; block mode merges flash partials
    # with a logsumexp pmax/psum
    ("attend", "heads"): _NO_COMM,
    ("attend", "blocks"): ShardingRules(frozenset({"pmax", "psum"}), ()),
    # prefill attention: sequence-sharded queries, replicated K/V — the
    # re-gather is a sharding constraint, not an explicit collective
    ("attn", "seq"): _NO_COMM,
}


def sharding_rules(stage: str, mode: str) -> ShardingRules:
    """Contract rules for one registered stage jit.  Stages without an
    entry (embed, logits, recurrent, rec-*, finalize, admit-embed) are
    replicated: no collectives, no sharded outputs."""
    return _SHARDING_RULES.get((stage, mode), _NO_COMM)


def stage_shard_mode(stage: str, cfg, plane_mesh) -> str:
    """Which sharding mode a stage lowers under for (cfg, plane_mesh)."""
    if plane_mesh is None:
        return "none"
    if stage in ("select", "attend"):
        return plane_mesh.pool_shard_mode(cfg)
    if stage == "attn":
        return "seq"
    return "none"

# ---------------------------------------------------------------------------
# Launch budgets (shared by tests/planeasserts.py and the analyzer)
# ---------------------------------------------------------------------------


def staged_launches_per_iteration(cfg) -> int:
    """Jitted launches ONE staged decode iteration issues: embed + logits
    + (select + attend) per attention layer + one per recurrent layer —
    the O(L) budget the stage-protocol checker proves statically and
    ``tests/planeasserts.py`` asserts at runtime."""
    n_attn = cfg.num_attention_layers()
    return 2 + 2 * n_attn + (cfg.num_layers - n_attn)


def mixed_launches_per_iteration(cfg, n_decode_planes: int, n_groups: int,
                                 n_finalize_planes: int) -> int:
    """Jitted launches ONE mixed iteration of the hybrid plane issues:
    every decode plane pays the full staged budget, plus one bucketed
    launch per executed prefill (layer, chunk) group and one shared
    finalize per prefill plane with finished rows.  Independent of how
    many decode ROWS or prefill requests ride each plane — the O(L)
    budget ``tests/planeasserts.assert_mixed_launch_invariant`` checks
    against the engine's measured ``mixed_iter_log``."""
    return (n_decode_planes * staged_launches_per_iteration(cfg)
            + n_groups + n_finalize_planes)


def staged_host_syncs_per_iteration(cfg) -> int:
    """Blocking device->host syncs ONE async staged decode iteration is
    allowed on the dispatch thread: exactly the np.asarray of the
    selection tensor, once per attention layer (zero with DSA off — then
    there is nothing to stage).  Everything else (stripe conversion, DRAM
    staging) runs on the HostStageWorker; the logits readback at sampling
    happens after the iteration's drain and is not a per-layer cost.
    ``tests/planeasserts.assert_host_sync_invariant`` checks the planes'
    measured ``host_syncs`` counters against this."""
    return cfg.num_attention_layers() if cfg.dsa.enabled else 0


# pool-updating stages that must DECLARE buffer donation (donate_argnums
# on the pool/cache argument) so XLA reuses the buffer in place on
# accelerator backends instead of copying a pool per layer per iteration.
# tests/planeasserts.assert_donation_contract checks a live registry's
# StageFns.donated against this.
STAGED_DONATED_STAGES: Dict[str, Tuple[int, ...]] = {
    "select": (2,),             # consumes + returns the layer pool cache
    "recurrent-mamba": (2,),    # consumes + returns the recurrent state
    "recurrent-rwkv": (2,),
}


def staged_stage_kinds(cfg) -> int:
    """Distinct stage kinds of the staged decode pipeline for ``cfg`` —
    the per-shape-bucket trace budget (embed, select, attend, logits, plus
    each recurrent layer kind present)."""
    from repro.models import model as M
    kinds = {M.layer_kind(cfg, i) for i in range(cfg.num_layers)}
    return 4 + len(kinds - {"attn"})


def iter_registries():
    """The live per-stage jit registries, as (registry_name, fns) pairs —
    what the sharding-leak pass lowers.  Imported lazily so the contract
    itself stays import-light."""
    from repro.core import device_pool, prefill_plane
    # NOTE: the hybrid registry (hybrid_plane._HYBRID_FNS) is deliberately
    # absent — it COMPOSES the staged and prefill registries below without
    # adding jits; lowering it here would double-check every stage.
    for name, reg in (("staged", device_pool._STAGED_FNS),
                      ("prefill", prefill_plane._PREFILL_FNS),
                      ("admit-embed", prefill_plane._ADMIT_EMBED_FNS)):
        for fns in reg.values():
            yield name, fns

# ---------------------------------------------------------------------------
# Waivers
# ---------------------------------------------------------------------------

WAIVER_RE = re.compile(
    r"#\s*plane-contract:\s*allow\(([a-z0-9-]+)\)\s*(.*)$")


def collect_waivers(source: str) -> Dict[int, Tuple[str, str]]:
    """{line_number: (rule, reason)} for every waiver comment in a file.
    A waiver applies to findings of that rule on its own line or the line
    directly below (comment-above style)."""
    out: Dict[int, Tuple[str, str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = WAIVER_RE.search(line)
        if m:
            out[i] = (m.group(1), m.group(2).strip())
    return out


def waiver_for(waivers: Dict[int, Tuple[str, str]], rule: str,
               line: int) -> Optional[str]:
    """The reason string if ``rule`` at ``line`` is waived, else None."""
    for at in (line, line - 1):
        hit = waivers.get(at)
        if hit is not None and hit[0] == rule:
            return hit[1]
    return None

# ---------------------------------------------------------------------------
# Analysis targets
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnalysisTarget:
    """Everything one ``tools/analysis/run.py`` invocation analyzes.  The
    default target is the real tree; fixture targets under
    ``tools/analysis/fixtures/`` carry one seeded violation each.

    sharding: None (skip pass 3), "default" (lower the live registries
    populated by a smoke workload), or "<file>:<function>" returning a
    list of ``StageLowering``."""
    name: str
    drivers: Tuple[DriverSpec, ...] = ()
    registries: Tuple[RegistrySpec, ...] = ()
    jit_files: Tuple[str, ...] = ()
    sharding: Optional[str] = None


@dataclasses.dataclass
class StageLowering:
    """One stage jit to abstractly lower and check against the sharding
    contract: ``fn(*args)`` is traced via jax.make_jaxpr (args are
    ShapeDtypeStructs recorded by ``StageFns``)."""
    stage: str
    fn: object
    args: Tuple
    rules: ShardingRules
    file: str
    line: int


DEFAULT_TARGET = AnalysisTarget(
    name="tree",
    drivers=DEFAULT_DRIVERS,
    registries=DEFAULT_REGISTRIES,
    jit_files=DEFAULT_JIT_FILES,
    sharding="default",
)
