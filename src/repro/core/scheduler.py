"""Request scheduler: FCFS continuous batching + hybrid batches +
working-set-aware batch size control (paper §3.3, Algorithm 1).

The base scheduler builds an initial candidate batch under the classic
constraints R_max (requests/batch) and T_max (tokens/batch).  SparseServe
adds M_avl — the available HBM cache capacity — and admits a request only
while the running sum of estimated working sets fits, rejecting (resetting)
the rest.  This prevents HBM cache thrashing: Fig. 1 shows throughput
COLLAPSING when aggregated working sets exceed HBM (21.36x more block loads
going from batch 6 to 12).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.kv_cache import KVGeometry
from repro.core.working_set import (DecodeWorkingSet, estimate_decode_ws_bytes,
                                    estimate_prefill_ws_bytes)
from repro.serving.request import Phase, Request


@dataclasses.dataclass
class SchedulerConfig:
    r_max: int = 64                 # max requests per batch
    t_max: int = 4096               # max tokens per batch
    m_avl_bytes: int = 0            # HBM cache capacity for Algorithm 1 (0 = off)
    prefill_mode: str = "layer_segmented"   # "chunked" | "layer_segmented"
    chunk_size: int = 2048          # chunked-prefill token chunk
    max_inject_tokens: int = 0      # layer-segmented: prefill tokens per batch
                                    # (0 -> chunk_size * num_layers, paper §4.2)
    segment_tokens: int = 0         # layer-segmented: intra-layer chunk size
                                    # (PrefillSegment granularity; 0 = whole
                                    # layers).  Injections are rounded to
                                    # whole segments so the scheduler charges
                                    # exactly the token work the batched
                                    # prefill plane will execute.
    ws_control: bool = True         # working-set-aware admission (WC)


@dataclasses.dataclass
class BatchPlan:
    """What to run this iteration."""
    decode_reqs: List[Request]
    prefill_reqs: List[Tuple[Request, int]]   # (request, tokens to inject)
    total_tokens: int = 0
    rejected: int = 0                          # WS-control rejections
    # Algorithm 1's arbitration record for the MIXED iteration: HBM bytes
    # the admitted decode rows' working sets claim vs the admitted prefill
    # rows' watermark claim (both from estimate_*_ws_bytes; 0 with WS
    # control off).  Their sum is what admission held under m_avl_bytes.
    ws_decode_bytes: int = 0
    ws_prefill_bytes: int = 0


class Scheduler:
    """FCFS hybrid-batching scheduler with Algorithm 1 admission."""

    def __init__(self, cfg: SchedulerConfig, geom: KVGeometry,
                 num_layers: int, top_k_blocks: int,
                 num_attn_layers: Optional[int] = None):
        """num_layers: MODEL layers (token-layer prefill budget).
        num_attn_layers: layers that hold paged KV — the multiplier the
        working-set estimators use.  Defaults to ``geom.num_layers`` (the
        geometry is attention-only in the engine and simulator); for hybrid
        models it must NOT be ``num_layers``, or Algorithm 1's cold-start
        worst case counts recurrent layers that cache nothing."""
        self.cfg = cfg
        self.geom = geom
        self.num_layers = num_layers
        self.num_attn_layers = (geom.num_layers if num_attn_layers is None
                                else num_attn_layers)
        self.top_k_blocks = top_k_blocks
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.working_sets: Dict[str, DecodeWorkingSet] = {}

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        self.waiting.append(req)

    def finish_request(self, req: Request) -> None:
        req.phase = Phase.FINISHED
        if req in self.running:
            self.running.remove(req)
        self.working_sets.pop(req.req_id, None)

    def observe_selection(self, req: Request,
                          selected: List[Tuple[int, int]]) -> None:
        ws = self.working_sets.setdefault(
            req.req_id, DecodeWorkingSet(self.geom, window=12))
        ws.observe(selected)

    def queue_depths(self) -> Tuple[int, int]:
        """(waiting, running) — the obs layer's queue-depth gauges."""
        return len(self.waiting), len(self.running)

    # ------------------------------------------------------------------
    def _estimate_ws(self, req: Request) -> int:
        """estimateWS(req) from Algorithm 1, line 9."""
        if req.phase == Phase.DECODE:
            ws = self.working_sets.setdefault(
                req.req_id, DecodeWorkingSet(self.geom, window=12))
            return estimate_decode_ws_bytes(ws, self.geom, self.top_k_blocks,
                                            self.num_attn_layers)
        # prefill (or waiting about to prefill)
        return estimate_prefill_ws_bytes(self.geom, req.prompt_len,
                                         self.cfg.prefill_mode,
                                         self.num_attn_layers)

    def _initial_batch(self) -> Tuple[List[Request], List[Tuple[Request, int]]]:
        """S.getBatch(R_max, T_max): FCFS decode-first hybrid batching."""
        cfg = self.cfg
        decode = [r for r in self.running if r.phase == Phase.DECODE]
        decode = decode[:cfg.r_max]
        tokens = len(decode)                      # 1 token per decode req
        prefills: List[Tuple[Request, int]] = []
        budget = (cfg.max_inject_tokens
                  if cfg.prefill_mode == "layer_segmented"
                  and cfg.max_inject_tokens > 0
                  else cfg.chunk_size)

        # continue in-flight prefills first, then admit waiting requests
        cand = [r for r in self.running if r.phase == Phase.PREFILL]
        cand += [r for r in self.waiting]
        for r in cand:
            if len(decode) + len(prefills) >= cfg.r_max:
                break
            if cfg.prefill_mode == "layer_segmented":
                # `budget` (maxInjectToken) counts TOKEN-LAYERS: one token
                # through ONE layer.  A chunked-prefill token is L
                # token-layers, so budget B*L == chunk size B (paper §4.2).
                # One iteration may process MULTIPLE layer segments until
                # the budget is consumed.
                if budget <= 0:
                    break
                remaining_total = ((self.num_layers - r.prefill_layer)
                                   * r.prompt_len
                                   - r.prefill_layer_tokens_done)
                inject = min(remaining_total, budget)
                if cfg.segment_tokens > 0:
                    # batched-segment charging: the prefill plane executes
                    # whole (layer, chunk) segments, so round the injection
                    # to segment multiples (at least one segment — the
                    # plane's progress guarantee) and charge that
                    seg = cfg.segment_tokens
                    inject = min(remaining_total,
                                 max(seg, (inject // seg) * seg))
                work = max(1, inject // max(1, self.num_layers))
                if tokens + work > cfg.t_max:
                    break
                tokens += work
            else:
                if tokens >= cfg.t_max:
                    break
                remaining = r.prompt_len - r.prefill_tokens_done
                inject = min(remaining, cfg.chunk_size, cfg.t_max - tokens)
                tokens += inject
            if inject <= 0:
                break
            prefills.append((r, inject))
            budget -= inject
        return decode, prefills

    def schedule(self) -> BatchPlan:
        """Algorithm 1: candidate batch -> WS-aware admission."""
        decode, prefills = self._initial_batch()
        if not self.cfg.ws_control or self.cfg.m_avl_bytes <= 0:
            plan = BatchPlan(decode, prefills)
        else:
            m_used = 0
            ws_d = ws_p = 0
            adm_d: List[Request] = []
            adm_p: List[Tuple[Request, int]] = []
            rejected = 0
            for req in decode:
                m_req = self._estimate_ws(req)
                if m_used + m_req <= self.cfg.m_avl_bytes:
                    adm_d.append(req)
                    m_used += m_req
                    ws_d += m_req
                else:
                    rejected += 1          # S.reset(req): stays queued
            for req, inject in prefills:
                m_req = self._estimate_ws(req)
                if m_used + m_req <= self.cfg.m_avl_bytes:
                    adm_p.append((req, inject))
                    m_used += m_req
                    ws_p += m_req
                else:
                    rejected += 1
            plan = BatchPlan(adm_d, adm_p, rejected=rejected,
                             ws_decode_bytes=ws_d, ws_prefill_bytes=ws_p)

        # promote admitted waiting requests to running/prefill
        for req, _ in plan.prefill_reqs:
            if req.phase == Phase.WAITING:
                req.phase = Phase.PREFILL
                self.waiting.remove(req)
                self.running.append(req)
        plan.total_tokens = (len(plan.decode_reqs)
                             + sum(t for _, t in plan.prefill_reqs))
        return plan
