"""Batched jitted prefill plane — layer-segmented prefill as a subsystem.

Before this module, layer-segmented prefill (paper §3.4) ran per request:
a batch-1 unjitted Python loop over whole layers (`engine._run_layer_segment`)
with one numpy `save_contiguous` host round-trip per layer per request —
while decode had had a persistent jitted plane since PR 2.  `PrefillPlane`
gives prefill the same treatment, mirroring `DevicePoolPlane`:

* **Admission** — a request entering layer-segmented prefill is admitted
  ONCE into a padded plane row carrying its residual stream (`hidden`
  (B_cap, S_cap, d)), per-layer recurrent states (mamba/rwkv), and whisper
  encoder KV; the segment plan from `layer_prefill.plan_segments` becomes
  the row's cursor.  Freed rows are reused lowest-first.
* **Batched layer launches** — each iteration groups every admitted row's
  next `PrefillSegment` by (layer, chunk_start) and runs each group as ONE
  jitted launch over the padded batch (`models.model.prefill_*_batched`):
  `token_mask` marks each row's real tokens, `step_mask` parks rows whose
  request is not scheduled, token windows and batch rows follow
  `BucketingPolicy` buckets, so retraces stay bounded by distinct shape
  signatures (`_PrefillFns.trace_count == len(shape_signatures)`, the same
  cache-hit invariant as the decode plane).
* **Chunked layer segments** — the intra-layer (layer, chunk) steps that
  `plan_segments` emits are EXECUTED here (the legacy executor only ever
  ran whole layers): chunk c of layer l attends to the layer's earlier
  chunks through the plane's one-layer context buffer `ctx_k/ctx_v`, which
  holds at most ONE layer of KV for the whole batch — the paper's prefill
  HBM bound, now per-batch.  The same buffer is what the engine reads for
  the per-group fused FlashD2H save and the end-of-layer pool builds.
* **Finalize** — rows whose last segment ran this iteration share one
  jitted logits launch (`prefill_logits_batched` gathers each row's last
  real position).

The engine drives this plane by default (`EngineConfig.prefill_exec=
"plane"`); the per-request loop survives as `prefill_exec="legacy"`, the
equivalence oracle.  MLA models run whole-layer segments only (their latent
cache has no chunked-context attention path, matching the chunked
baseline's MLA restriction).
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_pool import BucketingPolicy, StageFns
from repro.core.layer_prefill import PrefillSegment
from repro.models import model as M
from repro.obs.tracing import NULL_TRACER


class _PrefillFns(StageFns):
    """Per-stage jits for the batched prefill plane: one ATTENTION-layer
    stage, one stage per recurrent layer kind, and the finalize (logits)
    stage.  Every layer stage takes a LAYER's params pytree, so one trace
    serves all structurally identical layers; ``StageFns`` supplies the
    cache-hit invariant ``trace_count == len(shape_signatures)`` tests
    assert (bounded by stage kinds x shape buckets x chunk offsets, never
    the iteration count)."""

    contract_protocol = "prefill-plane"

    def __init__(self, cfg, plane_mesh=None):
        super().__init__()
        self.cfg = cfg
        self.plane_mesh = plane_mesh
        wrap = self.wrap

        # with a plane_mesh, attention launches run SEQUENCE-SHARDED over
        # the mesh's model axis (model._prefill_attn_layer_batched_cp):
        # only the window's fresh K/V is all-gathered.  Recurrent layers
        # (sequential scans) and MLA layers stay replicated.
        self.attn = wrap(
            "attn",
            lambda p, h, pos, tmask, smask, ctx, enc, qoff:
            M.prefill_attn_layer_batched(
                p, cfg, h, pos, tmask, smask,
                k_ctx=None if ctx is None else ctx[0],
                v_ctx=None if ctx is None else ctx[1],
                q_offset=qoff, enc_kv=enc, plane_mesh=plane_mesh))
        self.rec = {
            kind: wrap("rec-" + kind,
                       lambda p, h, tmask, smask, state, kind=kind:
                       M.prefill_recurrent_layer_batched(
                           p, cfg, kind, h, tmask, smask, state))
            for kind in ("mamba", "rwkv")}
        self.finalize = wrap(
            "finalize",
            lambda params, h, tok_len:
            M.prefill_logits_batched(params, cfg, h, tok_len))


# keyed structurally like device_pool's registries so value-equal configs
# share one compile cache across engines
_PREFILL_FNS: Dict[Any, _PrefillFns] = {}


def prefill_fns_for(cfg, plane_mesh=None) -> _PrefillFns:
    key = (repr(cfg), None if plane_mesh is None else plane_mesh.key())
    if key not in _PREFILL_FNS:
        _PREFILL_FNS[key] = _PrefillFns(cfg, plane_mesh)
    return _PREFILL_FNS[key]


class _AdmitEmbedFns(StageFns):
    """ONE jitted bucketed embedding launch for a whole admission batch.

    Admission used to embed eagerly one request at a time (one lookup
    launch per admitted request per iteration); the engine now collects
    every pure-text request admitted in an iteration, pads the token ids
    to (batch bucket, token bucket), and runs this single stage —
    ``trace_count == len(shape_signatures)`` bounds compiles by the bucket
    grid, independent of how many requests arrive together."""

    contract_protocol = "admit-embed"

    def __init__(self, cfg):
        super().__init__()
        self.cfg = cfg
        self.embed = self.wrap(
            "admit-embed", lambda params, tokens: params["embed"][tokens])


_ADMIT_EMBED_FNS: Dict[str, _AdmitEmbedFns] = {}


def admit_embed_fns_for(cfg) -> _AdmitEmbedFns:
    key = repr(cfg)
    if key not in _ADMIT_EMBED_FNS:
        _ADMIT_EMBED_FNS[key] = _AdmitEmbedFns(cfg)
    return _ADMIT_EMBED_FNS[key]


@dataclasses.dataclass
class PrefillGroupRun:
    """One executed batched launch: every scheduled row whose next segment
    was (layer, chunk_start), padded to `chunk_cap` tokens."""
    layer: int
    kind: str                               # 'attn' | 'mamba' | 'rwkv'
    chunk_start: int
    chunk_cap: int                          # bucketed token window
    req_ids: List[str]
    segs: Dict[str, PrefillSegment]


@dataclasses.dataclass
class PrefillIterationResult:
    groups: List[PrefillGroupRun]
    finished: List[str]                     # rows whose LAST segment ran
    logits: Optional[jax.Array]             # (B_cap, V) when any finished
    peaks: Dict[str, int]                   # per-row peak resident KV tokens
                                            # of the CURRENT attention layer
                                            # (HBM watermark, token units;
                                            # recurrent layers hold no paged
                                            # KV and count 0)


@dataclasses.dataclass
class PrefillWalk:
    """Budget/progress state of ONE mixed-iteration walk over a plane
    (``begin_iteration`` -> ``run_layer`` per model layer ->
    ``finish_iteration``) — exactly what ``run_iteration``'s pass loop
    keeps in locals."""
    allow: Dict[str, int]
    ran: set = dataclasses.field(default_factory=set)
    finished: List[str] = dataclasses.field(default_factory=list)
    peaks: Dict[str, int] = dataclasses.field(default_factory=dict)
    groups: List[PrefillGroupRun] = dataclasses.field(default_factory=list)


class PrefillPlane:
    """Persistent padded prefill state for one group of batched requests.

    Requests whose whisper encoder KV shapes agree (the engine's prefill
    group key) share one plane.  The plane owns the rows' residual stream
    and recurrent states for the duration of prefill; the engine reads
    per-layer KV out of the context buffer (fused D2H saves + pool builds)
    and extracts recurrent states at finalize."""

    def __init__(self, cfg, policy: Optional[BucketingPolicy] = None,
                 plane_mesh=None):
        self.cfg = cfg
        self.policy = policy or BucketingPolicy()
        self.plane_mesh = plane_mesh
        self.fns = prefill_fns_for(cfg, plane_mesh)
        self.b_cap = 0
        self.s_cap = 0
        self.hidden: Optional[jax.Array] = None      # (B_cap, S_cap, d)
        self.ctx_k: Optional[jax.Array] = None       # (B_cap, S_cap, Hkv, hd)
        self.ctx_v: Optional[jax.Array] = None       # None for MLA
        self.rec: Optional[List[Any]] = None         # per model layer
        self.enc: Optional[List[Tuple[jax.Array, jax.Array]]] = None
        self._tok_len: Optional[jax.Array] = None    # (B_cap,) int32
        self.rows: Dict[str, int] = {}
        self.tok_len: Dict[str, int] = {}            # host mirror
        self.segments: Dict[str, List[PrefillSegment]] = {}
        self.next_idx: Dict[str, int] = {}
        self._free: List[int] = []
        self._ever_used: set = set()
        self._layer_params_cache: Optional[Tuple[Dict, List[Dict]]] = None
        # counters (bench_prefill / tests)
        self.admits = 0
        self.rows_reused = 0
        self.launches = 0                   # batched layer launches, total
        self.chunk_launches = 0             # launches with chunk_start > 0
        self.finalize_launches = 0
        self.iterations = 0
        self.buckets_seen: set = set()      # (b_cap, chunk_cap) launched at
        self.tracer = NULL_TRACER           # engine installs a live Tracer
                                            # when EngineConfig.obs is on

    # -- params ------------------------------------------------------------

    def _layer_params(self, params: Dict) -> List[Dict]:
        """Per-layer param slices, computed once per params object (same
        caching rationale as ``DevicePoolPlane._layer_params``)."""
        hit = self._layer_params_cache
        if hit is not None and hit[0] is params:
            return hit[1]
        layers = [M.get_layer(params, i) for i in range(self.cfg.num_layers)]
        self._layer_params_cache = (params, layers)
        return layers

    # -- capacity ----------------------------------------------------------

    def _pad_rows(self, v, db):
        return jnp.pad(v, ((0, db),) + ((0, 0),) * (v.ndim - 1))

    def _pad_rows_tokens(self, v, db, ds):
        return jnp.pad(v, ((0, db), (0, ds)) + ((0, 0),) * (v.ndim - 2))

    def _ensure_capacity(self, need_rows: int, need_tokens: int,
                         template_h: jax.Array) -> None:
        b_cap = max(self.b_cap, self.policy.bucket_batch(need_rows))
        s_cap = max(self.s_cap, self.policy.bucket_tokens(need_tokens))
        if self.hidden is None:
            d = template_h.shape[-1]
            self.hidden = jnp.zeros((b_cap, s_cap, d), template_h.dtype)
            self._tok_len = jnp.zeros((b_cap,), jnp.int32)
            self.rec = M._init_rec_states(self.cfg, b_cap, template_h.dtype)
            self._free = list(range(b_cap))
        elif b_cap != self.b_cap or s_cap != self.s_cap:
            db, ds = b_cap - self.b_cap, s_cap - self.s_cap
            self.hidden = self._pad_rows_tokens(self.hidden, db, ds)
            self._tok_len = self._pad_rows(self._tok_len, db)
            if self.ctx_k is not None:
                self.ctx_k = self._pad_rows_tokens(self.ctx_k, db, ds)
            if self.ctx_v is not None:
                self.ctx_v = self._pad_rows_tokens(self.ctx_v, db, ds)
            self.rec = [None if s is None
                        else jax.tree.map(lambda x: self._pad_rows(x, db), s)
                        for s in self.rec]
            if self.enc is not None:
                self.enc = [tuple(self._pad_rows(a, db) for a in kv)
                            for kv in self.enc]
            for r in range(self.b_cap, b_cap):
                bisect.insort(self._free, r)
        self.b_cap, self.s_cap = b_cap, s_cap

    def _ensure_ctx(self, kv_tail_shapes: Tuple) -> None:
        """Lazily allocate the one-layer KV context buffer from the first
        launch's output shapes ((Hkv, hd) for GQA, (lat,) for MLA)."""
        if self.ctx_k is not None:
            return
        k_tail, v_tail = kv_tail_shapes
        self.ctx_k = jnp.zeros((self.b_cap, self.s_cap) + k_tail, jnp.float32)
        if v_tail is not None:
            self.ctx_v = jnp.zeros((self.b_cap, self.s_cap) + v_tail,
                                   jnp.float32)

    # -- slot lifecycle ----------------------------------------------------

    def admit(self, req_id: str, h: jax.Array,
              segments: List[PrefillSegment],
              enc_kvs: Optional[List[Tuple[jax.Array, jax.Array]]] = None
              ) -> int:
        """Admit one request: copy its embedded residual stream (1, S, d)
        into a free row, zero the row's recurrent states, and install its
        segment plan.  The only full-stream copy in the request's prefill
        lifetime."""
        if req_id in self.rows:
            raise ValueError(f"{req_id} already admitted")
        S = int(h.shape[1])
        self._ensure_capacity(len(self.rows) + 1, S, h)
        row = self._free.pop(0)
        if row in self._ever_used:
            self.rows_reused += 1
        self._ever_used.add(row)
        self.hidden = self.hidden.at[row].set(0).at[row, :S].set(h[0])
        self._tok_len = self._tok_len.at[row].set(S)
        for l, s in enumerate(self.rec):
            if s is not None:
                self.rec[l] = jax.tree.map(lambda x: x.at[row].set(0), s)
        if enc_kvs is not None:
            if self.enc is None:
                self.enc = [tuple(jnp.zeros((self.b_cap,) + a.shape[1:],
                                            a.dtype) for a in kv)
                            for kv in enc_kvs]
            self.enc = [tuple(dst.at[row].set(src[0])
                              for dst, src in zip(self.enc[l], enc_kvs[l]))
                        for l in range(len(self.enc))]
        self.rows[req_id] = row
        self.tok_len[req_id] = S
        self.segments[req_id] = list(segments)
        self.next_idx[req_id] = 0
        self.admits += 1
        return row

    def release(self, req_id: str) -> int:
        """Free a finished request's row for reuse."""
        row = self.rows.pop(req_id)
        self.tok_len.pop(req_id)
        self.segments.pop(req_id)
        self.next_idx.pop(req_id)
        bisect.insort(self._free, row)
        return row

    def done(self, req_id: str) -> bool:
        return self.next_idx[req_id] >= len(self.segments[req_id])

    # -- iteration ---------------------------------------------------------

    def run_iteration(self, params: Dict, allowance: Dict[str, int],
                      group_cb=None) -> PrefillIterationResult:
        """Run one engine iteration's worth of prefill segments.

        allowance: per-request token budget for this iteration (within-layer
        token units — one chunk costs its chunk_len).  Every scheduled
        request runs AT LEAST one segment (progress guarantee, like the
        legacy executor's >=1 whole layer per iteration); beyond that,
        segments run while the budget lasts.  Each pass groups the rows'
        next segments by (layer, chunk_start) and runs each group as ONE
        jitted launch; a request's segments always execute in plan order.

        group_cb(group) runs right after each launch — the window in which
        the engine must read the group's KV out of the ONE-layer context
        buffer (fused FlashD2H save, end-of-layer pool build): the next
        layer's launch overwrites it.
        """
        allow = {rid: int(a) for rid, a in allowance.items()
                 if rid in self.rows}
        ran: set = set()
        finished: List[str] = []
        peaks: Dict[str, int] = {}
        groups: List[PrefillGroupRun] = []
        while True:
            pending: Dict[Tuple[int, int], List[str]] = {}
            for rid in sorted(allow, key=lambda r: self.rows[r]):
                idx = self.next_idx[rid]
                segs = self.segments[rid]
                if idx >= len(segs):
                    continue
                if allow[rid] <= 0 and rid in ran:
                    continue
                seg = segs[idx]
                pending.setdefault((seg.layer, seg.chunk_start),
                                   []).append(rid)
            if not pending:
                break
            for key in sorted(pending):
                layer, start = key
                rids = pending[key]
                g = self._run_group(params, layer, start, rids)
                groups.append(g)
                if group_cb is not None:
                    group_cb(g)
                for rid in rids:
                    seg = g.segs[rid]
                    allow[rid] -= seg.chunk_len
                    ran.add(rid)
                    self.next_idx[rid] += 1
                    if g.kind == "attn":
                        # only attention layers hold paged KV; recurrent
                        # segments contribute nothing to the watermark
                        peaks[rid] = max(peaks.get(rid, 0),
                                         seg.chunk_start + seg.chunk_len)
                    if seg.is_last:
                        finished.append(rid)
        # idle resident rows still hold their partially-built layer's KV
        for rid, resident in self.resident_tokens().items():
            peaks[rid] = max(peaks.get(rid, 0), resident)
        logits = None
        if finished:
            logits = self.fns.finalize(params, self.hidden, self._tok_len)
            self.finalize_launches += 1
        self.iterations += 1
        return PrefillIterationResult(groups=groups, finished=finished,
                                      logits=logits, peaks=peaks)

    # -- mixed-iteration walk (core.hybrid_plane) --------------------------

    def begin_iteration(self, allowance: Dict[str, int]) -> "PrefillWalk":
        """Open a mixed-iteration walk over this plane's rows.  The hybrid
        driver (``core.hybrid_plane``) visits model layers 0..L-1 ONCE per
        engine iteration, calling ``run_layer`` at each; the walk carries
        the same per-request budget/progress state ``run_iteration``'s pass
        loop keeps, so both schemes execute the identical segment set."""
        return PrefillWalk(allow={rid: int(a) for rid, a in allowance.items()
                                  if rid in self.rows})

    def run_layer(self, params: Dict, layer: int,
                  walk: "PrefillWalk") -> List[PrefillGroupRun]:
        """Run every segment the walk owes at ``layer``: rows whose NEXT
        segment sits at this layer are grouped by chunk_start (one jitted
        bucketed launch per group, same as ``run_iteration``) and chunks
        execute in plan order until no scheduled row is pending here.
        Because ``plan_segments`` emits segments in non-decreasing layer
        order, exhausting each layer in the ascending walk executes exactly
        the segments ``run_iteration``'s multi-pass loop would."""
        out: List[PrefillGroupRun] = []
        while True:
            pending: Dict[int, List[str]] = {}
            for rid in sorted(walk.allow, key=lambda r: self.rows[r]):
                idx = self.next_idx[rid]
                segs = self.segments[rid]
                if idx >= len(segs):
                    continue
                if walk.allow[rid] <= 0 and rid in walk.ran:
                    continue
                seg = segs[idx]
                if seg.layer != layer:
                    continue
                pending.setdefault(seg.chunk_start, []).append(rid)
            if not pending:
                break
            for start in sorted(pending):
                rids = pending[start]
                g = self._run_group(params, layer, start, rids)
                out.append(g)
                walk.groups.append(g)
                for rid in rids:
                    seg = g.segs[rid]
                    walk.allow[rid] -= seg.chunk_len
                    walk.ran.add(rid)
                    self.next_idx[rid] += 1
                    if g.kind == "attn":
                        walk.peaks[rid] = max(walk.peaks.get(rid, 0),
                                              seg.chunk_start + seg.chunk_len)
                    if seg.is_last:
                        walk.finished.append(rid)
        return out

    def finish_iteration(self, params: Dict,
                         walk: "PrefillWalk") -> PrefillIterationResult:
        """Close a mixed-iteration walk: book idle residency into the
        peaks, run the shared finalize (logits) launch for rows whose last
        segment ran, and bump the iteration counter — the same epilogue
        ``run_iteration`` performs after its pass loop."""
        for rid, resident in self.resident_tokens().items():
            walk.peaks[rid] = max(walk.peaks.get(rid, 0), resident)
        logits = None
        if walk.finished:
            logits = self.fns.finalize(params, self.hidden, self._tok_len)
            self.finalize_launches += 1
        self.iterations += 1
        return PrefillIterationResult(groups=walk.groups,
                                      finished=walk.finished,
                                      logits=logits, peaks=walk.peaks)

    def _run_group(self, params: Dict, layer: int, start: int,
                   rids: List[str]) -> PrefillGroupRun:
        cfg = self.cfg
        kind = M.layer_kind(cfg, layer)
        tr = self.tracer
        if tr.enabled:
            _ts = time.perf_counter()
        segs = {rid: self.segments[rid][self.next_idx[rid]] for rid in rids}
        t_cap = min(self.policy.bucket_tokens(
            max(s.chunk_len for s in segs.values())), self.s_cap - start)
        smask = np.zeros((self.b_cap,), bool)
        tmask = np.zeros((self.b_cap, t_cap), bool)
        for rid in rids:
            row = self.rows[rid]
            smask[row] = True
            tmask[row, :segs[rid].chunk_len] = True
        smask_j = jnp.asarray(smask)
        tmask_j = jnp.asarray(tmask)
        h_win = self.hidden[:, start:start + t_cap]
        p_l = self._layer_params(params)[layer]
        if kind == "attn":
            pos_win = jnp.broadcast_to(
                jnp.arange(start, start + t_cap, dtype=jnp.int32),
                (self.b_cap, t_cap))
            ctx = None
            if start > 0:
                if cfg.attention_type == "mla":
                    raise NotImplementedError(
                        "chunked layer segments are not supported for MLA "
                        "models (no latent-context attention path); plan "
                        "whole-layer segments")
                ctx = (self.ctx_k[:, :start], self.ctx_v[:, :start])
            enc = self.enc[layer] if self.enc is not None else None
            h_out, kv_out = self.fns.attn(
                p_l, h_win, pos_win, tmask_j, smask_j, ctx, enc,
                jnp.asarray(start, jnp.int32))
            rows_arr = jnp.asarray([self.rows[r] for r in rids], jnp.int32)
            if cfg.attention_type == "mla":
                (latent,) = kv_out
                self._ensure_ctx((latent.shape[2:], None))
                self.ctx_k = self.ctx_k.at[rows_arr, start:start + t_cap].set(
                    latent[rows_arr].astype(self.ctx_k.dtype))
            else:
                k, v = kv_out
                self._ensure_ctx((k.shape[2:], v.shape[2:]))
                self.ctx_k = self.ctx_k.at[rows_arr, start:start + t_cap].set(
                    k[rows_arr].astype(self.ctx_k.dtype))
                self.ctx_v = self.ctx_v.at[rows_arr, start:start + t_cap].set(
                    v[rows_arr].astype(self.ctx_v.dtype))
        else:
            h_out, new_state = self.fns.rec[kind](
                p_l, h_win, tmask_j, smask_j, self.rec[layer])
            self.rec[layer] = new_state
        self.hidden = self.hidden.at[:, start:start + t_cap].set(h_out)
        self.launches += 1
        if start > 0:
            self.chunk_launches += 1
        self.buckets_seen.add((self.b_cap, t_cap))
        if tr.enabled:
            tr.end("prefill-group", "prefill", _ts, layer=layer,
                   chunk_start=start, chunk_cap=t_cap, rows=len(rids),
                   kind=kind)
        return PrefillGroupRun(layer=layer, kind=kind, chunk_start=start,
                               chunk_cap=t_cap, req_ids=list(rids),
                               segs=segs)

    def resident_tokens(self) -> Dict[str, int]:
        """Per-row tokens of CURRENT-layer attention KV held right now —
        the residency a row carries BETWEEN iterations (mid-layer chunk
        progress).  A row whose next segment is chunk c of attention layer
        l holds chunks 0..c-1 (= chunk_start tokens) in the one-layer ctx
        buffer; a row parked before a recurrent layer (or before chunk 0)
        holds nothing — the previous layer was already saved and evicted.
        The engine sums this over every admitted row of every plane (also
        the ones with no scheduled request this iteration) for the batched
        HBM watermark."""
        out: Dict[str, int] = {}
        for rid in self.rows:
            idx = self.next_idx[rid]
            segs = self.segments[rid]
            resident = 0
            if idx < len(segs):
                seg = segs[idx]
                if M.layer_kind(self.cfg, seg.layer) == "attn":
                    resident = seg.chunk_start
            out[rid] = resident
        return out

    # -- data plane readbacks ---------------------------------------------

    def read_group_kv(self, g: PrefillGroupRun
                      ) -> Dict[str, Tuple[np.ndarray,
                                           Optional[np.ndarray]]]:
        """Read the KV stripes a batched ATTENTION launch just produced —
        the FlashD2H phase-1 source: ONE fused device->host readback per
        group, covering every request in the launch.  Returns
        {req_id: (k (Hkv, T, D), v | None)} trimmed to each row's real
        chunk length (MLA: the single latent head)."""
        rows = jnp.asarray([self.rows[r] for r in g.req_ids], jnp.int32)
        sl = slice(g.chunk_start, g.chunk_start + g.chunk_cap)
        k_all = np.asarray(self.ctx_k[rows, sl])
        v_all = (np.asarray(self.ctx_v[rows, sl])
                 if self.ctx_v is not None else None)
        out: Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]] = {}
        for i, rid in enumerate(g.req_ids):
            clen = g.segs[rid].chunk_len
            if k_all.ndim == 3:                    # MLA latent: (R, T, lat)
                k = k_all[i, :clen][None, :, :]    # -> (1, T, lat)
                v = None
            else:                                  # (R, T, Hkv, hd)
                k = np.transpose(k_all[i, :clen], (1, 0, 2))
                v = np.transpose(v_all[i, :clen], (1, 0, 2))
            out[rid] = (k, v)
        return out

    def read_group_kv_async(self, g: PrefillGroupRun):
        """Dispatch the group's fused KV stripe gather WITHOUT a host sync
        and return a zero-arg *finisher*.  The gather (a queued device op
        on value-snapshotted ctx buffers) starts immediately; calling the
        finisher — on the ``HostStageWorker`` — pays the blocking
        ``np.asarray`` plus the per-request trim/transpose and returns
        exactly what ``read_group_kv`` would have."""
        rows = jnp.asarray([self.rows[r] for r in g.req_ids], jnp.int32)
        sl = slice(g.chunk_start, g.chunk_start + g.chunk_cap)
        k_dev = self.ctx_k[rows, sl]
        v_dev = self.ctx_v[rows, sl] if self.ctx_v is not None else None
        req_ids = list(g.req_ids)
        chunk_lens = {rid: g.segs[rid].chunk_len for rid in req_ids}

        def finish() -> Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]]:
            k_all = np.asarray(k_dev)
            v_all = None if v_dev is None else np.asarray(v_dev)
            out: Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]] = {}
            for i, rid in enumerate(req_ids):
                clen = chunk_lens[rid]
                if k_all.ndim == 3:                # MLA latent: (R, T, lat)
                    k = k_all[i, :clen][None, :, :]
                    v = None
                else:                              # (R, T, Hkv, hd)
                    k = np.transpose(k_all[i, :clen], (1, 0, 2))
                    v = np.transpose(v_all[i, :clen], (1, 0, 2))
                out[rid] = (k, v)
            return out
        return finish

    def layer_ctx(self, req_id: str) -> Tuple:
        """The request's completed CURRENT-layer KV (kv_out form, B=1) —
        what the engine turns into the layer's paged decode pool at the end
        of the layer.  GQA: (k, v) each (1, S, Hkv, hd); MLA: (latent,)."""
        row = self.rows[req_id]
        S = self.tok_len[req_id]
        if self.ctx_v is None:
            return (self.ctx_k[row:row + 1, :S],)
        return (self.ctx_k[row:row + 1, :S], self.ctx_v[row:row + 1, :S])

    def rec_state(self, req_id: str, layer: int):
        """One row's layer recurrent state (B=1) — decode-state assembly at
        finalize."""
        row = self.rows[req_id]
        return jax.tree.map(lambda x: x[row:row + 1], self.rec[layer])

    def device_bytes(self) -> int:
        leaves = [self.hidden, self.ctx_k, self.ctx_v, self._tok_len]
        if self.rec is not None:
            leaves += jax.tree.leaves(self.rec)
        if self.enc is not None:
            leaves += jax.tree.leaves(self.enc)
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in leaves if leaf is not None)
