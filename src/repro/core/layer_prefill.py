"""Layer-segmented prefill planner (paper §3.4).

Prefill is divided into LAYER segments processed in separate hybrid batches.
After layer *l* runs over the whole prompt, its KV blocks are saved to DRAM
(FlashD2H) and immediately evicted from HBM — the prefill HBM footprint is
bounded by ONE layer of KV at all times.  The residual-stream activations
(B, S, d) are carried between iterations to resume at layer l+1.

If one layer over the whole prompt would exceed the TBT SLO, the layer is
further split into token chunks ("combination with chunked prefill") —
``plan_segments`` emits (layer, chunk) steps; chunk c of layer l attends to
chunks 0..c of the SAME layer, so the per-layer KV context is still bounded
to one layer.

``max_inject_tokens`` follows the paper's fairness convention (§4.2): to
inject the same total token work per iteration as chunked prefill with
chunk size B, set max_inject_tokens = B * L.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class PrefillSegment:
    layer: int
    chunk_start: int      # token offset within the prompt
    chunk_len: int
    is_last_chunk_of_layer: bool
    is_last: bool         # final segment of the whole prefill


def plan_segments(prompt_len: int, num_layers: int,
                  max_tokens_per_step: int) -> List[PrefillSegment]:
    """Static plan of all (layer, chunk) prefill steps for one request.

    max_tokens_per_step bounds the tokens processed in a single batch
    (derived from maxInjectToken / TBT SLO).  If >= prompt_len, each layer
    is one segment (pure layer-segmented prefill)."""
    chunk = min(max(1, max_tokens_per_step), prompt_len)
    n_chunks = math.ceil(prompt_len / chunk)
    segs: List[PrefillSegment] = []
    for l in range(num_layers):
        for c in range(n_chunks):
            start = c * chunk
            clen = min(chunk, prompt_len - start)
            segs.append(PrefillSegment(
                layer=l, chunk_start=start, chunk_len=clen,
                is_last_chunk_of_layer=(c == n_chunks - 1),
                is_last=(l == num_layers - 1 and c == n_chunks - 1)))
    return segs


@dataclasses.dataclass
class LayerPrefillState:
    """Mutable per-request execution cursor + carried activations.

    hidden: residual stream after the last completed layer (host-side
    between iterations; the paper saves activation states the same way)."""
    segments: List[PrefillSegment]
    next_idx: int = 0
    hidden: Optional[object] = None          # (B, S, d) array
    positions: Optional[object] = None
    enc_kvs: Optional[object] = None         # whisper cross-attn KV
    rec_states: Optional[list] = None        # mamba/rwkv per-layer states

    @property
    def done(self) -> bool:
        return self.next_idx >= len(self.segments)

    def peek(self) -> PrefillSegment:
        return self.segments[self.next_idx]

    def advance(self) -> PrefillSegment:
        seg = self.segments[self.next_idx]
        self.next_idx += 1
        return seg


def segment_tokens_for_iteration(prompt_len: int, num_layers: int,
                                 max_inject_tokens: int) -> int:
    """How many prompt tokens one iteration may process.

    Layer-segmented prefill touches `prompt_len` tokens per layer step but
    only ONE layer — its per-iteration compute equals prompt_len tokens of
    one layer.  Normalised to whole-model token work it is
    prompt_len / num_layers; the paper's maxInjectToken bounds exactly this
    so that layer-segmented and chunked prefill inject equal work."""
    whole_model_tokens = max(1, max_inject_tokens)
    per_layer_tokens = whole_model_tokens * num_layers
    return min(prompt_len, per_layer_tokens)


def hbm_footprint_tokens(prompt_len: int, mode: str, num_layers: int,
                         tokens_done: int = 0) -> int:
    """Token-layer units of KV resident in HBM during prefill (Fig. 16a
    rationale).  chunked: tokens_done * L grows; layer-segmented: <= prompt
    tokens of ONE layer."""
    if mode == "chunked":
        return tokens_done * num_layers
    return prompt_len
