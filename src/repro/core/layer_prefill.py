"""Layer-segmented prefill planner (paper §3.4).

Prefill is divided into LAYER segments processed in separate hybrid batches.
After layer *l* runs over the whole prompt, its KV blocks are saved to DRAM
(FlashD2H) and immediately evicted from HBM — the prefill HBM footprint is
bounded by ONE layer of KV at all times.  The residual-stream activations
(B, S, d) are carried between iterations to resume at layer l+1.

If one layer over the whole prompt would exceed the TBT SLO, the layer is
further split into token chunks ("combination with chunked prefill") —
``plan_segments`` emits (layer, chunk) steps; chunk c of layer l attends to
chunks 0..c of the SAME layer, so the per-layer KV context is still bounded
to one layer.  The batched prefill plane (``repro.core.prefill_plane``)
executes these chunked segments (``EngineConfig.prefill_max_tokens_per_step``
sets the granularity); the legacy per-request executor runs whole layers
only, which is why the engine plans whole-layer segments for it.

``max_inject_tokens`` follows the paper's fairness convention (§4.2): to
inject the same total token work per iteration as chunked prefill with
chunk size B, set max_inject_tokens = B * L.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class PrefillSegment:
    layer: int
    chunk_start: int      # token offset within the prompt
    chunk_len: int
    is_last_chunk_of_layer: bool
    is_last: bool         # final segment of the whole prefill


def plan_segments(prompt_len: int, num_layers: int,
                  max_tokens_per_step: int) -> List[PrefillSegment]:
    """Static plan of all (layer, chunk) prefill steps for one request.

    max_tokens_per_step bounds the tokens processed in a single batch
    (derived from maxInjectToken / TBT SLO).  If >= prompt_len, each layer
    is one segment (pure layer-segmented prefill)."""
    chunk = min(max(1, max_tokens_per_step), prompt_len)
    n_chunks = math.ceil(prompt_len / chunk)
    segs: List[PrefillSegment] = []
    for l in range(num_layers):
        for c in range(n_chunks):
            start = c * chunk
            clen = min(chunk, prompt_len - start)
            segs.append(PrefillSegment(
                layer=l, chunk_start=start, chunk_len=clen,
                is_last_chunk_of_layer=(c == n_chunks - 1),
                is_last=(l == num_layers - 1 and c == n_chunks - 1)))
    return segs


@dataclasses.dataclass
class LayerPrefillState:
    """Mutable per-request execution cursor + carried activations.

    hidden: residual stream after the last completed layer (host-side
    between iterations; the paper saves activation states the same way)."""
    segments: List[PrefillSegment]
    next_idx: int = 0
    hidden: Optional[object] = None          # (B, S, d) array
    positions: Optional[object] = None
    enc_kvs: Optional[object] = None         # whisper cross-attn KV
    rec_states: Optional[list] = None        # mamba/rwkv per-layer states

    @property
    def done(self) -> bool:
        return self.next_idx >= len(self.segments)

    def peek(self) -> PrefillSegment:
        return self.segments[self.next_idx]

    def advance(self) -> PrefillSegment:
        seg = self.segments[self.next_idx]
        self.next_idx += 1
        return seg


def segment_tokens_for_iteration(prompt_len: int, num_layers: int,
                                 max_inject_tokens: int) -> int:
    """How many prompt tokens one iteration may process.

    Layer-segmented prefill touches `prompt_len` tokens per layer step but
    only ONE layer — its per-iteration compute equals prompt_len tokens of
    one layer.  Normalised to whole-model token work it is
    prompt_len / num_layers; the paper's maxInjectToken bounds exactly this
    so that layer-segmented and chunked prefill inject equal work."""
    whole_model_tokens = max(1, max_inject_tokens)
    per_layer_tokens = whole_model_tokens * num_layers
    return min(prompt_len, per_layer_tokens)


def hbm_footprint_tokens(prompt_len: int, mode: str, num_layers: int,
                         tokens_done: int = 0,
                         layer_tokens_resident: Optional[int] = None) -> int:
    """Token-layer units of KV ONE request holds in HBM during prefill
    (Fig. 16a rationale).

    chunked: every processed token's KV of ALL layers stays resident —
    ``tokens_done * num_layers``, growing with progress.

    layer_segmented: only the CURRENT layer's KV is resident — at most
    ``prompt_len`` token-layers (the one-layer bound).
    ``layer_tokens_resident`` is the measured number of prompt tokens whose
    KV of the current layer is live (the prefill plane reports its per-row
    within-iteration peak); omitted, the bound itself is returned (the
    legacy whole-layer executor holds exactly the full layer while a
    segment runs).

    The serving engine SUMS this over every request with live prefill state
    each iteration and maxes the sums into
    ``ServingEngine.prefill_hbm_peak_tokens`` — a real batched per-iteration
    watermark for both modes, not a per-request recording."""
    if mode == "chunked":
        return tokens_done * num_layers
    if layer_tokens_resident is None:
        return prompt_len
    return min(layer_tokens_resident, prompt_len)
