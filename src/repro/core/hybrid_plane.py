"""Unified hybrid-batching plane — prefill + decode in ONE mixed iteration.

Before this module the engine ran its two jitted planes back to back inside
``step``: every staged decode group walked all L layers
(``DevicePoolPlane.step_staged``), then every ``PrefillPlane`` ran its own
(layer, chunk) pass loop — decode rows idled while prefill segments
launched and vice versa, and each plane paid its own per-layer host stage
(separate fused FlashD2H saves, separate LRU/FlashH2D rounds).  The paper
names exactly this — "high HBM demands of hybrid batching" — as the
problem layer-segmented prefill exists to solve: both work kinds must
share one iteration's transfer stages.

``HybridPlane.run_iteration`` walks the model's layers ONCE per engine
iteration, carrying every decode plane's staged pipeline AND every prefill
plane's same-(layer, chunk) segment groups together.  Per model layer *i*:

1. decode ``select`` (attention) or the recurrent stage runs for every
   decode plane — identical jitted stages, identical inputs, identical
   order as ``step_staged``;
2. the layer's prefill groups run (``PrefillPlane.run_layer`` — one
   bucketed launch per (layer, chunk) group, chunks in plan order);
3. ONE ``layer_cb(win)`` fires — the single per-layer host stage.  The
   engine merges decode write-back and the prefill groups' fresh KV into
   ONE fused FlashD2H save, runs the LRU round for decode's selections,
   loads every plane's misses through at most ONE fused FlashH2D, and
   scatters restores into the decode pools BEFORE the attention that
   selected them;
4. decode ``attend`` runs for every decode plane over the restored pools.

After the walk each decode plane takes its logits stage and each prefill
plane its shared finalize — launches stay O(L) per iteration, independent
of how many decode rows and prefill segments are live (see
``plane_contract.mixed_launches_per_iteration``).

Because every launch is the SAME ``StageFns`` jit the split path uses, on
identical per-request inputs (masked-batch exact), mixed greedy tokens are
byte-identical to the split two-plane path — the ``"split"`` oracle knob
on ``EngineConfig.hybrid_plane`` keeps that path alive for equivalence
tests (tests/test_hybrid_plane.py).

``_HybridFns`` is the plane's registry, keyed structurally like
``staged_fns_for``: it COMPOSES the staged decode and prefill registries
rather than wrapping new jits, so the mixed plane adds zero new traces —
the cache-hit invariant of both underlying registries covers it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

import jax.numpy as jnp

from repro.core.device_pool import DevicePoolPlane, staged_fns_for
from repro.core.prefill_plane import (PrefillGroupRun,
                                      PrefillIterationResult, PrefillPlane,
                                      PrefillWalk, prefill_fns_for)
from repro.models import model as M
from repro.obs.tracing import NULL_TRACER


class _HybridFns:
    """Stage registry of the mixed plane: a composition of the staged
    decode registry and the prefill registry (the mixed iteration launches
    exactly their jits, never new ones).  Keyed like ``staged_fns_for`` so
    value-equal configs share the same underlying compile caches."""

    contract_protocol = "hybrid-plane"

    def __init__(self, cfg, attn_impl: str, plane_mesh=None):
        self.cfg = cfg
        self.decode = staged_fns_for(cfg, attn_impl, plane_mesh)
        self.prefill = prefill_fns_for(cfg, plane_mesh)

    @property
    def trace_count(self) -> int:
        return self.decode.trace_count + self.prefill.trace_count

    @property
    def calls(self) -> int:
        return self.decode.calls + self.prefill.calls

    @property
    def shape_signatures(self) -> set:
        return self.decode.shape_signatures | self.prefill.shape_signatures


_HYBRID_FNS: Dict[Any, _HybridFns] = {}


def hybrid_fns_for(cfg, attn_impl: str, plane_mesh=None) -> _HybridFns:
    key = (repr(cfg), attn_impl,
           None if plane_mesh is None else plane_mesh.key())
    if key not in _HYBRID_FNS:
        _HYBRID_FNS[key] = _HybridFns(cfg, attn_impl, plane_mesh)
    return _HYBRID_FNS[key]


@dataclasses.dataclass
class DecodeJob:
    """One decode group's work for the mixed iteration."""
    plane: DevicePoolPlane
    token_by_req: Dict[str, int]


@dataclasses.dataclass
class PrefillJob:
    """One prefill plane's scheduled allowance for the mixed iteration."""
    plane: PrefillPlane
    allowance: Dict[str, int]


@dataclasses.dataclass
class DecodeRun:
    """In-flight staged state of one decode plane during the layer walk —
    the locals ``step_staged`` would keep."""
    plane: DevicePoolPlane
    fns: Any
    req_ids: List[str]
    mask: jax.Array
    x: jax.Array
    layer_params: List[Dict]
    enc_kvs: Any
    prev: Dict[str, int]
    info: Dict[str, Any]
    q: Any = None
    idx: Any = None
    valid: Any = None


@dataclasses.dataclass
class LayerWindow:
    """What ONE per-layer host stage sees: every decode plane's selection
    for this layer plus every prefill group that just ran here.  The
    engine's ``layer_cb`` merges these into one fused FlashD2H and at most
    one fused FlashH2D."""
    layer: int
    kind: str                                     # 'attn' | 'mamba' | 'rwkv'
    selections: List[Tuple[DecodeRun, Optional[np.ndarray]]]
    groups: List[Tuple[PrefillPlane, PrefillGroupRun]]


@dataclasses.dataclass
class MixedIterationResult:
    decode: List[Tuple[DevicePoolPlane, jax.Array, Dict, Dict[str, int]]]
    prefill: List[Tuple[PrefillPlane, PrefillIterationResult]]


class HybridPlane:
    """Mixed-iteration driver over the decode and prefill planes.

    Stateless between iterations apart from counters: the decode planes
    keep their persistent pools and the prefill planes their rows — this
    driver only owns the per-iteration layer walk."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.iterations = 0
        self.stage_timeline: List[Tuple[int, float, float]] = []
        # last iteration's (layer, idx_sync_s, host_stage_s) per layer_cb
        self.dispatch_sync_s = 0.0    # accumulated across iterations —
        self.host_stage_s = 0.0       # counter half of the overlap
                                      # cross-check (see device_pool)
        self.tracer = NULL_TRACER     # engine installs a live Tracer
                                      # when EngineConfig.obs is on

    def run_iteration(self, params: Dict, decode_jobs: List[DecodeJob],
                      prefill_jobs: List[PrefillJob],
                      layer_cb=None) -> MixedIterationResult:
        """Walk model layers 0..L-1 once, interleaving every decode
        plane's staged pipeline with every prefill plane's layer groups.
        ``layer_cb(win)`` fires exactly once per layer, between the
        layer's selections/prefill launches and its decode attends — the
        one per-layer host stage (fused FlashD2H/H2D window)."""
        cfg = self.cfg
        dec: List[DecodeRun] = []
        for job in decode_jobs:
            plane = job.plane
            fns = plane.staged_fns
            tokens = np.zeros((plane.b_cap,), np.int32)
            mask = np.zeros((plane.b_cap,), bool)
            for rid, tok in job.token_by_req.items():
                tokens[plane.rows[rid]] = tok
                mask[plane.rows[rid]] = True
            x = fns.embed(params, jnp.asarray(tokens))
            dec.append(DecodeRun(
                plane=plane, fns=fns, req_ids=list(job.token_by_req),
                mask=jnp.asarray(mask), x=x,
                layer_params=plane._layer_params(params),
                enc_kvs=plane.state["extra"].get("enc_kvs"),
                prev={rid: plane.cur_host[rid] for rid in job.token_by_req},
                info={"selected": {}}))
        pre: List[Tuple[PrefillPlane, PrefillWalk]] = []
        for pj in prefill_jobs:
            pre.append((pj.plane, pj.plane.begin_iteration(pj.allowance)))
        timeline: List[Tuple[int, float, float]] = []
        tr = self.tracer
        for i in range(cfg.num_layers):
            kind = M.layer_kind(cfg, i)
            selections: List[Tuple[DecodeRun, Optional[np.ndarray]]] = []
            t_sync = 0.0
            if kind == "attn":
                if tr.enabled and dec:
                    _ts = time.perf_counter()
                for d in dec:
                    st = d.plane.state
                    q, new_cache, idx, valid = d.fns.select(
                        d.layer_params[i], d.x, st["caches"][i],
                        st["cur_len"], d.mask)
                    st["caches"][i] = new_cache
                    if idx is not None:
                        d.info["selected"][i] = idx
                    d.q, d.idx, d.valid = q, idx, valid
                    # np.asarray(idx) is the ONLY host sync per layer (same
                    # as step_staged): it forces select_i — and the still-
                    # queued attend_{i-1} — before the host stage runs
                    t0 = time.perf_counter()
                    selections.append(
                        (d, None if idx is None else np.asarray(idx)))
                    t_sync += time.perf_counter() - t0
                if tr.enabled and dec:
                    tr.end("select", "stage", _ts, layer=i,
                           planes=len(dec))
            else:
                for d in dec:
                    st = d.plane.state
                    d.x, new_cache = d.fns._recurrent[kind](
                        d.layer_params[i], d.x, st["caches"][i], d.mask)
                    st["caches"][i] = new_cache
            layer_groups: List[Tuple[PrefillPlane, PrefillGroupRun]] = []
            for plane, walk in pre:
                for g in plane.run_layer(params, i, walk):
                    layer_groups.append((plane, g))
            if layer_cb is not None and (selections or layer_groups):
                t1 = time.perf_counter()
                layer_cb(LayerWindow(layer=i, kind=kind,
                                     selections=selections,
                                     groups=layer_groups))
                t2 = time.perf_counter()
                timeline.append((i, t_sync, t2 - t1))
                if tr.enabled:
                    # same t1/t2 as the timeline entry: trace and counter
                    # overlap instruments share the measurement
                    tr.complete_at("host-stage", "host-stage", t1,
                                   t2 - t1, layer=i,
                                   groups=len(layer_groups))
            if kind == "attn":
                if tr.enabled and dec:
                    _ts = time.perf_counter()
                for d in dec:
                    st = d.plane.state
                    d.x = d.fns.attend(d.layer_params[i], d.x, d.q,
                                       st["caches"][i], st["cur_len"],
                                       d.idx, d.valid,
                                       M.index_enc_kvs(d.enc_kvs, i))
                if tr.enabled and dec:
                    tr.end("attend", "stage", _ts, layer=i,
                           planes=len(dec))
        self.stage_timeline = timeline
        for _, _sync_s, _stage_s in timeline:
            self.dispatch_sync_s += _sync_s
            self.host_stage_s += _stage_s
        out_dec = []
        for d in dec:
            st = d.plane.state
            logits, new_len = d.fns.logits(params, d.x, st["cur_len"],
                                           d.mask)
            st["cur_len"] = new_len
            d.plane.buckets_seen.add((d.plane.b_cap, d.plane.nb_cap))
            d.plane.steps += 1
            for rid in d.req_ids:
                d.plane.cur_host[rid] += 1
            out_dec.append((d.plane, logits, d.info, d.prev))
        out_pre = []
        for plane, walk in pre:
            out_pre.append((plane, plane.finish_iteration(params, walk)))
        self.iterations += 1
        return MixedIterationResult(decode=out_dec, prefill=out_pre)
