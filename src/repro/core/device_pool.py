"""Persistent shared device decode pool — the engine's decode data plane.

Before this module, every batched decode iteration re-stacked each running
request's per-layer KV pool into a fresh padded device pool
(``model.stack_decode_states``) and unstacked it afterwards: O(batch x pool)
HBM copies per generated token, exactly the fragmented KV-cache movement
SparseServe's hierarchical HBM/DRAM design is meant to eliminate.

``DevicePoolPlane`` replaces that round-trip with ONE persistent padded
paged pool per layer that lives on device for the lifetime of the decode
batch:

* **Slot lifecycle** — a request joining decode is admitted once
  (``admit``: its prefill-built pools are copied into a free batch row);
  while it decodes, NOTHING is copied per iteration; when it finishes,
  ``release`` frees the row for the next admitted request to reuse.  Freed
  rows are reused lowest-first so replaying a trace is deterministic.
* **Bucketed jit** — the batched ``model.decode_step`` is jit-compiled at
  bucketed shapes (batch rows from ``BucketingPolicy.batch_buckets``, block
  capacity rounded up to ``block_bucket``), so steady-state decode is one
  cached compiled call per bucket instead of a retrace (or an eager
  dispatch storm) per iteration.  Requests scheduled this iteration are
  selected with a ``step_mask`` argument — occupancy changes do NOT change
  shapes, hence do not retrace.  Pool buffers are donated to the jitted
  call on accelerator backends so XLA updates them in place.
* **Staged per-layer pipeline** — ``step_staged`` runs the same forward as
  per-layer select -> [host restore] -> attend stage jits
  (``_StagedDecodeFns``), giving the serving engine a window between a
  layer's DSA selection and its attention in which fused FlashH2D restores
  land in the device pool BEFORE use — the structure that makes
  block-granular HBM eviction oracle-exact (the engine's default
  ``decode_plane="staged"``).  Launches are O(num_layers) per iteration;
  traces stay bounded by (stage kinds x shape buckets).
* **FlashH2D/D2H wiring** — ``restore_blocks`` scatters fused-gather
  payloads from ``KVCacheManager.load_blocks_fused`` directly into device
  slots (the jnp scatter here is the interpret-mode stand-in for
  ``repro.kernels.scatter_blocks``; ``gather_row_blocks`` mirrors
  ``repro.kernels.gather_blocks``), and ``drop_blocks`` zeroes evicted
  blocks so HBM eviction actually destroys device-resident data.  Block
  metadata is never dropped: DSA scoring stays exact while block *data*
  moves through the hierarchy.

The legacy stack/unstack path survives behind
``EngineConfig.decode_plane="stacked"`` as the equivalence oracle.
"""
from __future__ import annotations

import bisect
import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.obs.tracing import NULL_TRACER

# Route row-slot block movement through the Pallas kernels
# (kernels/gather_blocks.py / scatter_blocks.py, _hkv variants).  Default is
# the jnp fast path: on this CPU-only container the kernels run in interpret
# mode (Python-per-block — correct but slow); on TPU set
# REPRO_PLANE_KERNEL=1 REPRO_KERNEL_INTERPRET=0 for the compiled DMA stream.
USE_PALLAS_PLANE = os.environ.get("REPRO_PLANE_KERNEL", "0") == "1"


@dataclasses.dataclass(frozen=True)
class BucketingPolicy:
    """Shape-bucketing policy for the jitted batched decode step and the
    batched prefill plane.

    batch_buckets: allowed padded batch-row counts; demand beyond the last
        bucket doubles it (8 -> 16 -> 32 ...).
    block_bucket: pool block capacity is rounded UP to a multiple of this,
        so admitting a slightly-longer request reuses the compiled bucket
        instead of retracing at nb, nb+1, nb+2, ...
    token_bucket: prefill-plane token-length grid — segment windows (and
        row capacities) round up to token_bucket, then DOUBLE (64, 128,
        256, ...), so the number of distinct compiled token lengths is
        logarithmic in the longest prompt.
    """
    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    block_bucket: int = 8
    token_bucket: int = 64

    def bucket_batch(self, n: int) -> int:
        for b in self.batch_buckets:
            if b >= n:
                return b
        b = self.batch_buckets[-1]
        while b < n:
            b *= 2
        return b

    def bucket_blocks(self, nb: int) -> int:
        bb = self.block_bucket
        return max(bb, -(-nb // bb) * bb)

    def bucket_tokens(self, n: int) -> int:
        t = self.token_bucket
        while t < n:
            t *= 2
        return t


class StageFns:
    """Shared plumbing for per-stage jit registries (the staged decode
    plane's ``_StagedDecodeFns`` and the prefill plane's ``_PrefillFns``):
    ``wrap`` builds a jitted stage whose trace-time side effect counts XLA
    compiles and whose call-time hook records (stage, arg pytree
    structure, leaf shapes/dtypes) — so ``trace_count ==
    len(shape_signatures)`` is the cache-hit invariant tests assert for
    every registry.  The pytree STRUCTURE is part of the signature because
    optional args (enc_kv, ctx, DSA idx) may be None: two calls whose
    leaves coincide but whose structures differ trace separately.
    Donation applies on accelerator backends only (CPU buffers are not
    donatable and would only emit a warning per compile).

    Contract metadata: every registry retains each stage's RAW (unjitted)
    callable (``raw_fns``) and the abstract shapes of its first call
    (``abstract_args``, a ShapeDtypeStruct pytree) — what the plane
    sharding-leak pass (tools/analysis, docs/architecture.md §8) lowers
    via ``jax.make_jaxpr`` to check collectives and out-spec replication
    against ``repro.core.plane_contract.sharding_rules``."""

    contract_protocol = "stage-registry"

    def __init__(self):
        self.trace_count = 0
        self.calls = 0                      # jitted stage launches, total
        self.shape_signatures: set = set()
        self.raw_fns: Dict[str, Any] = {}   # stage -> unjitted callable
        self.abstract_args: Dict[str, Tuple] = {}   # stage -> SDS pytree
        self.donated: Dict[str, Tuple[int, ...]] = {}  # stage -> donate args
        self._donate_ok = jax.default_backend() != "cpu"
        # whether donation is actually armed on this backend (CPU buffers
        # are not donatable; declaring them would only warn per compile)
        self.donate_active = self._donate_ok

    def wrap(self, stage, f, donate=()):
        self.raw_fns[stage] = f
        self.donated[stage] = tuple(donate)

        def fn(*args):
            self.trace_count += 1           # trace-time side effect only
            return f(*args)
        jitted = jax.jit(fn,
                         donate_argnums=donate if self._donate_ok else ())

        def call(*args):
            self.calls += 1
            leaves, treedef = jax.tree.flatten(args)
            self.shape_signatures.add(
                (stage, str(treedef),
                 tuple((tuple(jnp.shape(leaf)), str(jnp.result_type(leaf)))
                       for leaf in leaves)))
            if stage not in self.abstract_args:
                self.abstract_args[stage] = jax.tree.map(
                    lambda leaf: jax.ShapeDtypeStruct(
                        jnp.shape(leaf), jnp.result_type(leaf)), args)
            return jitted(*args)
        return call


class _DecodeFn:
    """One jit-compiled batched ``decode_step`` per (model config, impl).

    Shared across every ``DevicePoolPlane`` (and engine instance) built for
    the same config so jax's compilation cache is hit across engines.
    ``trace_count`` increments via a Python side effect that only runs at
    trace time — the exact number of XLA compilations — and
    ``shape_signatures`` records every distinct input-shape signature seen,
    so ``trace_count == len(shape_signatures)`` is the cache-hit invariant
    tests assert (bounded by the bucket count for a bucketed workload).
    """

    def __init__(self, cfg, attn_impl: str):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.trace_count = 0
        self.calls = 0
        self.shape_signatures: set = set()
        # donation lets XLA reuse the pool buffers in place; CPU buffers are
        # not donatable and would only emit a warning per compile
        donate = (3,) if jax.default_backend() != "cpu" else ()

        def fn(params, tokens, step_mask, state):
            self.trace_count += 1        # trace-time side effect only
            return M.decode_step(params, cfg, tokens, state,
                                 attn_impl=attn_impl, return_info=True,
                                 step_mask=step_mask)

        self._jit = jax.jit(fn, donate_argnums=donate)

    @staticmethod
    def signature(state: Dict) -> Tuple:
        return tuple((tuple(leaf.shape), str(leaf.dtype))
                     for leaf in jax.tree.leaves(state))

    def __call__(self, params, tokens, step_mask, state):
        self.calls += 1
        self.shape_signatures.add(self.signature(state))
        return self._jit(params, tokens, step_mask, state)


# keyed STRUCTURALLY (dataclass repr covers every field, nested configs
# included) so value-equal configs share one _DecodeFn — and hence one XLA
# compile cache — instead of leaking an entry per fresh-but-equal object.
# Entries live for the process (bounded by the number of distinct configs).
_DECODE_FNS: Dict[Tuple[str, str], _DecodeFn] = {}


def decode_fn_for(cfg, attn_impl: str) -> _DecodeFn:
    key = (repr(cfg), attn_impl)
    if key not in _DECODE_FNS:
        _DECODE_FNS[key] = _DecodeFn(cfg, attn_impl)
    return _DECODE_FNS[key]


class _StagedDecodeFns(StageFns):
    """Per-stage jits for the STAGED decode pipeline: embed, per-layer
    select / attend (attention layers), per-layer recurrent (mamba/rwkv),
    and the final logits stage.

    Every stage function takes a LAYER's params pytree, so one trace serves
    all structurally identical layers — per-iteration jitted LAUNCHES are
    O(num_layers) but TRACES stay bounded by (distinct layer structures x
    shape buckets), the same cache-hit invariant as the fused ``_DecodeFn``:
    ``trace_count == len(shape_signatures)`` (see ``StageFns``; pool
    buffers are donated so XLA updates them in place on accelerators).
    """

    contract_protocol = "staged-decode"

    def __init__(self, cfg, attn_impl: str, plane_mesh=None):
        super().__init__()
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.plane_mesh = plane_mesh
        wrap = self.wrap

        self.embed = wrap("embed",
                          lambda params, tokens:
                          M.decode_embed(params, cfg, tokens))
        # select consumes and returns the layer's pool cache (arg 2): donate
        # so the append/meta update reuses the buffer instead of copying the
        # full pool per layer per iteration.  With a plane_mesh the pool-
        # touching core of both stages runs under shard_map (KV-head- or
        # block-sharded slots; see launch/plane_mesh.py).
        self.select = wrap("select",
                           lambda p, x, cache, cur_len, mask:
                           M.decode_select_layer(p, cfg, x, cache, cur_len,
                                                 step_mask=mask,
                                                 plane_mesh=plane_mesh),
                           donate=(2,))
        self.attend = wrap("attend",
                           lambda p, x, q, cache, cur_len, idx, valid, enc:
                           M.decode_attend_layer(p, cfg, x, q, cache,
                                                 cur_len, idx, valid,
                                                 enc_kv=enc,
                                                 attn_impl=attn_impl,
                                                 plane_mesh=plane_mesh))
        self._recurrent = {
            kind: wrap("recurrent-" + kind,
                       lambda p, x, cache, mask, kind=kind:
                       M.decode_recurrent_layer(p, cfg, kind, x, cache,
                                                step_mask=mask),
                       donate=(2,))
            for kind in ("mamba", "rwkv")}
        self.logits = wrap("logits",
                           lambda params, x, cur_len, mask:
                           M.decode_logits(params, cfg, x, cur_len,
                                           step_mask=mask))


_STAGED_FNS: Dict[Tuple, _StagedDecodeFns] = {}


def staged_fns_for(cfg, attn_impl: str, plane_mesh=None) -> _StagedDecodeFns:
    key = (repr(cfg), attn_impl,
           None if plane_mesh is None else plane_mesh.key())
    if key not in _STAGED_FNS:
        _STAGED_FNS[key] = _StagedDecodeFns(cfg, attn_impl, plane_mesh)
    return _STAGED_FNS[key]


def gather_row_blocks(pool: jax.Array, row: int, blocks) -> jax.Array:
    """Gather `blocks` of one batch row: (B,H,NB,bs,D) -> (H,K,bs,D).

    FlashH2D direction; with ``REPRO_PLANE_KERNEL=1`` this runs the Pallas
    ``gather_blocks_hkv`` kernel (one launch, one block-granular DMA per
    grid step), otherwise the equivalent jnp gather."""
    idx = jnp.asarray(blocks, jnp.int32)
    if USE_PALLAS_PLANE:
        from repro.kernels import ops
        return ops.gather_blocks_hkv(pool[row], idx)
    return pool[row][:, idx]


def scatter_row_blocks(pool: jax.Array, row: int, blocks,
                       payload: jax.Array) -> jax.Array:
    """Scatter `payload` (H,K,bs,D) into `blocks` of one batch row in place.

    FlashD2H / H2D-restore direction: whole-block granularity, untouched
    blocks preserved.  With ``REPRO_PLANE_KERNEL=1`` this runs the Pallas
    ``scatter_blocks_hkv`` kernel (pool aliased in place), otherwise the
    equivalent jnp scatter."""
    idx = jnp.asarray(blocks, jnp.int32)
    payload = payload.astype(pool.dtype)
    if USE_PALLAS_PLANE:
        from repro.kernels import ops
        new_row = ops.scatter_blocks_hkv(pool[row], payload, idx)
    else:
        new_row = pool[row].at[:, idx].set(payload)
    return pool.at[row].set(new_row)


class DevicePoolPlane:
    """Persistent padded decode state for one group of batched requests.

    Requests whose non-pool decode state agrees in every per-request shape
    (the engine's ``_decode_group_key``) share one plane; pools pad along
    the block axis to the bucketed capacity.  The plane OWNS its requests'
    decode state: after ``admit`` the engine must not keep using the
    per-request state it passed in (``extract`` hands a copy back).
    """

    def __init__(self, cfg, policy: Optional[BucketingPolicy] = None,
                 attn_impl: str = "ref", plane_mesh=None):
        if plane_mesh is not None and not cfg.dsa.enabled:
            raise NotImplementedError(
                "sharded decode plane requires DSA (cfg.dsa.enabled): the "
                "context-parallel attend has no dense fallback")
        self.cfg = cfg
        self.policy = policy or BucketingPolicy()
        self.attn_impl = attn_impl
        self.plane_mesh = plane_mesh
        self.decode_fn = decode_fn_for(cfg, attn_impl)
        self.staged_fns = staged_fns_for(cfg, attn_impl, plane_mesh)
        self.state: Optional[Dict] = None
        self.b_cap = 0
        self.nb_cap = 0
        self.rows: Dict[str, int] = {}            # req_id -> batch row
        self.row_layout: Dict[str, List[Optional[int]]] = {}  # per-layer nb
        self.cur_host: Dict[str, int] = {}        # host mirror of cur_len
        self._free: List[int] = []                # sorted free rows
        self._ever_used: set = set()
        self.buckets_seen: set = set()            # (b_cap, nb_cap) stepped at
        self.steps = 0
        self.admits = 0
        self.rows_reused = 0
        self.blocks_dropped = 0
        self.blocks_restored = 0
        self.blocks_restored_before_use = 0   # landed before the attention
                                              # that selected them (staged)
        self.host_syncs = 0              # async mode: per-layer np.asarray(
                                         # selected ids) — the ONLY blocking
                                         # sync the dispatch thread pays
        self.d2h_readback_bytes = 0      # stripe bytes read back by
                                         # new_token_kv[_async]: pins that
                                         # write-back never copies pool-sized
                                         # buffers to host
        self.stage_timeline: List[Tuple[int, float, float]] = []
        # last iteration's (layer, idx_sync_s, host_stage_s) per stage_cb
        self.dispatch_sync_s = 0.0       # accumulated idx-sync time, all
        self.host_stage_s = 0.0          # iterations; the counter half of
                                         # the overlap cross-check (the
                                         # trace half reuses the same
                                         # perf_counter reads)
        self.tracer = NULL_TRACER        # engine swaps in a live Tracer
                                         # when EngineConfig.obs is on
        # per-layer param slices for the staged pipeline, cached per params
        # OBJECT (the entry's strong ref keeps the id() stable).  Lives on
        # the plane — not the process-global _StagedDecodeFns — so retired
        # engines' params are reclaimable once their planes go away.
        self._layer_params_cache: Optional[Tuple[Dict, List[Dict]]] = None

    def _layer_params(self, params: Dict) -> List[Dict]:
        """Per-layer param slices (``get_layer``), computed once per params
        object: with stacked layer params each slice is a device op, so
        doing it per layer per iteration would bloat the launch count."""
        hit = self._layer_params_cache
        if hit is not None and hit[0] is params:
            return hit[1]
        layers = [M.get_layer(params, i) for i in range(self.cfg.num_layers)]
        self._layer_params_cache = (params, layers)
        return layers

    # -- capacity ----------------------------------------------------------

    def _alloc(self, template: Dict, b_cap: int, nb_cap: int) -> Dict:
        caches: List[Any] = []
        for c in template["caches"]:
            if M.is_pool_cache(c):
                caches.append({
                    key: jnp.zeros((b_cap,) + v.shape[1:2] + (nb_cap,)
                                   + v.shape[3:], v.dtype)
                    for key, v in c.items()})
            else:
                caches.append(jax.tree.map(
                    lambda x: jnp.zeros((b_cap,) + x.shape[1:], x.dtype), c))
        extra = (jax.tree.map(
            lambda x: jnp.zeros((b_cap,) + x.shape[1:], x.dtype),
            template["extra"]) if template["extra"] else {})
        return {"caches": caches,
                "cur_len": jnp.zeros((b_cap,), jnp.int32),
                "extra": extra}

    def _grow(self, b_cap: int, nb_cap: int) -> None:
        db = b_cap - self.b_cap
        dnb = nb_cap - self.nb_cap

        def pad_pool(v):
            return jnp.pad(v, ((0, db), (0, 0), (0, dnb))
                           + ((0, 0),) * (v.ndim - 3))

        def pad_rows(v):
            return jnp.pad(v, ((0, db),) + ((0, 0),) * (v.ndim - 1))

        st = self.state
        st["caches"] = [
            ({key: pad_pool(v) for key, v in c.items()}
             if M.is_pool_cache(c) else jax.tree.map(pad_rows, c))
            for c in st["caches"]]
        st["cur_len"] = pad_rows(st["cur_len"])
        if st["extra"]:
            st["extra"] = jax.tree.map(pad_rows, st["extra"])
        for r in range(self.b_cap, b_cap):
            bisect.insort(self._free, r)

    def _ensure_capacity(self, template: Dict, need_rows: int,
                         need_nb: int) -> None:
        b_cap = max(self.b_cap, self.policy.bucket_batch(need_rows))
        nb_cap = max(self.nb_cap, self.policy.bucket_blocks(need_nb))
        if self.plane_mesh is not None:
            # block-sharded pools must divide the model axis evenly
            nb_cap = self.plane_mesh.round_blocks(self.cfg, nb_cap)
        if self.state is None:
            self.state = self._alloc(template, b_cap, nb_cap)
            self._free = list(range(b_cap))
        elif b_cap != self.b_cap or nb_cap != self.nb_cap:
            self._grow(b_cap, nb_cap)
        self.b_cap, self.nb_cap = b_cap, nb_cap

    # -- slot lifecycle ----------------------------------------------------

    def admit(self, req_id: str, state: Dict) -> int:
        """Copy one request's DecodeState (B=1, list-mode caches) into a
        free batch row; returns the row.  The ONLY full-pool copy in a
        request's decode lifetime."""
        if req_id in self.rows:
            raise ValueError(f"{req_id} already admitted")
        if not isinstance(state["caches"], list):
            raise ValueError("DevicePoolPlane requires list-mode caches")
        if int(state["cur_len"].shape[0]) != 1:
            raise ValueError("admit expects a single-request state (B=1)")
        nbs = [c["k"].shape[2] if M.is_pool_cache(c) else None
               for c in state["caches"]]
        nb_req = max((n for n in nbs if n is not None), default=0)
        self._ensure_capacity(state, len(self.rows) + 1, nb_req)
        row = self._free.pop(0)
        if row in self._ever_used:
            self.rows_reused += 1
        self._ever_used.add(row)
        st = self.state
        for l, c in enumerate(state["caches"]):
            if M.is_pool_cache(c):
                for key, v in c.items():
                    st["caches"][l][key] = \
                        st["caches"][l][key].at[row, :, :nbs[l]].set(v[0])
            else:
                st["caches"][l] = jax.tree.map(
                    lambda dst, src: dst.at[row].set(src[0]),
                    st["caches"][l], c)
        st["cur_len"] = st["cur_len"].at[row].set(state["cur_len"][0])
        if state["extra"]:
            st["extra"] = jax.tree.map(
                lambda dst, src: dst.at[row].set(src[0]),
                st["extra"], state["extra"])
        self.rows[req_id] = row
        self.row_layout[req_id] = nbs
        self.cur_host[req_id] = int(state["cur_len"][0])
        self.admits += 1
        return row

    def release(self, req_id: str) -> int:
        """Free a finished request's row (device slots become reusable —
        this is where a finished request's device memory is dropped)."""
        row = self.rows.pop(req_id)
        self.row_layout.pop(req_id)
        self.cur_host.pop(req_id)
        bisect.insort(self._free, row)
        return row

    # -- iteration ---------------------------------------------------------

    def step(self, params: Dict, token_by_req: Dict[str, int]
             ) -> Tuple[jax.Array, Dict, Dict[str, int]]:
        """ONE jitted batched forward over the plane's padded rows.

        token_by_req: the scheduled requests' input tokens.  Unscheduled
        (or free) rows are masked out via ``step_mask`` — their pools,
        recurrent states and cur_len come back unchanged, and occupancy
        changes never retrace.  Returns (logits (B_cap, V), info,
        {req_id: cur_len BEFORE the step}) — the pre-step lengths are the
        positions where this step's KV landed (FlashD2H write-back needs
        them)."""
        tokens = np.zeros((self.b_cap,), np.int32)
        mask = np.zeros((self.b_cap,), bool)
        for rid, tok in token_by_req.items():
            row = self.rows[rid]
            tokens[row] = tok
            mask[row] = True
        logits, new_state, info = self.decode_fn(
            params, jnp.asarray(tokens), jnp.asarray(mask), self.state)
        self.state = new_state
        self.buckets_seen.add((self.b_cap, self.nb_cap))
        self.steps += 1
        prev = {rid: self.cur_host[rid] for rid in token_by_req}
        for rid in token_by_req:
            self.cur_host[rid] += 1
        return logits, info, prev

    def step_staged(self, params: Dict, token_by_req: Dict[str, int],
                    stage_cb=None) -> Tuple[jax.Array, Dict, Dict[str, int]]:
        """Staged per-layer pipeline: select -> [host restore] -> attend.

        Runs the decode forward ONE layer at a time through the per-stage
        jits (``_StagedDecodeFns``).  For each attention layer *l*:

        1. ``select`` (jitted) appends the new token's KV to layer *l*'s
           pool and emits its DSA block selections;
        2. ``stage_cb(l, sel_np, prev_lens)`` runs on the host — this is
           the window in which the engine writes back layer *l*'s new KV
           (FlashD2H), touches the LRU, and scatters fused FlashH2D restore
           payloads into ``self.state["caches"][l]``;
        3. ``attend`` (jitted) runs block-sparse attention over the now
           restored pool — restores always land BEFORE use, which is what
           makes block-granular device eviction oracle-exact.

        Pipelining: attend_l and select_{l+1} are dispatched back-to-back
        without a host sync (JAX async dispatch) — the host's only per-layer
        block is on the tiny selection tensor it needs for staging, so on an
        accelerator the device queue holds attend_l + select_{l+1} while the
        host does layer l+1's LRU bookkeeping and DRAM gather.  The cost
        model charges this overlap as max(compute, transfer) per layer
        (``costmodel.overlapped_decode_time``).

        Returns (logits, info, prev) exactly like ``step``.
        """
        cfg = self.cfg
        fns = self.staged_fns
        tokens = np.zeros((self.b_cap,), np.int32)
        mask = np.zeros((self.b_cap,), bool)
        for rid, tok in token_by_req.items():
            tokens[self.rows[rid]] = tok
            mask[self.rows[rid]] = True
        tokens = jnp.asarray(tokens)
        mask = jnp.asarray(mask)
        st = self.state
        layer_params = self._layer_params(params)
        enc_kvs = st["extra"].get("enc_kvs")
        prev = {rid: self.cur_host[rid] for rid in token_by_req}
        info: Dict[str, Any] = {"selected": {}}
        timeline: List[Tuple[int, float, float]] = []
        tr = self.tracer

        x = fns.embed(params, tokens)
        for i in range(cfg.num_layers):
            kind = M.layer_kind(cfg, i)
            if kind != "attn":
                x, new_cache = fns._recurrent[kind](
                    layer_params[i], x, st["caches"][i], mask)
                st["caches"][i] = new_cache
                continue
            if tr.enabled:
                _ts = time.perf_counter()
            q, new_cache, idx, valid = fns.select(
                layer_params[i], x, st["caches"][i], st["cur_len"], mask)
            if tr.enabled:
                tr.end("select", "stage", _ts, layer=i)
            st["caches"][i] = new_cache
            if idx is not None:
                info["selected"][i] = idx
            if stage_cb is not None:
                # np.asarray(idx) is the ONLY host sync per layer: it
                # forces select_i (and the still-queued attend_{i-1});
                # the callback then scatters restores into caches[i].
                # sel is None when DSA is off — the callback still runs
                # (per-layer FlashD2H write-back), it just has no
                # selections to stage.  In async mode the callback must
                # not block on the device again (plane-contract rule
                # no-sync-in-dispatch-window); the wall-clock split
                # between the idx sync and the host stage is recorded so
                # bench_overlap can report ACHIEVED overlap.
                t0 = time.perf_counter()
                sel = None if idx is None else np.asarray(idx)
                t1 = time.perf_counter()
                stage_cb(i, sel, prev)
                t2 = time.perf_counter()
                timeline.append((i, t1 - t0, t2 - t1))
                if tr.enabled:
                    # the spans reuse t0/t1/t2 verbatim — the trace and
                    # the dispatch_sync_s/host_stage_s counters are the
                    # same measurement exported two ways
                    tr.complete_at("idx-sync", "stage", t0, t1 - t0,
                                   layer=i)
                    tr.complete_at("host-stage", "host-stage", t1,
                                   t2 - t1, layer=i)
            if tr.enabled:
                _ts = time.perf_counter()
            x = fns.attend(layer_params[i], x, q, st["caches"][i],
                           st["cur_len"], idx, valid,
                           M.index_enc_kvs(enc_kvs, i))
            if tr.enabled:
                tr.end("attend", "stage", _ts, layer=i)
        logits, new_len = fns.logits(params, x, st["cur_len"], mask)
        st["cur_len"] = new_len
        self.stage_timeline = timeline
        for _, _sync_s, _stage_s in timeline:
            self.dispatch_sync_s += _sync_s
            self.host_stage_s += _stage_s
        self.buckets_seen.add((self.b_cap, self.nb_cap))
        self.steps += 1
        for rid in token_by_req:
            self.cur_host[rid] += 1
        return logits, info, prev

    # -- data plane: FlashH2D/D2H wiring ----------------------------------

    def pool_layers(self) -> List[int]:
        """Model-layer indices that hold paged attn pools."""
        if self.state is None:
            return []
        return [l for l, c in enumerate(self.state["caches"])
                if M.is_pool_cache(c)]

    def new_token_kv(self, req_ids: List[str], prev_lens: Dict[str, int],
                     layers: Optional[List[int]] = None
                     ) -> Dict[int, Tuple[np.ndarray, Optional[np.ndarray]]]:
        """Read back the KV stripe this iteration appended (FlashD2H phase 1
        source): {model_layer: (k (R,Hkv,D), v (R,Hkv,D) | None)} with rows
        ordered like `req_ids`.  ``layers`` restricts the readback to a
        subset of pool layers — the staged plane saves layer *l* right after
        its select stage (and before its restores), one layer at a time."""
        return {l: (np.asarray(k), None if v is None else np.asarray(v))
                for l, (k, v) in self.new_token_kv_async(
                    req_ids, prev_lens, layers).items()}

    def new_token_kv_async(self, req_ids: List[str],
                           prev_lens: Dict[str, int],
                           layers: Optional[List[int]] = None
                           ) -> Dict[int, Tuple[jax.Array,
                                                Optional[jax.Array]]]:
        """Dispatch the appended-KV stripe gathers WITHOUT a host sync:
        same mapping as ``new_token_kv`` but the values are DEVICE arrays
        (the gather is queued behind this layer's select stage).  Convert
        with ``np.asarray`` off-thread (``HostStageWorker``) — JAX's value
        semantics guarantee the queued gather reads the pool value as of
        dispatch, so later pool-updating stages cannot corrupt the stripe
        even though they reuse (donated) pool buffers."""
        bs = self.cfg.dsa.block_size
        rows = jnp.asarray([self.rows[r] for r in req_ids], jnp.int32)
        pos = np.asarray([prev_lens[r] for r in req_ids], np.int64)
        blk = jnp.asarray(pos // bs, jnp.int32)
        slot = jnp.asarray(pos % bs, jnp.int32)
        out: Dict[int, Tuple[jax.Array, Optional[jax.Array]]] = {}
        for l in (self.pool_layers() if layers is None else layers):
            c = self.state["caches"][l]
            k = c["k"][rows, :, blk, slot]                    # (R, Hkv, D)
            v = c["v"][rows, :, blk, slot] if "v" in c else None
            self.d2h_readback_bytes += k.nbytes + (
                0 if v is None else v.nbytes)
            out[l] = (k, v)
        return out

    def restore_blocks(self, req_id: str, layer: int, blocks: List[int],
                       k_host: np.ndarray,
                       v_host: Optional[np.ndarray]) -> None:
        """Scatter a fused FlashH2D payload (from
        ``KVCacheManager.load_blocks_fused``) directly into this request's
        device slots.  k_host/v_host: (Hkv, K, bs, D)."""
        self.restore_blocks_fused(layer, {req_id: (blocks, k_host, v_host)})

    def restore_blocks_fused(self, layer: int,
                             payload_by_req: Dict[str, Tuple[List[int],
                                                             np.ndarray,
                                                             Any]],
                             before_use: bool = False) -> None:
        """Land one layer's fused FlashH2D payloads for the WHOLE batch in
        a single pool update (mirrors the one-launch-per-layer transfer:
        one device-buffer update per layer per iteration, not one per
        request).  payload_by_req: {req_id: (blocks, k (Hkv,K,bs,D),
        v | None)}.  before_use: the restore lands between this layer's
        select and attend stages (staged plane) — i.e. BEFORE the attention
        that selected the blocks — and is counted separately so the
        restore-ordering rate is observable (bench_overlap)."""
        c = self.state["caches"][layer]
        H = c["k"].shape[1]
        rows_l: List[int] = []
        blks_l: List[int] = []
        ks: List[np.ndarray] = []
        vs: List[np.ndarray] = []
        has_v = "v" in c
        for req_id, (blocks, k_host, v_host) in payload_by_req.items():
            row = self.rows[req_id]
            rows_l.extend([row] * len(blocks))
            blks_l.extend(blocks)
            # MLA: the host pool broadcasts the single latent head over
            # geom.num_kv_heads; the device pool keeps one — use the first
            ks.append(np.asarray(k_host)[:H])
            if has_v and v_host is not None:
                vs.append(np.asarray(v_host)[:H])
        if not blks_l:
            return
        if USE_PALLAS_PLANE:
            # kernel-demonstration route: per-row Pallas scatters
            for req_id, (blocks, k_host, v_host) in payload_by_req.items():
                row = self.rows[req_id]
                c["k"] = scatter_row_blocks(c["k"], row, blocks,
                                            jnp.asarray(k_host[:H]))
                if has_v and v_host is not None:
                    c["v"] = scatter_row_blocks(c["v"], row, blocks,
                                                jnp.asarray(v_host[:H]))
            self.blocks_restored += len(blks_l)
            if before_use:
                self.blocks_restored_before_use += len(blks_l)
            return
        rows = jnp.asarray(rows_l, jnp.int32)
        blks = jnp.asarray(blks_l, jnp.int32)
        # (Hkv, K_total, bs, D) -> (K_total, Hkv, bs, D): advanced indices
        # at axes 0 and 2 put the gathered axis first in the update shape
        k_all = jnp.asarray(np.concatenate(ks, axis=1).transpose(1, 0, 2, 3))
        c["k"] = c["k"].at[rows, :, blks].set(k_all.astype(c["k"].dtype))
        if has_v and vs:
            v_all = jnp.asarray(
                np.concatenate(vs, axis=1).transpose(1, 0, 2, 3))
            c["v"] = c["v"].at[rows, :, blks].set(v_all.astype(c["v"].dtype))
        self.blocks_restored += len(blks_l)
        if before_use:
            self.blocks_restored_before_use += len(blks_l)

    def drop_blocks(self, req_id: str, layer: int,
                    blocks: List[int]) -> None:
        """Zero evicted blocks' device data (HBM eviction -> device memory
        actually dropped).  Block METADATA is kept resident so DSA scoring
        stays exact; re-selected blocks come back via ``restore_blocks``."""
        row = self.rows[req_id]
        c = self.state["caches"][layer]
        idx = jnp.asarray(blocks, jnp.int32)
        zero = jnp.zeros((c["k"].shape[1], len(blocks)) + c["k"].shape[3:],
                         c["k"].dtype)
        c["k"] = scatter_row_blocks(c["k"], row, idx, zero)
        if "v" in c:
            c["v"] = scatter_row_blocks(c["v"], row, idx, zero)
        self.blocks_dropped += len(blocks)

    # -- introspection -----------------------------------------------------

    def extract(self, req_id: str) -> Dict:
        """Copy one request's state back out (B=1, pools trimmed to the
        request's own block counts) — tests/debugging, not the hot path."""
        row = self.rows[req_id]
        nbs = self.row_layout[req_id]
        caches: List[Any] = []
        for l, c in enumerate(self.state["caches"]):
            if M.is_pool_cache(c):
                caches.append({key: v[row:row + 1, :, :nbs[l]]
                               for key, v in c.items()})
            else:
                caches.append(jax.tree.map(lambda x: x[row:row + 1], c))
        return {"caches": caches,
                "cur_len": self.state["cur_len"][row:row + 1],
                "extra": (jax.tree.map(lambda x: x[row:row + 1],
                                       self.state["extra"])
                          if self.state["extra"] else {})}

    def device_bytes(self) -> int:
        if self.state is None:
            return 0
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.state))
