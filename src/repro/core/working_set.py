"""Working-set estimation (paper §3.3).

Decode: the working set of a request is the union of KV blocks it selected
over the last ``w`` decode steps (w=12 by default — Fig. 8 shows the overlap
ratio plateaus there).  Prefill: computed exactly — full-prompt KV for
chunked prefill, ONE layer of KV for layer-segmented prefill.
"""
from __future__ import annotations

import collections
from typing import Deque, FrozenSet, Iterable, Optional, Set, Tuple

from repro.core.kv_cache import KVGeometry


class DecodeWorkingSet:
    """Sliding-window union of selected (layer, block) ids for one request."""

    def __init__(self, geom: KVGeometry, window: int = 12):
        self.geom = geom
        self.window = window
        self._hist: Deque[FrozenSet[Tuple[int, int]]] = collections.deque(
            maxlen=window)

    def observe(self, selected: Iterable[Tuple[int, int]]) -> None:
        """Record the (layer, block) selection of one decode step."""
        self._hist.append(frozenset(selected))

    def union(self) -> Set[Tuple[int, int]]:
        out: Set[Tuple[int, int]] = set()
        for s in self._hist:
            out |= s
        return out

    def size_blocks(self) -> int:
        return len(self.union())

    def size_bytes(self) -> int:
        per_lb = self.geom.block_bytes_per_head * self.geom.num_kv_heads
        return self.size_blocks() * per_lb

    def overlap_with_last(self, selected: Iterable[Tuple[int, int]]) -> float:
        """Fraction of `selected` already in the window union (Fig. 8)."""
        sel = set(selected)
        if not sel:
            return 1.0
        return len(sel & self.union()) / len(sel)


def estimate_decode_ws_bytes(ws: DecodeWorkingSet, geom: KVGeometry,
                             top_k_blocks: int, num_layers: int) -> int:
    """Working set estimate for the NEXT step: history union if available,
    else the worst case (top-k fresh blocks for every layer).

    ``num_layers`` must be the ATTENTION-layer count: recurrent (mamba/rwkv)
    layers hold no paged KV, so counting them would make Algorithm 1
    over-throttle hybrid (jamba-style) batches in the cold-start worst case.
    """
    per_lb = geom.block_bytes_per_head * geom.num_kv_heads
    if ws.size_blocks() == 0:
        return top_k_blocks * num_layers * per_lb
    return ws.size_bytes()


def estimate_prefill_ws_bytes(geom: KVGeometry, prompt_tokens: int,
                              mode: str,
                              num_attn_layers: Optional[int] = None) -> int:
    """Exact prefill working set (§3.3 "Prefill working set").

    chunked: KV of ALL attention layers of the whole prompt must stay in
    HBM.  layer_segmented: bounded to ONE layer (previous layers evicted to
    DRAM).

    The layer multiplier is the ATTENTION-layer count — recurrent layers
    produce no paged KV.  ``geom.num_layers`` already carries that count
    when the geometry was built from ``cfg.num_attention_layers()`` (the
    engine and simulator both do); ``num_attn_layers`` overrides it for
    callers whose geometry tracks all model layers.
    """
    per_token_layer = (geom.head_dim * geom.dtype_bytes * geom.kv_factor
                       * geom.num_kv_heads)
    L = geom.num_layers if num_attn_layers is None else num_attn_layers
    if mode == "chunked":
        return prompt_tokens * per_token_layer * L
    elif mode == "layer_segmented":
        return prompt_tokens * per_token_layer
    raise ValueError(f"unknown prefill mode {mode!r}")
