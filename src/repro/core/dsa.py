"""Dynamic Sparse Attention (DSA) primitives — paper §2.2.

DSAs partition the KV cache into blocks of ``block_size`` consecutive tokens,
keep small per-block metadata, and per query token (1) estimate each block's
criticality from the metadata, (2) select the top-k blocks, (3) run attention
over only those blocks.

Two metadata constructions are supported (both from the literature the paper
builds on):

* ``"mean"``   — the mean key vector of the block (InfLLM [45]).
* ``"cuboid"`` — the per-dimension min/max bounding cuboid of the block's
  keys (Quest [41] / ArkVale [9]); criticality is the *upper bound* of
  q·k over the cuboid:  sum_d max(q_d * min_d, q_d * max_d).

Shape conventions (decode, single query token):
    q          (B, Hq, D)
    kv pool    (B, Hkv, NB, bs, D)     -- paper's (H, N, D) head-major layout
    meta mean  (B, Hkv, NB, D)
    meta cuboid(B, Hkv, NB, 2, D)      -- [min, max]
    scores     (B, Hkv, NB)            -- group-reduced over GQA query heads
    selection  (B, Hkv, K) int32

All functions are pure jnp and jit/shard_map friendly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import DSAConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Metadata construction (runs when a block fills up — KV manager / prefill)
# ---------------------------------------------------------------------------

def build_block_metadata(keys: jax.Array, method: str = "cuboid",
                         valid: Optional[jax.Array] = None) -> jax.Array:
    """Build per-block metadata from block keys.

    keys:  (..., NB, bs, D)
    valid: optional (..., NB, bs) bool — tokens actually written.
    returns: mean -> (..., NB, D); cuboid -> (..., NB, 2, D)
    """
    kf = keys.astype(jnp.float32)
    if method == "mean":
        if valid is None:
            return jnp.mean(kf, axis=-2)
        v = valid[..., None].astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(v, axis=-2), 1.0)
        return jnp.sum(kf * v, axis=-2) / denom
    elif method == "cuboid":
        if valid is None:
            mn = jnp.min(kf, axis=-2)
            mx = jnp.max(kf, axis=-2)
        else:
            v = valid[..., None]
            mn = jnp.min(jnp.where(v, kf, jnp.inf), axis=-2)
            mx = jnp.max(jnp.where(v, kf, -jnp.inf), axis=-2)
            # fully-empty blocks: zero cuboid (scored but masked elsewhere)
            any_valid = jnp.any(valid, axis=-1)[..., None]
            mn = jnp.where(any_valid, mn, 0.0)
            mx = jnp.where(any_valid, mx, 0.0)
        return jnp.stack([mn, mx], axis=-2)
    raise ValueError(f"unknown DSA metadata method: {method}")


def metadata_shape(cfg: DSAConfig, num_blocks: int, head_dim: int,
                   prefix=()) -> Tuple[int, ...]:
    if cfg.metadata == "mean":
        return (*prefix, num_blocks, head_dim)
    return (*prefix, num_blocks, 2, head_dim)


# ---------------------------------------------------------------------------
# Block criticality scoring
# ---------------------------------------------------------------------------

def score_blocks(q: jax.Array, meta: jax.Array, method: str = "cuboid",
                 group_reduce: str = "max") -> jax.Array:
    """Estimate block criticality for each query head, reduce over GQA group.

    q:    (B, Hq, D)
    meta: (B, Hkv, NB, D) or (B, Hkv, NB, 2, D)
    returns scores (B, Hkv, NB) float32
    """
    B, Hq, D = q.shape
    Hkv = meta.shape[1]
    group = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D)
    if method == "mean":
        # (B,Hkv,G,D) x (B,Hkv,NB,D) -> (B,Hkv,G,NB)
        s = jnp.einsum("bhgd,bhnd->bhgn", qf, meta.astype(jnp.float32))
    elif method == "cuboid":
        mn = meta[..., 0, :].astype(jnp.float32)   # (B,Hkv,NB,D)
        mx = meta[..., 1, :].astype(jnp.float32)
        lo = jnp.einsum("bhgd,bhnd->bhgn", qf, mn)
        hi = jnp.einsum("bhgd,bhnd->bhgn", qf, mx)
        s = jnp.maximum(lo, hi)  # == sum_d max(q_d*mn_d, q_d*mx_d) per-dim?
        # NOTE: true Quest bound maxes per-dimension BEFORE summing; do that:
        pos = jnp.maximum(qf, 0.0)
        neg = jnp.minimum(qf, 0.0)
        s = (jnp.einsum("bhgd,bhnd->bhgn", pos, mx)
             + jnp.einsum("bhgd,bhnd->bhgn", neg, mn))
    else:
        raise ValueError(f"unknown DSA metadata method: {method}")
    if group_reduce == "max":
        return jnp.max(s, axis=2)
    elif group_reduce == "sum":
        return jnp.sum(s, axis=2)
    raise ValueError(group_reduce)


# ---------------------------------------------------------------------------
# Top-k block selection
# ---------------------------------------------------------------------------

def selected_block_ids(sel_row) -> list:
    """Host-side de-dup of one request's selection: (Hkv, K) indices ->
    sorted unique block ids.  This is the unit the serving engine feeds to
    the per-layer LRU (``KVCacheManager.access_layer``) — invalid selections
    were already substituted with block 0 by ``select_blocks``, which is a
    force-included sink block, so no filtering is needed here."""
    return sorted({int(b) for b in np.asarray(sel_row).ravel()})


def select_blocks(scores: jax.Array, cfg: DSAConfig, cur_len: jax.Array,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Select top-k KV blocks per (batch, kv-head).

    scores : (B, Hkv, NB) float32
    cur_len: (B,) int32 — tokens currently in the cache (per request).
    returns (indices (B,Hkv,K) int32, sel_valid (B,Hkv,K) bool)

    Invalid (unwritten) blocks are masked out.  Sink blocks (prefix) and the
    most recent blocks are force-included by score override — DSAs keep
    attention sinks + local context unconditionally.
    """
    B, Hkv, NB = scores.shape
    k = min(cfg.top_k_blocks, NB)
    blk_ids = jnp.arange(NB, dtype=jnp.int32)
    n_valid = jnp.ceil(cur_len.astype(jnp.float32) / cfg.block_size
                       ).astype(jnp.int32)                       # (B,)
    valid = blk_ids[None, :] < n_valid[:, None]                   # (B, NB)
    s = jnp.where(valid[:, None, :], scores, NEG_INF)
    # force-include sinks + recent blocks
    if cfg.sink_blocks > 0:
        sink = (blk_ids[None, :] < jnp.minimum(cfg.sink_blocks, n_valid)[:, None])
        s = jnp.where(sink[:, None, :] & valid[:, None, :], jnp.inf, s)
    if cfg.recent_blocks > 0:
        recent = (blk_ids[None, :] >= (n_valid - cfg.recent_blocks)[:, None])
        s = jnp.where(recent[:, None, :] & valid[:, None, :], jnp.inf, s)
    top_scores, top_idx = jax.lax.top_k(s, k)                     # (B,Hkv,K)
    sel_valid = top_scores > NEG_INF / 2
    top_idx = jnp.where(sel_valid, top_idx, 0).astype(jnp.int32)
    return top_idx, sel_valid


# ---------------------------------------------------------------------------
# Reference block-sparse decode attention (pure jnp oracle; the Pallas
# kernel in kernels/sparse_decode_attention.py matches this)
# ---------------------------------------------------------------------------

def sparse_decode_attention_ref(
        q: jax.Array,            # (B, Hq, D)
        k_pool: jax.Array,       # (B, Hkv, NB, bs, D)
        v_pool: jax.Array,       # (B, Hkv, NB, bs, Dv)
        block_idx: jax.Array,    # (B, Hkv, K) int32
        sel_valid: jax.Array,    # (B, Hkv, K) bool
        cur_len: jax.Array,      # (B,) int32
        scale: Optional[float] = None) -> jax.Array:
    """Attention over only the selected KV blocks.  Returns (B, Hq, Dv)."""
    B, Hq, D = q.shape
    _, Hkv, NB, bs, _ = k_pool.shape
    Dv = v_pool.shape[-1]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)

    # gather selected blocks: (B, Hkv, K, bs, D)
    k_sel = jnp.take_along_axis(k_pool, block_idx[..., None, None], axis=2)
    v_sel = jnp.take_along_axis(v_pool, block_idx[..., None, None], axis=2)

    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D)
    s = jnp.einsum("bhgd,bhksd->bhgks", qf, k_sel.astype(jnp.float32)) * scale

    # token-validity inside selected blocks: global position < cur_len
    tok_in_blk = jnp.arange(bs, dtype=jnp.int32)
    pos = block_idx[..., None] * bs + tok_in_blk                  # (B,Hkv,K,bs)
    tok_valid = pos < cur_len[:, None, None, None]
    mask = tok_valid & sel_valid[..., None]
    s = jnp.where(mask[:, :, None, :, :], s, NEG_INF)

    s = s.reshape(B, Hkv, group, -1)
    p = jax.nn.softmax(s, axis=-1)
    v_flat = v_sel.astype(jnp.float32).reshape(B, Hkv, -1, Dv)
    o = jnp.einsum("bhgt,bhtd->bhgd", p, v_flat)
    return o.reshape(B, Hq, Dv).astype(q.dtype)


def sparse_decode_attention_partial(
        q: jax.Array,            # (B, Hq, D)
        k_pool: jax.Array,       # (B, Hkv, NB_loc, bs, D) — LOCAL shard
        v_pool: jax.Array,       # (B, Hkv, NB_loc, bs, Dv)
        block_idx: jax.Array,    # (B, Hkv, K) int32 LOCAL block ids
        sel_valid: jax.Array,    # (B, Hkv, K) bool (False for remote blocks)
        cur_len: jax.Array,      # (B,) int32 GLOBAL length
        block_offset,            # global id of this shard's block 0
        scale: Optional[float] = None):
    """Unnormalized flash-style partials for context-parallel decode.

    Returns (acc (B,Hq,Dv), m (B,Hq), l (B,Hq)): softmax statistics over the
    LOCAL selected blocks only; shards combine with the usual logsumexp
    merge (pmax m, rescale, psum l/acc).  Token validity uses GLOBAL
    positions via block_offset."""
    B, Hq, D = q.shape
    _, Hkv, NB, bs, _ = k_pool.shape
    Dv = v_pool.shape[-1]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)

    k_sel = jnp.take_along_axis(k_pool, block_idx[..., None, None], axis=2)
    v_sel = jnp.take_along_axis(v_pool, block_idx[..., None, None], axis=2)
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D)
    s = jnp.einsum("bhgd,bhksd->bhgks", qf, k_sel.astype(jnp.float32)) * scale

    tok_in_blk = jnp.arange(bs, dtype=jnp.int32)
    pos = (block_idx[..., None] + block_offset) * bs + tok_in_blk
    tok_valid = pos < cur_len[:, None, None, None]
    mask = tok_valid & sel_valid[..., None]
    s = jnp.where(mask[:, :, None, :, :], s, NEG_INF)

    s = s.reshape(B, Hkv, group, -1)
    m = jnp.max(s, axis=-1)                                  # (B,Hkv,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)                  # empty shards
    l = jnp.sum(p, axis=-1)
    v_flat = v_sel.astype(jnp.float32).reshape(B, Hkv, -1, Dv)
    acc = jnp.einsum("bhgt,bhtd->bhgd", p, v_flat)
    return (acc.reshape(B, Hq, Dv), m.reshape(B, Hq), l.reshape(B, Hq))


def full_decode_attention_ref(q, k_pool, v_pool, cur_len, scale=None):
    """Dense (non-sparse) decode attention oracle over the whole pool."""
    B, Hq, D = q.shape
    _, Hkv, NB, bs, Dv = v_pool.shape
    all_idx = jnp.broadcast_to(jnp.arange(NB, dtype=jnp.int32),
                               (B, Hkv, NB))
    valid = jnp.ones((B, Hkv, NB), dtype=bool)
    return sparse_decode_attention_ref(q, k_pool, v_pool, all_idx, valid,
                                       cur_len, scale)
