"""Hierarchical HBM–DRAM KV cache manager (paper §3.1 "KV Cache Manager").

Control plane: block-table bookkeeping, HBM LRU cache, transfer accounting.
Data plane: host-resident block pools (numpy) + device working buffers, with
FlashH2D (fused gather) loading and FlashD2H (contiguous flush + deferred
scatter) saving — `repro.kernels.gather_blocks` / `scatter_blocks`.

Blocks are tracked per (layer, kv_head, block_id) — the paper's per-head
granularity (Fig. 5, (H, N, D) layout) — so transfer sizes and hit rates
match what an A100/v5e deployment would see.

All byte/transfer counters feed the cost model (`serving/costmodel.py`)
and the Fig. 4 / Fig. 14 / Fig. 15 benchmarks.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.tracing import NULL_TRACER


@dataclasses.dataclass
class KVGeometry:
    """Shape of one request's KV cache."""
    num_layers: int          # attention layers only
    num_kv_heads: int
    block_size: int          # tokens per block
    head_dim: int            # cached dim per token per head (MLA: latent)
    dtype_bytes: int = 2     # bf16
    kv_factor: int = 2       # k and v (MLA latent: 1)

    @property
    def block_bytes_per_head(self) -> int:
        return self.block_size * self.head_dim * self.dtype_bytes * self.kv_factor

    @property
    def block_bytes(self) -> int:
        """One block id across all layers+heads (working-set accounting)."""
        return self.block_bytes_per_head * self.num_kv_heads * self.num_layers

    def tokens_bytes(self, n_tokens: int) -> int:
        return (n_tokens * self.head_dim * self.dtype_bytes * self.kv_factor
                * self.num_kv_heads * self.num_layers)


@dataclasses.dataclass
class TransferStats:
    h2d_bytes: int = 0
    h2d_calls: int = 0          # fused kernel launches (FlashH2D)
    h2d_blocks: int = 0         # fragmented blocks moved
    d2h_bytes: int = 0
    d2h_calls: int = 0
    d2h_blocks: int = 0
    evictions: int = 0
    hits: int = 0
    misses: int = 0

    def merge(self, o: "TransferStats") -> None:
        for f in dataclasses.fields(TransferStats):
            setattr(self, f.name, getattr(self, f.name) + getattr(o, f.name))


class HBMCache:
    """LRU cache of HBM-resident KV blocks for ONE request.

    Keys are (layer, block_id); all kv heads of a block move together (the
    per-head transfer granularity is reflected in byte accounting).  The LRU
    policy exploits the temporal locality of DSA block selection —
    consecutive query tokens select highly-overlapping blocks (§3.1/Fig. 8).
    """

    def __init__(self, geom: KVGeometry, capacity_blocks: int):
        self.geom = geom
        self.capacity = capacity_blocks            # in (layer, block) units
        self._lru: "collections.OrderedDict[Tuple[int,int], bool]" = \
            collections.OrderedDict()
        # eviction keys are recorded only when a consumer drains them
        # (engine with drop_evicted_device_blocks): unconditional recording
        # would grow without bound on the default path
        self.track_evictions = False
        self._evicted: List[Tuple[int, int]] = []  # since last pop_evicted
        self.stats = TransferStats()

    def resident(self, layer: int, block: int) -> bool:
        return (layer, block) in self._lru

    @property
    def num_resident(self) -> int:
        return len(self._lru)

    def access(self, layer: int, blocks: List[int]) -> List[int]:
        """Touch `blocks` for `layer`; return the MISSING block ids (to load).

        Units: `blocks` are block *ids* (``block_size`` tokens each); one
        LRU entry is one (layer, block) key covering all kv heads.  Evicts
        LRU entries beyond capacity (retrievable until the next
        ``pop_evicted``).  Residency accounting ONLY (hits/misses/
        evictions): the actual FlashH2D transfer — and its h2d_* stats —
        happens exactly once, in the data plane (``HostPool.load_blocks`` /
        ``KVCacheManager.load_blocks_fused``), so ``total_stats`` never
        double-counts a transfer.
        """
        missing = []
        for b in blocks:
            key = (layer, b)
            if key in self._lru:
                self._lru.move_to_end(key)
                self.stats.hits += 1
            else:
                missing.append(b)
                self.stats.misses += 1
        for b in missing:
            self._lru[(layer, b)] = True
        self._evict_over_capacity()
        return missing

    def _evict_over_capacity(self) -> None:
        while len(self._lru) > self.capacity:
            key = self._lru.popitem(last=False)[0]
            if self.track_evictions:
                self._evicted.append(key)
            self.stats.evictions += 1

    def pop_evicted(self) -> List[Tuple[int, int]]:
        """Drain the (layer, block) keys evicted since the last call — the
        engine zeroes these device slots when
        ``drop_evicted_device_blocks`` is on (which also sets
        ``track_evictions``; keys are not recorded otherwise)."""
        out, self._evicted = self._evicted, []
        return out

    def insert(self, layer: int, block: int) -> None:
        """Insert a freshly produced block (decode append) without a load."""
        self._lru[(layer, block)] = True
        self._lru.move_to_end((layer, block))
        self._evict_over_capacity()

    def drop_layer(self, layer: int) -> int:
        """Evict all blocks of one layer (layer-segmented prefill §3.4)."""
        keys = [k for k in self._lru if k[0] == layer]
        for k in keys:
            del self._lru[k]
        return len(keys)


class HostPool:
    """Host-DRAM block pool for ONE request (data plane).

    Stores K/V blocks as numpy arrays shaped (L, Hkv, NB, bs, D).  Saving
    follows FlashD2H: the contiguous per-iteration KV stripe is appended to
    a staging buffer in one "memcpy" and scattered into blocks lazily
    (``flush``), mirroring the paper's CPU-assisted two-phase save.
    """

    def __init__(self, geom: KVGeometry, num_blocks: int):
        g = geom
        self.geom = g
        self.num_blocks = num_blocks
        shape = (g.num_layers, g.num_kv_heads, num_blocks, g.block_size,
                 g.head_dim)
        self.k = np.zeros(shape, np.float32)
        self.v = np.zeros(shape, np.float32) if g.kv_factor == 2 else None
        self._staging: List[Tuple[int, int, np.ndarray, Optional[np.ndarray]]] = []
        self.stats = TransferStats()

    def stage(self, layer: int, start_token: int, k_new: np.ndarray,
              v_new: Optional[np.ndarray]) -> int:
        """Append one contiguous KV stripe to the staging buffer WITHOUT
        booking d2h stats (callers that represent one fused launch across
        many pools — ``KVCacheManager.save_new_tokens_fused`` — account the
        launch themselves; ``save_contiguous`` accounts per-call).

        k_new/v_new: (Hkv, T, D) for T new tokens starting at absolute
        token position ``start_token``.  Bounds contract: the stripe
        [start_token, start_token+T) must fit the pool registered at
        ``KVCacheManager.register`` time — out-of-range stripes raise
        ``ValueError`` immediately rather than corrupting block state.
        Returns the stripe's byte size (both K and V)."""
        end_token = start_token + k_new.shape[1]
        max_tokens = self.num_blocks * self.geom.block_size
        if start_token < 0 or end_token > max_tokens:
            raise ValueError(
                f"HostPool.stage: tokens [{start_token}, {end_token})"
                f" exceed the registered pool capacity of {max_tokens} tokens"
                f" ({self.num_blocks} blocks x {self.geom.block_size}); "
                f"register the request with a larger max_tokens")
        self._staging.append((layer, start_token, np.asarray(k_new),
                              None if v_new is None else np.asarray(v_new)))
        return k_new.nbytes * (2 if v_new is not None else 1)

    def save_contiguous(self, layer: int, start_token: int, k_new: np.ndarray,
                        v_new: Optional[np.ndarray]) -> None:
        """Phase 1 of FlashD2H: one contiguous D2H transfer into staging.

        k_new/v_new: (Hkv, T, D) for T new tokens starting at start_token.
        Books exactly one ``d2h_calls`` (the contiguous DMA) and its bytes;
        the CPU-side block scatter is deferred to ``flush`` (which books
        ``d2h_blocks`` only — a staged byte is never double-counted)."""
        nbytes = self.stage(layer, start_token, k_new, v_new)
        self.stats.d2h_calls += 1
        self.stats.d2h_bytes += nbytes

    def flush(self) -> int:
        """Phase 2 of FlashD2H: CPU-side scatter of staged stripes into the
        per-head block layout.  Returns blocks written."""
        g = self.geom
        written = 0
        for layer, start, k_new, v_new in self._staging:
            T = k_new.shape[1]
            t0 = 0
            while t0 < T:
                blk = (start + t0) // g.block_size
                off = (start + t0) % g.block_size
                if blk >= self.num_blocks:
                    raise ValueError(
                        f"HostPool.flush: staged token {start + t0} maps to "
                        f"block {blk} but the pool only has "
                        f"{self.num_blocks} blocks")
                # split on block boundaries (start may be mid-block)
                t1 = min(t0 + (g.block_size - off), T)
                self.k[layer, :, blk, off:off + (t1 - t0)] = k_new[:, t0:t1]
                if v_new is not None:
                    self.v[layer, :, blk, off:off + (t1 - t0)] = v_new[:, t0:t1]
                written += 1
                self.stats.d2h_blocks += 1
                t0 = t1
        self._staging.clear()
        return written

    def gather(self, layer: int, blocks: List[int]
               ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Data-plane gather of fragmented blocks — NO accounting.

        Returns (k (Hkv, K, bs, D), v or None).  Callers that represent one
        fused kernel launch record the h2d_* stats themselves (either
        ``load_blocks`` below or ``KVCacheManager.load_blocks_fused``)."""
        if blocks and (max(blocks) >= self.num_blocks or min(blocks) < 0):
            bad = max(blocks) if max(blocks) >= self.num_blocks \
                else min(blocks)
            raise ValueError(
                f"HostPool.gather: block {bad} out of range "
                f"(pool has {self.num_blocks} blocks)")
        idx = np.asarray(blocks, np.int32)
        k = self.k[layer][:, idx]
        v = None if self.v is None else self.v[layer][:, idx]
        return k, v

    def load_blocks(self, layer: int, blocks: List[int]
                    ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """FlashH2D data plane: ONE fused gather of fragmented blocks.

        Returns (k (Hkv, K, bs, D), v or None)."""
        k, v = self.gather(layer, blocks)
        nbytes = k.nbytes * (1 if v is None else 2)
        self.stats.h2d_calls += 1
        self.stats.h2d_blocks += len(blocks) * self.geom.num_kv_heads
        self.stats.h2d_bytes += nbytes
        return k, v


class KVCacheManager:
    """System-wide manager: per-request HBM caches + host pools + global
    HBM budget (M_avl feeds the scheduler's Algorithm 1)."""

    def __init__(self, geom: KVGeometry, hbm_budget_bytes: int,
                 host_budget_bytes: Optional[int] = None):
        self.geom = geom
        self.hbm_budget_bytes = hbm_budget_bytes
        self.host_budget_bytes = host_budget_bytes
        self.caches: Dict[str, HBMCache] = {}
        self.pools: Dict[str, HostPool] = {}
        self._retired_stats = TransferStats()   # stats of released requests
        self.fused_stats = TransferStats()      # batched FlashH2D launches
        self.tracer = NULL_TRACER               # engine installs a live
                                                # Tracer when obs is on

    # -- lifecycle ---------------------------------------------------------
    def register(self, req_id: str, max_tokens: int,
                 hbm_blocks_per_request: int) -> None:
        nb = -(-max_tokens // self.geom.block_size)
        self.caches[req_id] = HBMCache(self.geom, hbm_blocks_per_request)
        self.pools[req_id] = HostPool(self.geom, nb)

    def release(self, req_id: str) -> None:
        c = self.caches.pop(req_id, None)
        p = self.pools.pop(req_id, None)
        if c is not None:
            self._retired_stats.merge(c.stats)
        if p is not None:
            self._retired_stats.merge(p.stats)

    # -- control plane -----------------------------------------------------
    def access_layer(self, layer: int, blocks_by_req: Dict[str, List[int]],
                     drain_evicted: bool = False
                     ) -> Tuple[Dict[str, List[int]],
                                Dict[str, List[Tuple[int, int]]]]:
        """Touch one layer's selected blocks for every request of a decode
        iteration (LRU residency only — no transfer accounting; see
        ``HBMCache.access``).

        The per-layer unit matches the decode planes: the fused plane calls
        this once per layer after its single forward, the staged plane calls
        it between a layer's select and attend stages so the returned
        ``missing`` can be loaded (``load_blocks_fused``) and restored into
        device slots BEFORE that layer's attention.

        `layer` is the attention-layer ordinal.  Returns
        (missing_by_req, evicted_by_req): the block ids each request must
        load, and — when ``drain_evicted`` — the (layer, block) keys each
        request's LRU evicted during these accesses (``pop_evicted``; empty
        lists otherwise).  Requests without a registered cache are skipped.
        """
        missing_by_req: Dict[str, List[int]] = {}
        evicted_by_req: Dict[str, List[Tuple[int, int]]] = {}
        for req_id, blocks in blocks_by_req.items():
            cache = self.caches.get(req_id)
            if cache is None:
                continue
            missing = cache.access(layer, blocks)
            if missing:
                missing_by_req[req_id] = missing
            if drain_evicted:
                evicted_by_req[req_id] = cache.pop_evicted()
        return missing_by_req, evicted_by_req

    # -- data plane --------------------------------------------------------
    def load_blocks_fused(self, layer: int,
                          blocks_by_req: Dict[str, List[int]]
                          ) -> Dict[str, Tuple[np.ndarray,
                                               Optional[np.ndarray]]]:
        """ONE fused FlashH2D launch covering every missing block of `layer`
        across the whole decode batch (batched engine hot path).

        The paper's FlashH2D kernel gathers fragmented blocks from pinned
        DRAM in a single launch; under batched decode the launch amortizes
        over ALL requests in the iteration, so h2d_calls grows
        per-layer-per-iteration, not per-request.  Accounting lives HERE and
        only here for these transfers (``HBMCache.access`` books residency
        only), so each moved block is counted exactly once: h2d_calls in
        fused launches, h2d_blocks in (block x kv-head) units, h2d_bytes in
        bytes of K+V payload.

        `layer` is the attention-layer ORDINAL (0..geom.num_layers-1), not
        the model layer id; `blocks_by_req` values are block ids, each
        bounds-checked by ``HostPool.gather`` against the pool registered
        at ``register`` time.  Returns {req_id: (k (Hkv,K,bs,D), v|None)} —
        under the persistent decode plane the engine scatters these
        payloads DIRECTLY into the requests' device slots
        (``DevicePoolPlane.restore_blocks``)."""
        tr = self.tracer
        if tr.enabled:
            _ts = time.perf_counter()
        out: Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]] = {}
        total_blocks = 0
        total_bytes = 0
        for req_id, blocks in blocks_by_req.items():
            pool = self.pools.get(req_id)
            if pool is None or not blocks:
                continue
            k, v = pool.gather(layer, blocks)
            out[req_id] = (k, v)
            total_blocks += len(blocks) * self.geom.num_kv_heads
            total_bytes += k.nbytes * (1 if v is None else 2)
        if total_blocks:
            self.fused_stats.h2d_calls += 1
            self.fused_stats.h2d_blocks += total_blocks
            self.fused_stats.h2d_bytes += total_bytes
            if tr.enabled:
                tr.end("FlashH2D", "transfer", _ts, layer=layer,
                       blocks=total_blocks, bytes=total_bytes,
                       fused_reqs=len(out))
        return out

    def save_new_tokens_fused(self, layer: int,
                              kv_by_req: Dict[str, Tuple[int, np.ndarray,
                                                         Optional[np.ndarray]]]
                              ) -> None:
        """ONE fused FlashD2H save of this iteration's newly produced KV
        for `layer` across a whole batch — the decode planes' per-layer
        write-back AND the prefill plane's per-(layer, chunk)-group save
        (each batched prefill launch saves every request's stripe through
        one call here, replacing the legacy per-request
        ``save_contiguous`` loop).

        kv_by_req: {req_id: (start_token, k (Hkv,T,D), v or None)}.  Under
        batching the stripe is contiguous across the batch, so the paper
        saves it with one D2H DMA per layer per iteration; accordingly
        ``d2h_calls`` is booked ONCE here (on ``fused_stats``) while each
        pool stages its stripe without accounting (``HostPool.stage``).
        The CPU-side scatter into blocks still happens at each pool's
        ``flush``.  Keeping the host pool a byte-exact superset of device
        KV is what makes ``load_blocks_fused`` payloads safe to scatter
        straight into device slots."""
        tr = self.tracer
        if tr.enabled:
            _ts = time.perf_counter()
        total_bytes = 0
        for req_id, (start, k, v) in kv_by_req.items():
            pool = self.pools.get(req_id)
            if pool is None:
                continue
            total_bytes += pool.stage(layer, start, k, v)
        if total_bytes:
            self.fused_stats.d2h_calls += 1
            self.fused_stats.d2h_bytes += total_bytes
            # in async mode this fires on the HostStageWorker thread —
            # the tracer is thread-safe and books the span to that tid
            if tr.enabled:
                tr.end("FlashD2H", "transfer", _ts, layer=layer,
                       bytes=total_bytes, fused_reqs=len(kv_by_req))

    # -- accounting --------------------------------------------------------
    def hbm_used_bytes(self) -> int:
        per_lb = (self.geom.block_bytes_per_head * self.geom.num_kv_heads)
        return sum(c.num_resident * per_lb for c in self.caches.values())

    def total_stats(self) -> TransferStats:
        s = TransferStats()
        s.merge(self._retired_stats)
        s.merge(self.fused_stats)
        for c in self.caches.values():
            s.merge(c.stats)
        for p in self.pools.values():
            s.merge(p.stats)
        return s
