"""Hierarchical HBM–DRAM KV cache manager (paper §3.1 "KV Cache Manager").

Control plane: block-table bookkeeping, HBM LRU cache, transfer accounting.
Data plane: host-resident block pools (numpy) + device working buffers, with
FlashH2D (fused gather) loading and FlashD2H (contiguous flush + deferred
scatter) saving — `repro.kernels.gather_blocks` / `scatter_blocks`.

Blocks are tracked per (layer, kv_head, block_id) — the paper's per-head
granularity (Fig. 5, (H, N, D) layout) — so transfer sizes and hit rates
match what an A100/v5e deployment would see.

All byte/transfer counters feed the cost model (`serving/costmodel.py`)
and the Fig. 4 / Fig. 14 / Fig. 15 benchmarks.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.tracing import NULL_TRACER


@dataclasses.dataclass
class KVGeometry:
    """Shape of one request's KV cache."""
    num_layers: int          # attention layers only
    num_kv_heads: int
    block_size: int          # tokens per block
    head_dim: int            # cached dim per token per head (MLA: latent)
    dtype_bytes: int = 2     # bf16
    kv_factor: int = 2       # k and v (MLA latent: 1)

    @property
    def block_bytes_per_head(self) -> int:
        """Bytes of ONE block for ONE kv head at the MODELED device dtype
        (``dtype_bytes``, bf16 by default), K and V together
        (``kv_factor``).  This is the deployment-sized unit the cost model
        charges transfers in; it is independent of the f32 numpy pools the
        smoke data plane happens to hold, and of the offload tier's stored
        size (``HostPool.wire_bytes`` for that)."""
        return self.block_size * self.head_dim * self.dtype_bytes * self.kv_factor

    @property
    def block_bytes(self) -> int:
        """One block id across ALL layers and kv heads — the working-set
        unit (bytes per entry of a request's block table).  Scheduler
        admission (M_avl) and working-set estimates use this; per-transfer
        accounting uses the per-(layer, head) slices instead."""
        return self.block_bytes_per_head * self.num_kv_heads * self.num_layers

    def tokens_bytes(self, n_tokens: int) -> int:
        """Logical KV bytes of ``n_tokens`` across all layers/heads at the
        modeled dtype (no block-size round-up)."""
        return (n_tokens * self.head_dim * self.dtype_bytes * self.kv_factor
                * self.num_kv_heads * self.num_layers)


@dataclasses.dataclass
class TransferStats:
    """PCIe/DMA traffic counters, booked exactly once per moved byte.

    Units: ``*_bytes`` are bytes AS STORED IN THE OFFLOAD TIER (the wire
    size of the DMA) — under ``offload_quant="int8"`` that is the int8
    payload plus 4 B per (kv-head, block) scale, NOT the logical fp size;
    with the default fp tier the two coincide.  ``*_calls`` count fused
    kernel launches (one FlashH2D/FlashD2H per layer per iteration under
    batching), ``*_blocks`` count (block x kv-head) units moved.

    Who books what (the staged-vs-accounted split): ``HBMCache.access``
    books residency only (hits/misses/evictions); ``HostPool.stage``
    appends to staging WITHOUT booking (it returns the wire bytes so the
    one fused caller can book them); bytes/calls land at the single fused
    data-plane call (``KVCacheManager.load_blocks_fused`` /
    ``save_new_tokens_fused`` on ``fused_stats``, or the per-request
    ``HostPool.load_blocks`` / ``save_contiguous``); ``HostPool.flush``
    books ``d2h_blocks`` only — a staged byte is never counted twice.
    """
    h2d_bytes: int = 0          # wire bytes (stored size, see above)
    h2d_calls: int = 0          # fused kernel launches (FlashH2D)
    h2d_blocks: int = 0         # fragmented (block x kv-head) units moved
    d2h_bytes: int = 0
    d2h_calls: int = 0
    d2h_blocks: int = 0
    evictions: int = 0
    hits: int = 0
    misses: int = 0

    def merge(self, o: "TransferStats") -> None:
        for f in dataclasses.fields(TransferStats):
            setattr(self, f.name, getattr(self, f.name) + getattr(o, f.name))


class HBMCache:
    """LRU cache of HBM-resident KV blocks for ONE request.

    Keys are (layer, block_id); all kv heads of a block move together (the
    per-head transfer granularity is reflected in byte accounting).  The LRU
    policy exploits the temporal locality of DSA block selection —
    consecutive query tokens select highly-overlapping blocks (§3.1/Fig. 8).
    """

    def __init__(self, geom: KVGeometry, capacity_blocks: int):
        self.geom = geom
        self.capacity = capacity_blocks            # in (layer, block) units
        self._lru: "collections.OrderedDict[Tuple[int,int], bool]" = \
            collections.OrderedDict()
        # eviction keys are recorded only when a consumer drains them
        # (engine with drop_evicted_device_blocks): unconditional recording
        # would grow without bound on the default path
        self.track_evictions = False
        self._evicted: List[Tuple[int, int]] = []  # since last pop_evicted
        self.stats = TransferStats()

    def resident(self, layer: int, block: int) -> bool:
        return (layer, block) in self._lru

    @property
    def num_resident(self) -> int:
        return len(self._lru)

    def access(self, layer: int, blocks: List[int]) -> List[int]:
        """Touch `blocks` for `layer`; return the MISSING block ids (to load).

        Units: `blocks` are block *ids* (``block_size`` tokens each); one
        LRU entry is one (layer, block) key covering all kv heads.  Evicts
        LRU entries beyond capacity (retrievable until the next
        ``pop_evicted``).  Residency accounting ONLY (hits/misses/
        evictions): the actual FlashH2D transfer — and its h2d_* stats —
        happens exactly once, in the data plane (``HostPool.load_blocks`` /
        ``KVCacheManager.load_blocks_fused``), so ``total_stats`` never
        double-counts a transfer.
        """
        missing = []
        for b in blocks:
            key = (layer, b)
            if key in self._lru:
                self._lru.move_to_end(key)
                self.stats.hits += 1
            else:
                missing.append(b)
                self.stats.misses += 1
        for b in missing:
            self._lru[(layer, b)] = True
        self._evict_over_capacity()
        return missing

    def _evict_over_capacity(self) -> None:
        while len(self._lru) > self.capacity:
            key = self._lru.popitem(last=False)[0]
            if self.track_evictions:
                self._evicted.append(key)
            self.stats.evictions += 1

    def pop_evicted(self) -> List[Tuple[int, int]]:
        """Drain the (layer, block) keys evicted since the last call — the
        engine zeroes these device slots when
        ``drop_evicted_device_blocks`` is on (which also sets
        ``track_evictions``; keys are not recorded otherwise)."""
        out, self._evicted = self._evicted, []
        return out

    def insert(self, layer: int, block: int) -> None:
        """Insert a freshly produced block (decode append) without a load."""
        self._lru[(layer, block)] = True
        self._lru.move_to_end((layer, block))
        self._evict_over_capacity()

    def drop_layer(self, layer: int) -> int:
        """Evict all blocks of one layer (layer-segmented prefill §3.4)."""
        keys = [k for k in self._lru if k[0] == layer]
        for k in keys:
            del self._lru[k]
        return len(keys)


QUANT_SCALE_BYTES = 4  # one f32 scale per (kv-head, block) per tensor


def _quantize_block_np(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 quantization of one block, per kv head.

    x: (Hkv, bs, D) fp -> (q (Hkv, bs, D) int8, scales (Hkv,) f32) with
    scale = amax/127 per head; matches ``kernels.ref.quantize_blocks``
    bit-for-bit (``np.rint`` == ``jnp.rint``, round-half-to-even)."""
    xf = x.astype(np.float32)
    amax = np.max(np.abs(xf), axis=(1, 2))
    scales = (amax / 127.0).astype(np.float32)
    # reciprocal-multiply in f32, same as the kernel/ref paths — division
    # here would flip exact .5 rounding boundaries vs the kernels
    inv = np.where(scales > 0.0,
                   np.float32(1.0) / np.where(scales > 0.0, scales,
                                              np.float32(1.0)),
                   np.float32(1.0)).astype(np.float32)
    q = np.clip(np.rint(xf * inv[:, None, None]), -127, 127).astype(np.int8)
    return q, scales


def _dequantize_block_np(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Inverse of ``_quantize_block_np``: (Hkv, bs, D) int8 + (Hkv,) f32
    -> (Hkv, bs, D) f32."""
    return q.astype(np.float32) * scales[:, None, None]


class HostPool:
    """Host-DRAM block pool for ONE request (data plane).

    Stores K/V blocks as numpy arrays shaped (L, Hkv, NB, bs, D).  Saving
    follows FlashD2H: the contiguous per-iteration KV stripe is appended to
    a staging buffer in one "memcpy" and scattered into blocks lazily
    (``flush``), mirroring the paper's CPU-assisted two-phase save.

    ``quant="int8"`` switches the pool to the quantized offload tier: the
    K/V arrays hold int8 with per-(layer, kv-head, block) f32 scales
    (``k_scale``/``v_scale``), blocks quantize at ``flush`` and dequantize
    at ``gather``, and every byte counter reports the STORED size (int8
    payload + scales), not the logical fp size — so TransferStats, the obs
    spans, and the cost model all see the ~``dtype_bytes``x wire shrink.
    The staging buffer keeps fp stripes in both modes (quantization is
    per-block, so it must wait for the block scatter).
    """

    def __init__(self, geom: KVGeometry, num_blocks: int,
                 quant: str = "none"):
        if quant not in ("none", "int8"):
            raise ValueError(f"HostPool: unknown quant mode {quant!r}")
        g = geom
        self.geom = g
        self.num_blocks = num_blocks
        self.quant = quant
        shape = (g.num_layers, g.num_kv_heads, num_blocks, g.block_size,
                 g.head_dim)
        dt = np.int8 if quant == "int8" else np.float32
        self.k = np.zeros(shape, dt)
        self.v = np.zeros(shape, dt) if g.kv_factor == 2 else None
        if quant == "int8":
            sshape = (g.num_layers, g.num_kv_heads, num_blocks)
            self.k_scale = np.zeros(sshape, np.float32)
            self.v_scale = np.zeros(sshape, np.float32) \
                if self.v is not None else None
        else:
            self.k_scale = self.v_scale = None
        self._staging: List[Tuple[int, int, np.ndarray, Optional[np.ndarray]]] = []
        self.stats = TransferStats()

    def wire_bytes(self, n_blocks: int) -> int:
        """Bytes ``n_blocks`` whole blocks occupy AS STORED in this pool —
        the wire size of moving them (one layer, all kv heads, K+V).

        fp tier: elems x itemsize of the numpy arrays.  int8 tier: 1 B per
        element plus ``QUANT_SCALE_BYTES`` per (kv-head, block) per tensor.
        Every h2d/d2h byte counter for this pool is derived from this."""
        g = self.geom
        elems_per_head = g.block_size * g.head_dim
        if self.quant == "int8":
            per_head = elems_per_head + QUANT_SCALE_BYTES
        else:
            per_head = elems_per_head * self.k.itemsize
        kvf = 2 if self.v is not None else 1
        return n_blocks * g.num_kv_heads * per_head * kvf

    def stage(self, layer: int, start_token: int, k_new: np.ndarray,
              v_new: Optional[np.ndarray]) -> int:
        """Append one contiguous KV stripe to the staging buffer WITHOUT
        booking d2h stats (callers that represent one fused launch across
        many pools — ``KVCacheManager.save_new_tokens_fused`` — account the
        launch themselves; ``save_contiguous`` accounts per-call).

        k_new/v_new: (Hkv, T, D) for T new tokens starting at absolute
        token position ``start_token``.  Bounds contract: the stripe
        [start_token, start_token+T) must fit the pool registered at
        ``KVCacheManager.register`` time — out-of-range stripes raise
        ``ValueError`` immediately rather than corrupting block state.

        Returns the stripe's WIRE byte size for the caller to book: the
        fp stripe bytes (K+V) in the default tier, or — under
        ``quant="int8"`` — the int8 payload plus one scale per touched
        (kv-head, block) per tensor, i.e. the size the D2H DMA actually
        moves when ``quantize_blocks`` is fused into the save path."""
        T = k_new.shape[1]
        end_token = start_token + T
        max_tokens = self.num_blocks * self.geom.block_size
        if start_token < 0 or end_token > max_tokens:
            raise ValueError(
                f"HostPool.stage: tokens [{start_token}, {end_token})"
                f" exceed the registered pool capacity of {max_tokens} tokens"
                f" ({self.num_blocks} blocks x {self.geom.block_size}); "
                f"register the request with a larger max_tokens")
        self._staging.append((layer, start_token, np.asarray(k_new),
                              None if v_new is None else np.asarray(v_new)))
        kvf = 2 if v_new is not None else 1
        if self.quant == "int8" and T > 0:
            bs = self.geom.block_size
            touched = (end_token - 1) // bs - start_token // bs + 1
            elems = T * self.geom.num_kv_heads * k_new.shape[2]
            scale_b = touched * self.geom.num_kv_heads * QUANT_SCALE_BYTES
            return (elems + scale_b) * kvf
        return k_new.nbytes * kvf

    def save_contiguous(self, layer: int, start_token: int, k_new: np.ndarray,
                        v_new: Optional[np.ndarray]) -> None:
        """Phase 1 of FlashD2H: one contiguous D2H transfer into staging.

        k_new/v_new: (Hkv, T, D) for T new tokens starting at start_token.
        Books exactly one ``d2h_calls`` (the contiguous DMA) and its bytes;
        the CPU-side block scatter is deferred to ``flush`` (which books
        ``d2h_blocks`` only — a staged byte is never double-counted)."""
        nbytes = self.stage(layer, start_token, k_new, v_new)
        self.stats.d2h_calls += 1
        self.stats.d2h_bytes += nbytes

    def _store_quant_span(self, layer: int, blk: int, off: int,
                          stripe_k: np.ndarray,
                          stripe_v: Optional[np.ndarray]) -> None:
        """int8-tier block update: dequantize the resident block with its
        current per-head scales, overwrite tokens [off, off+n), then
        requantize the whole block with fresh scales.  Partial-block
        appends therefore requantize previously stored tokens — the drift
        is bounded (each token requantizes at most bs-1 times with scales
        that only grow as the block fills) and covered by the fidelity
        tests in ``tests/test_quant_kv.py``."""
        n = stripe_k.shape[1]
        cur_k = _dequantize_block_np(self.k[layer, :, blk],
                                     self.k_scale[layer, :, blk])
        cur_k[:, off:off + n] = stripe_k
        self.k[layer, :, blk], self.k_scale[layer, :, blk] = \
            _quantize_block_np(cur_k)
        if stripe_v is not None:
            cur_v = _dequantize_block_np(self.v[layer, :, blk],
                                         self.v_scale[layer, :, blk])
            cur_v[:, off:off + n] = stripe_v
            self.v[layer, :, blk], self.v_scale[layer, :, blk] = \
                _quantize_block_np(cur_v)

    def flush(self) -> int:
        """Phase 2 of FlashD2H: CPU-side scatter of staged stripes into the
        per-head block layout.  Returns blocks written (block-boundary
        segments; a stripe spanning two blocks writes two).

        Accounting: books ``d2h_blocks`` ONLY — the stripe's bytes and the
        fused launch were already booked when the stripe was staged
        (``save_contiguous`` / ``save_new_tokens_fused``), so flushing
        never double-counts.  In the int8 tier each touched block is
        (re)quantized here with fresh per-head scales — the numpy twin of
        fusing ``kernels.quantize_blocks`` into the D2H scatter."""
        g = self.geom
        written = 0
        for layer, start, k_new, v_new in self._staging:
            T = k_new.shape[1]
            t0 = 0
            while t0 < T:
                blk = (start + t0) // g.block_size
                off = (start + t0) % g.block_size
                if blk >= self.num_blocks:
                    raise ValueError(
                        f"HostPool.flush: staged token {start + t0} maps to "
                        f"block {blk} but the pool only has "
                        f"{self.num_blocks} blocks")
                # split on block boundaries (start may be mid-block)
                t1 = min(t0 + (g.block_size - off), T)
                if self.quant == "int8":
                    self._store_quant_span(
                        layer, blk, off, k_new[:, t0:t1],
                        None if v_new is None else v_new[:, t0:t1])
                else:
                    self.k[layer, :, blk, off:off + (t1 - t0)] = \
                        k_new[:, t0:t1]
                    if v_new is not None:
                        self.v[layer, :, blk, off:off + (t1 - t0)] = \
                            v_new[:, t0:t1]
                written += 1
                self.stats.d2h_blocks += 1
                t0 = t1
        self._staging.clear()
        return written

    def gather(self, layer: int, blocks: List[int]
               ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Data-plane gather of fragmented blocks — NO accounting.

        Returns (k (Hkv, K, bs, D), v or None), always in the COMPUTE
        dtype: the int8 tier dequantizes here with the stored per-head
        scales (the numpy twin of ``kernels.dequantize_scatter_blocks``),
        so downstream restore-into-device-slots is tier-agnostic.  Callers
        that represent one fused kernel launch record the h2d_* stats
        themselves (either ``load_blocks`` below or
        ``KVCacheManager.load_blocks_fused``) — at ``wire_bytes`` size,
        because the H2D DMA moves the stored payload, not this fp copy."""
        if blocks and (max(blocks) >= self.num_blocks or min(blocks) < 0):
            bad = max(blocks) if max(blocks) >= self.num_blocks \
                else min(blocks)
            raise ValueError(
                f"HostPool.gather: block {bad} out of range "
                f"(pool has {self.num_blocks} blocks)")
        idx = np.asarray(blocks, np.int32)
        k = self.k[layer][:, idx]
        v = None if self.v is None else self.v[layer][:, idx]
        if self.quant == "int8":
            ks = self.k_scale[layer][:, idx]            # (Hkv, K)
            k = k.astype(np.float32) * ks[..., None, None]
            if v is not None:
                vs = self.v_scale[layer][:, idx]
                v = v.astype(np.float32) * vs[..., None, None]
        return k, v

    def load_blocks(self, layer: int, blocks: List[int]
                    ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """FlashH2D data plane: ONE fused gather of fragmented blocks.

        Returns (k (Hkv, K, bs, D), v or None) in the compute dtype.
        Books one ``h2d_calls`` launch, ``h2d_blocks`` in (block x
        kv-head) units, and ``h2d_bytes`` at the pool's STORED size
        (``wire_bytes``) — int8 payload + scales under the quantized
        tier, fp bytes otherwise."""
        k, v = self.gather(layer, blocks)
        self.stats.h2d_calls += 1
        self.stats.h2d_blocks += len(blocks) * self.geom.num_kv_heads
        self.stats.h2d_bytes += self.wire_bytes(len(blocks))
        return k, v


class KVCacheManager:
    """System-wide manager: per-request HBM caches + host pools + global
    HBM budget (M_avl feeds the scheduler's Algorithm 1)."""

    def __init__(self, geom: KVGeometry, hbm_budget_bytes: int,
                 host_budget_bytes: Optional[int] = None,
                 offload_quant: str = "none"):
        if offload_quant not in ("none", "int8"):
            raise ValueError(
                f"KVCacheManager: unknown offload_quant {offload_quant!r}")
        self.geom = geom
        self.hbm_budget_bytes = hbm_budget_bytes
        self.host_budget_bytes = host_budget_bytes
        self.offload_quant = offload_quant
        self.caches: Dict[str, HBMCache] = {}
        self.pools: Dict[str, HostPool] = {}
        self._retired_stats = TransferStats()   # stats of released requests
        self.fused_stats = TransferStats()      # batched FlashH2D launches
        self.tracer = NULL_TRACER               # engine installs a live
                                                # Tracer when obs is on

    # -- lifecycle ---------------------------------------------------------
    def register(self, req_id: str, max_tokens: int,
                 hbm_blocks_per_request: int) -> None:
        nb = -(-max_tokens // self.geom.block_size)
        self.caches[req_id] = HBMCache(self.geom, hbm_blocks_per_request)
        self.pools[req_id] = HostPool(self.geom, nb,
                                      quant=self.offload_quant)

    def release(self, req_id: str) -> None:
        c = self.caches.pop(req_id, None)
        p = self.pools.pop(req_id, None)
        if c is not None:
            self._retired_stats.merge(c.stats)
        if p is not None:
            self._retired_stats.merge(p.stats)

    # -- control plane -----------------------------------------------------
    def access_layer(self, layer: int, blocks_by_req: Dict[str, List[int]],
                     drain_evicted: bool = False
                     ) -> Tuple[Dict[str, List[int]],
                                Dict[str, List[Tuple[int, int]]]]:
        """Touch one layer's selected blocks for every request of a decode
        iteration (LRU residency only — no transfer accounting; see
        ``HBMCache.access``).

        The per-layer unit matches the decode planes: the fused plane calls
        this once per layer after its single forward, the staged plane calls
        it between a layer's select and attend stages so the returned
        ``missing`` can be loaded (``load_blocks_fused``) and restored into
        device slots BEFORE that layer's attention.

        `layer` is the attention-layer ordinal.  Returns
        (missing_by_req, evicted_by_req): the block ids each request must
        load, and — when ``drain_evicted`` — the (layer, block) keys each
        request's LRU evicted during these accesses (``pop_evicted``; empty
        lists otherwise).  Requests without a registered cache are skipped.
        """
        missing_by_req: Dict[str, List[int]] = {}
        evicted_by_req: Dict[str, List[Tuple[int, int]]] = {}
        for req_id, blocks in blocks_by_req.items():
            cache = self.caches.get(req_id)
            if cache is None:
                continue
            missing = cache.access(layer, blocks)
            if missing:
                missing_by_req[req_id] = missing
            if drain_evicted:
                evicted_by_req[req_id] = cache.pop_evicted()
        return missing_by_req, evicted_by_req

    # -- data plane --------------------------------------------------------
    def load_blocks_fused(self, layer: int,
                          blocks_by_req: Dict[str, List[int]]
                          ) -> Dict[str, Tuple[np.ndarray,
                                               Optional[np.ndarray]]]:
        """ONE fused FlashH2D launch covering every missing block of `layer`
        across the whole decode batch (batched engine hot path).

        The paper's FlashH2D kernel gathers fragmented blocks from pinned
        DRAM in a single launch; under batched decode the launch amortizes
        over ALL requests in the iteration, so h2d_calls grows
        per-layer-per-iteration, not per-request.  Accounting lives HERE and
        only here for these transfers (``HBMCache.access`` books residency
        only), so each moved block is counted exactly once: h2d_calls in
        fused launches, h2d_blocks in (block x kv-head) units, h2d_bytes in
        K+V payload bytes AT STORED SIZE (``HostPool.wire_bytes`` — int8 +
        scales under ``offload_quant="int8"``, fp bytes otherwise).

        `layer` is the attention-layer ORDINAL (0..geom.num_layers-1), not
        the model layer id; `blocks_by_req` values are block ids, each
        bounds-checked by ``HostPool.gather`` against the pool registered
        at ``register`` time.  Returns {req_id: (k (Hkv,K,bs,D), v|None)} —
        under the persistent decode plane the engine scatters these
        payloads DIRECTLY into the requests' device slots
        (``DevicePoolPlane.restore_blocks``)."""
        tr = self.tracer
        if tr.enabled:
            _ts = time.perf_counter()
        out: Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]] = {}
        total_blocks = 0
        total_bytes = 0
        for req_id, blocks in blocks_by_req.items():
            pool = self.pools.get(req_id)
            if pool is None or not blocks:
                continue
            k, v = pool.gather(layer, blocks)
            out[req_id] = (k, v)
            total_blocks += len(blocks) * self.geom.num_kv_heads
            total_bytes += pool.wire_bytes(len(blocks))
        if total_blocks:
            self.fused_stats.h2d_calls += 1
            self.fused_stats.h2d_blocks += total_blocks
            self.fused_stats.h2d_bytes += total_bytes
            if tr.enabled:
                tr.end("FlashH2D", "transfer", _ts, layer=layer,
                       blocks=total_blocks, bytes=total_bytes,
                       fused_reqs=len(out))
        return out

    def save_new_tokens_fused(self, layer: int,
                              kv_by_req: Dict[str, Tuple[int, np.ndarray,
                                                         Optional[np.ndarray]]]
                              ) -> None:
        """ONE fused FlashD2H save of this iteration's newly produced KV
        for `layer` across a whole batch — the decode planes' per-layer
        write-back AND the prefill plane's per-(layer, chunk)-group save
        (each batched prefill launch saves every request's stripe through
        one call here, replacing the legacy per-request
        ``save_contiguous`` loop).

        kv_by_req: {req_id: (start_token, k (Hkv,T,D), v or None)}.  Under
        batching the stripe is contiguous across the batch, so the paper
        saves it with one D2H DMA per layer per iteration; accordingly
        ``d2h_calls`` is booked ONCE here (on ``fused_stats``) while each
        pool stages its stripe without accounting (``HostPool.stage``).
        The CPU-side scatter into blocks still happens at each pool's
        ``flush``.  With the default fp tier the host pool stays a
        byte-exact superset of device KV; under ``offload_quant="int8"``
        it is a BOUNDED-ERROR superset (per-block per-head scales), and
        either way ``load_blocks_fused`` payloads come back in the compute
        dtype — dequantized at gather — so they stay safe to scatter
        straight into device slots.  Staged bytes are booked at wire size
        (see ``HostPool.stage``)."""
        tr = self.tracer
        if tr.enabled:
            _ts = time.perf_counter()
        total_bytes = 0
        for req_id, (start, k, v) in kv_by_req.items():
            pool = self.pools.get(req_id)
            if pool is None:
                continue
            total_bytes += pool.stage(layer, start, k, v)
        if total_bytes:
            self.fused_stats.d2h_calls += 1
            self.fused_stats.d2h_bytes += total_bytes
            # in async mode this fires on the HostStageWorker thread —
            # the tracer is thread-safe and books the span to that tid
            if tr.enabled:
                tr.end("FlashD2H", "transfer", _ts, layer=layer,
                       bytes=total_bytes, fused_reqs=len(kv_by_req))

    # -- accounting --------------------------------------------------------
    def hbm_used_bytes(self) -> int:
        per_lb = (self.geom.block_bytes_per_head * self.geom.num_kv_heads)
        return sum(c.num_resident * per_lb for c in self.caches.values())

    def total_stats(self) -> TransferStats:
        s = TransferStats()
        s.merge(self._retired_stats)
        s.merge(self.fused_stats)
        for c in self.caches.values():
            s.merge(c.stats)
        for p in self.pools.values():
            s.merge(p.stats)
        return s
