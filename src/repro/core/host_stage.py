"""Host-stage worker thread for the async dispatch pipeline.

In ``stage_dispatch="async"`` mode the per-layer host stage no longer
blocks the dispatch thread on the FlashD2H write-back: the engine
dispatches the device->host stripe gather (a queued XLA op), hands the
*device arrays* to a :class:`HostStageWorker` job, and immediately goes
on to dispatch ``attend(l)`` / ``select(l+1)``.  The worker converts the
stripes (``np.asarray`` — the actual blocking transfer), stages them
into the DRAM pools (``save_new_tokens_fused`` + ``flush``), and records
completion per *key* (we key jobs by attention-layer index).

Correctness hinges on two fences the engine issues:

- ``fence(lidx)`` before any ``load_blocks_fused(lidx, ...)`` gather
  while a write-back job for that layer is outstanding (the
  *writeback-before-gather* / restore-before-use invariant), and
- ``drain()`` at the end of every iteration, before sampling and before
  any request release drops a DRAM pool the worker may still write
  (the *writeback-before-drop* invariant).

Exceptions raised by a job are captured and re-raised on the dispatch
thread at the next ``fence``/``drain``/``submit`` touching the worker,
so a failed write-back fails the iteration instead of vanishing on a
daemon thread.

JAX's value semantics make the off-thread conversion safe without
copying: the dispatched gather closes over the pool *value* at dispatch
time, so later pool-mutating stages (which produce new buffers — the
donated input buffers are only reused once no live reference remains)
never alter what the worker reads back.

The quantized offload tier (``EngineConfig.offload_quant="int8"``) rides
these same jobs unchanged: quantization happens inside the pool's
``flush`` (per-block, on this worker thread), so the fence semantics
above are exactly what guarantees a gather never observes a
half-quantized block — ``fence(lidx)`` orders the whole
stage-quantize-store sequence before any same-layer gather, and
``drain()`` orders it before pool teardown.  Only the booked byte counts
differ (wire size; see ``HostPool.stage``).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Optional

from repro.obs.tracing import NULL_TRACER


class HostStageError(RuntimeError):
    """A host-stage job failed; carries the original exception as cause."""


class _Job:
    __slots__ = ("key", "fn", "args", "done")

    def __init__(self, key: Any, fn: Callable[..., None], args: tuple):
        self.key = key
        self.fn = fn
        self.args = args
        self.done = threading.Event()


class HostStageWorker:
    """Single daemon thread executing host-stage jobs in FIFO order.

    FIFO execution means jobs for the same key complete in submission
    order, so ``fence(key)`` only needs to wait for the *last* job
    submitted under that key.
    """

    def __init__(self, name: str = "host-stage", tracer=None):
        self._q: "queue.Queue[Optional[_Job]]" = queue.Queue()
        self._last: Dict[Any, _Job] = {}       # key -> most recent job
        self._lock = threading.Lock()
        self._exc: Optional[BaseException] = None
        self._closed = False
        self.jobs_run = 0
        self.busy_s = 0.0                      # total time inside job fns
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._thread.start()

    # -- worker side --------------------------------------------------------
    def _loop(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            try:
                if self._exc is None:          # fail fast after first error
                    t0 = time.perf_counter()
                    job.fn(*job.args)
                    dt = time.perf_counter() - t0
                    self.busy_s += dt
                    self.jobs_run += 1
                    tr = self.tracer
                    if tr.enabled:
                        # same t0/dt as busy_s, so the trace and counter
                        # overlap instruments cannot drift on one run
                        tr.complete_at("host-stage", "host-stage-worker",
                                       t0, dt, key=job.key)
            except BaseException as e:         # noqa: BLE001 - re-raised
                self._exc = e                  # on the dispatch thread
            finally:
                job.done.set()

    # -- dispatch-thread side ----------------------------------------------
    def _raise_pending(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise HostStageError(
                f"host-stage job failed: {exc!r}") from exc

    def submit(self, key: Any, fn: Callable[..., None], *args: Any) -> None:
        """Enqueue ``fn(*args)`` under ``key``; raises pending job errors."""
        self._raise_pending()
        if self._closed:
            raise HostStageError("submit() after close()")
        job = _Job(key, fn, args)
        with self._lock:
            self._last[key] = job
        self._q.put(job)

    def pending(self, key: Any) -> bool:
        """True while a job submitted under ``key`` has not completed."""
        with self._lock:
            job = self._last.get(key)
        return job is not None and not job.done.is_set()

    def fence(self, key: Any) -> None:
        """Block until every job submitted under ``key`` has completed."""
        with self._lock:
            job = self._last.get(key)
        if job is not None:
            job.done.wait()
        self._raise_pending()

    def drain(self) -> None:
        """Block until every submitted job has completed."""
        with self._lock:
            jobs = list(self._last.values())
        for job in jobs:
            job.done.wait()
        # anything still queued was submitted concurrently by this thread —
        # there is a single producer, so _last covers the full queue.
        self._raise_pending()

    def close(self) -> None:
        """Drain outstanding work and stop the thread (idempotent).

        Errors from outstanding jobs surface here rather than being
        swallowed by shutdown.
        """
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._thread.join()
        self._raise_pending()

    @property
    def closed(self) -> bool:
        return self._closed
