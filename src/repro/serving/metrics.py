"""Serving metrics: TTFT / TBT / token throughput / goodput (paper §4)."""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class ServingMetrics:
    mean_ttft: float
    p99_ttft: float
    mean_tbt: float
    p99_tbt: float
    token_throughput: float        # generated tokens / sec
    request_throughput: float
    mean_queue_delay: float
    total_time: float
    num_finished: int

    def row(self) -> str:
        return (f"ttft={self.mean_ttft:.3f}s tbt={self.mean_tbt*1e3:.1f}ms "
                f"tok/s={self.token_throughput:.1f} "
                f"req/s={self.request_throughput:.3f} "
                f"queue={self.mean_queue_delay:.3f}s")


def compute_metrics(reqs: List[Request], total_time: float) -> ServingMetrics:
    fin = [r for r in reqs if r.finish_time is not None]
    ttfts = [r.ttft() for r in fin if r.ttft() is not None]
    tbts = [t for r in fin for t in r.tbts()]
    qd = [r.scheduled_time - r.arrival_time for r in fin
          if r.scheduled_time is not None]
    tokens = sum(r.generated for r in fin)
    return ServingMetrics(
        mean_ttft=float(np.mean(ttfts)) if ttfts else float("nan"),
        p99_ttft=float(np.percentile(ttfts, 99)) if ttfts else float("nan"),
        mean_tbt=float(np.mean(tbts)) if tbts else float("nan"),
        p99_tbt=float(np.percentile(tbts, 99)) if tbts else float("nan"),
        token_throughput=tokens / total_time if total_time > 0 else 0.0,
        request_throughput=len(fin) / total_time if total_time > 0 else 0.0,
        mean_queue_delay=float(np.mean(qd)) if qd else float("nan"),
        total_time=total_time,
        num_finished=len(fin),
    )


def meets_slo(reqs: List[Request], total_time: float, *,
              p99_tbt_limit: float, mean_queue_limit: float = 2.0,
              ) -> bool:
    """Goodput SLO gate (paper Fig. 13): P99 TBT <= 25x a decode iteration
    and mean scheduling delay <= 2 s."""
    m = compute_metrics(reqs, total_time)
    if m.num_finished == 0:
        return False
    if not np.isnan(m.p99_tbt) and m.p99_tbt > p99_tbt_limit:
        return False
    if not np.isnan(m.mean_queue_delay) and m.mean_queue_delay > mean_queue_limit:
        return False
    return True
