"""Serving metrics: TTFT / TBT / token throughput / goodput (paper §4)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serving.request import Request

# A tail percentile needs a tail: np.percentile([x], 99) happily reports
# a one-sample "p99" (it is just x), which is noise presented as a tail
# bound.  Below this many samples the p99 fields are None — explicitly
# unmeasured, so callers must decide a policy (see ``meets_slo``) instead
# of silently consuming a fabricated number.
P99_MIN_SAMPLES = 10


def _p99(xs: List[float]) -> Optional[float]:
    if len(xs) < P99_MIN_SAMPLES:
        return None
    return float(np.percentile(xs, 99))


@dataclasses.dataclass
class ServingMetrics:
    mean_ttft: float
    p99_ttft: Optional[float]      # None below P99_MIN_SAMPLES samples
    mean_tbt: float
    p99_tbt: Optional[float]       # None below P99_MIN_SAMPLES samples
    token_throughput: float        # generated tokens / sec
    request_throughput: float
    mean_queue_delay: float
    total_time: float
    num_finished: int

    def row(self) -> str:
        return (f"ttft={self.mean_ttft:.3f}s tbt={self.mean_tbt*1e3:.1f}ms "
                f"tok/s={self.token_throughput:.1f} "
                f"req/s={self.request_throughput:.3f} "
                f"queue={self.mean_queue_delay:.3f}s")


def compute_metrics(reqs: List[Request], total_time: float) -> ServingMetrics:
    fin = [r for r in reqs if r.finish_time is not None]
    ttfts = [r.ttft() for r in fin if r.ttft() is not None]
    tbts = [t for r in fin for t in r.tbts()]
    qd = [r.scheduled_time - r.arrival_time for r in fin
          if r.scheduled_time is not None]
    tokens = sum(r.generated for r in fin)
    return ServingMetrics(
        mean_ttft=float(np.mean(ttfts)) if ttfts else float("nan"),
        p99_ttft=_p99(ttfts),
        mean_tbt=float(np.mean(tbts)) if tbts else float("nan"),
        p99_tbt=_p99(tbts),
        token_throughput=tokens / total_time if total_time > 0 else 0.0,
        request_throughput=len(fin) / total_time if total_time > 0 else 0.0,
        mean_queue_delay=float(np.mean(qd)) if qd else float("nan"),
        total_time=total_time,
        num_finished=len(fin),
    )


def meets_slo(reqs: List[Request], total_time: float, *,
              p99_tbt_limit: float, mean_queue_limit: float = 2.0,
              strict_p99: bool = False) -> bool:
    """Goodput SLO gate (paper Fig. 13): P99 TBT <= 25x a decode iteration
    and mean scheduling delay <= 2 s.

    Unmeasurable-tail policy, explicitly: when p99_tbt is None (fewer
    than ``P99_MIN_SAMPLES`` TBT samples — see ``compute_metrics``) or
    NaN, the default is to PASS the p99 gate — the gate fails only on
    *measured* violations, matching the old NaN behavior but now by
    stated choice rather than by ``not np.isnan(...)`` accident.  Pass
    ``strict_p99=True`` to invert that: a batch too small to measure its
    tail fails the gate.  ``mean_queue_delay`` keeps the same
    measured-violations-only treatment (NaN passes).
    """
    m = compute_metrics(reqs, total_time)
    if m.num_finished == 0:
        return False
    p99 = m.p99_tbt
    if p99 is None or np.isnan(p99):
        if strict_p99:
            return False
    elif p99 > p99_tbt_limit:
        return False
    if not np.isnan(m.mean_queue_delay) \
            and m.mean_queue_delay > mean_queue_limit:
        return False
    return True
