"""Discrete-event serving simulator (paper §4 experiments on the cost model).

Replays a request trace through the full SparseServe control plane —
FCFS hybrid batching, Algorithm-1 working-set admission, LRU HBM caching,
layer-segmented prefill — advancing simulated time by the analytic cost
model (`costmodel.py`).  The systems ladder matches the paper:

    vllm        full attention, chunked prefill, KV resident in HBM
    vllm-s      + dynamic sparse attention (SA)          [still resident]
    vllm-so     + KV offloading to DRAM, memcpy transfers
    +ft         + fragmentation-aware transfer (FlashH2D/D2H)
    +wc         + working-set-aware batch size control
    +lp         + layer-segmented prefill  == sparseserve

Block-selection traces are synthesized with the temporal locality the paper
measures (Fig. 8): each step keeps a block from the previous selection with
probability ``p_keep`` and always includes sink+recent blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.kv_cache import HBMCache, KVGeometry
from repro.core.scheduler import BatchPlan, Scheduler, SchedulerConfig
from repro.serving import costmodel as cm
from repro.serving.metrics import ServingMetrics, compute_metrics
from repro.serving.request import Phase, Request


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str
    sparse_attention: bool = False
    offload: bool = False
    fragmentation_aware: bool = False
    ws_control: bool = False
    layer_segmented: bool = False


SYSTEMS: Dict[str, SystemConfig] = {
    "vllm": SystemConfig("vllm"),
    "vllm-s": SystemConfig("vllm-s", sparse_attention=True),
    "vllm-so": SystemConfig("vllm-so", sparse_attention=True, offload=True),
    "vllm-so+ft": SystemConfig("vllm-so+ft", sparse_attention=True,
                               offload=True, fragmentation_aware=True),
    "vllm-so+ft+wc": SystemConfig("vllm-so+ft+wc", sparse_attention=True,
                                  offload=True, fragmentation_aware=True,
                                  ws_control=True),
    "sparseserve": SystemConfig("sparseserve", sparse_attention=True,
                                offload=True, fragmentation_aware=True,
                                ws_control=True, layer_segmented=True),
}


@dataclasses.dataclass
class SimConfig:
    block_size: int = 32
    token_budget: int = 2048
    window: int = 12
    p_keep: float = 0.95            # selection temporal locality: the paper
                                    # (Fig. 8) and our real-engine replica
                                    # (benchmarks/bench_overlap.py) both
                                    # measure ~95% overlap with the window-12
                                    # union, which is what the LRU cache sees
    chunk_size: int = 2048
    r_max: int = 64
    t_max: int = 4096
    hbm_reserve_frac: float = 0.10  # activations/workspace
    seed: int = 0
    max_sim_time: float = 36000.0


@dataclasses.dataclass
class _ReqSim:
    """Simulator-side per-request state."""
    req: Request
    prev_sel: Set[int] = dataclasses.field(default_factory=set)
    cache: Optional[HBMCache] = None


class ServingSimulator:
    def __init__(self, model_cfg, system: SystemConfig,
                 hw: cm.HardwareSpec = cm.A100_40G,
                 sim: SimConfig = SimConfig()):
        self.cfg = model_cfg
        self.sys = system
        self.hw = hw
        self.sim = sim
        self.mc = cm.ModelCost.from_config(model_cfg)
        self.rng = np.random.default_rng(sim.seed)

        L = model_cfg.num_attention_layers()
        self.geom = KVGeometry(
            num_layers=max(L, 1),
            num_kv_heads=max(model_cfg.num_kv_heads, 1),
            block_size=sim.block_size,
            head_dim=model_cfg.kv_cache_dim,
            kv_factor=1 if model_cfg.attention_type == "mla" else 2)
        self.top_k = max(1, sim.token_budget // sim.block_size)

        hbm_free = hw.hbm_capacity * (1 - sim.hbm_reserve_frac) \
            - self.mc.param_bytes
        if hbm_free <= 0:
            raise ValueError("model does not fit in HBM")
        self.hbm_kv_budget = hbm_free

        prefill_mode = ("layer_segmented" if system.layer_segmented
                        else "chunked")
        self.scheduler = Scheduler(
            SchedulerConfig(
                r_max=sim.r_max, t_max=sim.t_max,
                m_avl_bytes=int(hbm_free) if system.ws_control else 0,
                prefill_mode=prefill_mode, chunk_size=sim.chunk_size,
                max_inject_tokens=sim.chunk_size * model_cfg.num_layers,
                ws_control=system.ws_control),
            self.geom, model_cfg.num_layers, self.top_k)

        # per-request LRU cache capacity: share of the HBM KV budget
        self._cache_blocks = max(
            self.top_k + 4,
            int(hbm_free / max(1, self.geom.block_bytes) / max(1, sim.r_max)))
        self.states: Dict[str, _ReqSim] = {}
        self.loads_per_iter: List[int] = []
        self.batch_sizes: List[int] = []
        self.decode_iter_time: float = 0.0   # last pure-decode iter (SLO ref)

    # ------------------------------------------------------------------
    def _resident_kv_bytes(self) -> float:
        """KV bytes pinned in HBM for non-offload systems."""
        tot = 0.0
        for st in self.states.values():
            r = st.req
            if r.phase == Phase.DECODE:
                tot += r.total_len * self.mc.kv_bytes_per_token
            elif r.phase == Phase.PREFILL:
                tot += r.prefill_tokens_done * self.mc.kv_bytes_per_token
        return tot

    def _admit_resident(self, plan: BatchPlan) -> BatchPlan:
        """vLLM-style HBM admission: a prefill may proceed only if its FULL
        prompt KV (+ current residency) fits — head-of-line blocking.
        Decode requests whose aggregate resident KV exceeds HBM are
        preempted (stalled) for the iteration, FCFS."""
        # decode residency cap (vLLM preemption when HBM overflows)
        ok_decode = []
        resident = 0.0
        for r in plan.decode_reqs:
            need = r.total_len * self.mc.kv_bytes_per_token
            if resident + need <= self.hbm_kv_budget:
                ok_decode.append(r)
                resident += need
        plan = BatchPlan(ok_decode, plan.prefill_reqs, rejected=plan.rejected)
        free = self.hbm_kv_budget - self._resident_kv_bytes()
        ok_prefills = []
        for req, inject in plan.prefill_reqs:
            need = ((req.prompt_len - req.prefill_tokens_done)
                    * self.mc.kv_bytes_per_token)
            if need <= free:
                ok_prefills.append((req, inject))
                free -= need
            else:
                # demote: back to waiting (blocked on HBM)
                if req.phase == Phase.PREFILL and req.prefill_tokens_done == 0:
                    req.phase = Phase.WAITING
                    if req in self.scheduler.running:
                        self.scheduler.running.remove(req)
                    if req not in self.scheduler.waiting:
                        self.scheduler.waiting.insert(0, req)
        return BatchPlan(plan.decode_reqs, ok_prefills,
                         rejected=plan.rejected)

    # ------------------------------------------------------------------
    def _synth_selection(self, st: _ReqSim) -> Set[int]:
        n_blocks = max(1, st.req.total_len // self.sim.block_size)
        k = min(self.top_k, n_blocks)
        forced = {0, max(0, n_blocks - 1), max(0, n_blocks - 2)}
        keep = {b for b in st.prev_sel
                if b < n_blocks and self.rng.random() < self.sim.p_keep}
        sel = set(sorted(forced | keep)[:k])
        while len(sel) < k:
            sel.add(int(self.rng.integers(n_blocks)))
        st.prev_sel = sel
        return sel

    # ------------------------------------------------------------------
    def _decode_cost(self, reqs: List[Request]) -> Tuple[float, int]:
        """Returns (iteration seconds, blocks loaded)."""
        if not reqs:
            return 0.0, 0
        L = self.geom.num_layers
        if self.sys.sparse_attention:
            attended = min(self.sim.token_budget,
                           int(np.mean([r.total_len for r in reqs])))
        else:
            attended = int(np.mean([r.total_len for r in reqs]))
        t = cm.decode_time(self.hw, self.mc, len(reqs), attended)
        self.decode_iter_time = t

        loads = 0
        t_load = 0.0
        if self.sys.offload:
            blk_bytes_all_layers = (self.geom.block_bytes_per_head
                                    * self.geom.num_kv_heads * L)
            per_head_bytes = self.geom.block_bytes_per_head
            # the HBM cache is SHARED: more running requests -> smaller
            # per-request share -> contention/thrashing (paper Fig. 1)
            share = max(4, int(self.hbm_kv_budget / blk_bytes_all_layers
                               / max(1, len(reqs))))
            for r in reqs:
                self.states[r.req_id].cache.capacity = share
            for r in reqs:
                st = self.states[r.req_id]
                sel = self._synth_selection(st)
                missing = st.cache.access(0, sorted(sel))
                # temporal locality is shared across layers (consecutive
                # queries select similar blocks in EVERY layer) — the working
                # set spans all L layers of the selected block ids.
                self.scheduler.observe_selection(
                    r, [(l, b) for l in range(L) for b in sel])
                if missing:
                    loads += len(missing) * L
                    mb = len(missing) * blk_bytes_all_layers
                    if self.sys.fragmentation_aware:
                        # one fused FlashH2D launch per layer
                        t_load += L * cm.fused_transfer_time(
                            self.hw, mb / L)
                    else:
                        # one memcpy per (block, head, layer)
                        n_copies = len(missing) * self.geom.num_kv_heads * L
                        t_load += cm.memcpy_transfer_time(
                            self.hw, n_copies, per_head_bytes)
        return t + t_load, loads

    def _prefill_cost(self, plan: BatchPlan) -> float:
        t = 0.0
        for req, inject in plan.prefill_reqs:
            if self.sys.layer_segmented:
                # one layer over `inject` prompt tokens (+ chunk split);
                # causal attention averages to prompt/2 context
                t_cmp = cm.prefill_time(self.hw, self.mc, inject,
                                        max(req.prompt_len // 2, 1), layers=1)
                if self.sys.offload:
                    save_bytes = inject * self.mc.kv_bytes_per_token \
                        / self.geom.num_layers
                    t_save = cm.fused_transfer_time(self.hw, save_bytes) \
                        if self.sys.fragmentation_aware else \
                        cm.memcpy_transfer_time(
                            self.hw,
                            max(1, inject // self.sim.block_size)
                            * self.geom.num_kv_heads,
                            self.geom.block_bytes_per_head)
                    t_cmp += max(0.0, t_save - t_cmp)  # async, may stall
            else:
                ctx = req.prefill_tokens_done + inject
                t_cmp = cm.prefill_time(self.hw, self.mc, inject, ctx)
                if self.sys.offload:
                    save_bytes = inject * self.mc.kv_bytes_per_token
                    t_save = cm.fused_transfer_time(self.hw, save_bytes) \
                        if self.sys.fragmentation_aware else \
                        cm.memcpy_transfer_time(
                            self.hw,
                            max(1, inject // self.sim.block_size)
                            * self.geom.num_kv_heads * self.geom.num_layers,
                            self.geom.block_bytes_per_head)
                    t_cmp += max(0.0, t_save - t_cmp)
            t += t_cmp
        return t

    # ------------------------------------------------------------------
    def _apply_progress(self, plan: BatchPlan, now: float) -> None:
        cfg = self.cfg
        for req, inject in plan.prefill_reqs:
            if req.scheduled_time is None:
                req.scheduled_time = now
            if self.sys.layer_segmented:
                req.prefill_layer_tokens_done += inject
                while (req.prefill_layer_tokens_done >= req.prompt_len
                       and req.prefill_layer < cfg.num_layers):
                    req.prefill_layer += 1
                    req.prefill_layer_tokens_done -= req.prompt_len
                done = req.prefill_layer >= cfg.num_layers
            else:
                req.prefill_tokens_done += inject
                done = req.prefill_tokens_done >= req.prompt_len
            if done:
                req.phase = Phase.DECODE
                req.first_token_time = now
                req.token_times.append(now)
                req.generated = 1
                req.prefill_tokens_done = req.prompt_len
        for req in plan.decode_reqs:
            req.generated += 1
            req.token_times.append(now)
            if req.generated >= req.max_new_tokens:
                req.finish_time = now
                self.scheduler.finish_request(req)
                self.states.pop(req.req_id, None)

    # ------------------------------------------------------------------
    def run(self, trace: List[Request]) -> ServingMetrics:
        pending = sorted(trace, key=lambda r: r.arrival_time)
        t = 0.0
        i_arr = 0
        n_total = len(pending)
        finished = 0
        while finished < n_total and t < self.sim.max_sim_time:
            while i_arr < n_total and pending[i_arr].arrival_time <= t:
                req = pending[i_arr]
                self.scheduler.add_request(req)
                st = _ReqSim(req)
                if self.sys.offload:
                    st.cache = HBMCache(
                        KVGeometry(self.geom.num_layers,
                                   self.geom.num_kv_heads,
                                   self.geom.block_size, self.geom.head_dim,
                                   kv_factor=self.geom.kv_factor),
                        self._cache_blocks)
                self.states[req.req_id] = st
                i_arr += 1

            plan = self.scheduler.schedule()
            if not self.sys.offload:
                plan = self._admit_resident(plan)
            if not plan.decode_reqs and not plan.prefill_reqs:
                if i_arr < n_total:
                    t = max(t, pending[i_arr].arrival_time)
                    continue
                break

            t_dec, loads = self._decode_cost(plan.decode_reqs)
            t_iter = t_dec + self._prefill_cost(plan)
            self.loads_per_iter.append(loads)
            t += max(t_iter, 1e-6)
            self.batch_sizes.append(len(plan.decode_reqs)
                                    + len(plan.prefill_reqs))
            self._apply_progress(plan, t)
            finished = sum(1 for r in pending if r.finish_time is not None)

        return compute_metrics(pending, max(t, 1e-9))
