"""Real-execution serving engine (runs actual tiny models on CPU-JAX).

This is the system the paper builds: a vLLM-style continuous-batching engine
with

  * dynamic sparse attention decode (select-then-compute, §2.2) executed as
    a STAGED per-layer pipeline over a PERSISTENT shared device pool
    (``repro.core.device_pool.DevicePoolPlane``): requests are admitted into
    padded pool rows once, each attention layer steps through jit-compiled
    bucketed select -> [host restore] -> attend stages (one compile per
    stage per shape bucket, zero per-iteration stack/unstack copies, O(L)
    launches per iteration), and rows are released when requests finish so
    later admissions reuse their slots.  Because a layer's fused FlashH2D
    restores land BETWEEN its DSA selection and its attention, HBM-evicted
    blocks can be physically dropped from the device pool without changing
    outputs (``drop_evicted_device_blocks`` defaults ON here — the paper's
    §3.2 overlap story, end to end).  ``decode_plane="persistent"`` keeps
    the fused one-launch forward over the same plane and
    ``decode_plane="stacked"`` the legacy pad+concat-every-iteration path,
    both as greedy-equivalence oracles; ``batched_decode=False`` is the
    per-request loop,
  * a hierarchical HBM–DRAM KV manager with per-request LRU HBM caches and
    host pools (§3.1 / §3.2 — FlashH2D/D2H accounting on every transfer;
    decode misses load through ONE fused FlashH2D launch per layer per
    iteration whose payloads scatter DIRECTLY into the device plane's
    slots; newly generated KV writes back to DRAM with one fused FlashD2H
    save per layer per iteration),
  * working-set-aware batch size control (Algorithm 1, §3.3),
  * layer-segmented OR chunked prefill (§3.4 vs the baseline).  Layer-
    segmented prefill runs on a batched jitted **PrefillPlane** by default
    (``repro.core.prefill_plane``): requests are admitted once into padded
    plane rows carrying their residual stream, every iteration batches all
    same-(layer, chunk) segments of the prefill batch into ONE jitted
    bucketed launch (token-length + batch buckets, ``step_mask`` parks
    unscheduled rows), each group's KV is saved to DRAM with ONE fused
    FlashD2H call, and the prefill HBM footprint stays bounded by one
    layer of KV for the WHOLE batch.  Chunked intra-layer segments
    (``prefill_max_tokens_per_step``) are executed natively.  The
    per-request whole-layer loop survives as ``prefill_exec="legacy"``,
    the equivalence oracle.  Hybrid iterations interleave plane prefill
    launches with the staged decode plane under the shared HBM budget.

See docs/architecture.md for the decode data plane and the prefill plane
end-to-end.

The CONTROL PLANE is fully real (scheduling, admission, caching, transfer
accounting, prefill segmentation); the MODEL COMPUTE is fully real (actual
forward passes, actual DSA block selections feeding the working-set
estimator).  Iteration LATENCY is charged from the analytic cost model,
because this container has no TPU — wall-clock on CPU would measure the
wrong machine.  Set ``charge_real_time=True`` to use wall clock instead
(useful for relative comparisons in tests).

The engine is what `examples/serve_longcontext.py` and the Fig. 8 / Fig. 16
benchmarks drive.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsa as dsa_mod
from repro.core.device_pool import BucketingPolicy, DevicePoolPlane
from repro.core.host_stage import HostStageWorker
from repro.core.hybrid_plane import (DecodeJob, HybridPlane, LayerWindow,
                                     PrefillJob)
from repro.core.kv_cache import KVCacheManager, KVGeometry, TransferStats
from repro.core.layer_prefill import (LayerPrefillState, hbm_footprint_tokens,
                                      plan_segments)
from repro.core.prefill_plane import PrefillPlane, admit_embed_fns_for
from repro.core.scheduler import BatchPlan, Scheduler, SchedulerConfig
from repro.launch.plane_mesh import PlaneMesh
from repro.models import model as M
from repro.models.common import ModelConfig
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import NULL_TRACER, Tracer
from repro.serving import costmodel as cm
from repro.serving.metrics import ServingMetrics, compute_metrics
from repro.serving.request import Phase, Request


@dataclasses.dataclass
class EngineConfig:
    prefill_mode: str = "layer_segmented"    # "chunked" | "layer_segmented"
    prefill_exec: str = "plane"              # layer-segmented executor:
                                             # "plane" (default): batched
                                             # jitted PrefillPlane — one
                                             # bucketed launch per (layer,
                                             # chunk) group per iteration,
                                             # one fused FlashD2H save per
                                             # group; "legacy": the
                                             # per-request whole-layer loop
                                             # (equivalence oracle).
    prefill_max_tokens_per_step: int = 0     # intra-layer chunk size for the
                                             # prefill plane's segments
                                             # (plan_segments granularity;
                                             # 0 = whole layers, the TBT-SLO
                                             # hybrid of §3.4 off).  MLA
                                             # models always run whole
                                             # layers (no latent-context
                                             # attention path).
    chunk_size: int = 2048
    max_inject_tokens: int = 0               # 0 -> chunk_size * L (paper §4.2)
    r_max: int = 8
    t_max: int = 8192
    ws_control: bool = True
    hbm_budget_bytes: int = 1 << 30          # HBM KV-cache budget (M_avl)
    hbm_blocks_per_request: int = 96         # per-request LRU capacity
    attn_impl: str = "ref"                   # "ref" | "kernel"
    charge_real_time: bool = False
    greedy: bool = True
    seed: int = 0
    batched_decode: bool = True              # ONE decode_step per iteration
                                             # (False: legacy B=1 loop)
    decode_plane: str = "staged"             # "staged" (default): per-layer
                                             # select -> restore -> attend
                                             # pipeline over a
                                             # DevicePoolPlane — H2D
                                             # restores land BEFORE the
                                             # attention that selected them;
                                             # "persistent": the fused
                                             # one-launch forward over the
                                             # same plane; "stacked": legacy
                                             # pad+concat every iteration.
                                             # All three are greedy-token
                                             # equivalent oracles of each
                                             # other (without block drops).
    bucketing: BucketingPolicy = dataclasses.field(
        default_factory=BucketingPolicy)     # device-plane shape buckets
    decode_write_back: bool = True           # FlashD2H: save newly generated
                                             # KV to the host pool each
                                             # iteration (one fused d2h call
                                             # per layer), keeping DRAM a
                                             # superset of device KV
    mesh_spec: Any = None                    # context-parallel plane mesh:
                                             # None (single-device planes),
                                             # "model=K" / int K (local mesh
                                             # with a K-way model axis), a
                                             # jax Mesh, or a PlaneMesh —
                                             # resolved once per engine via
                                             # PlaneMesh.resolve.  Shards
                                             # the staged decode plane's
                                             # pool slots (KV-head- or
                                             # block-mode) and the prefill
                                             # plane's token windows across
                                             # the model axis; requires
                                             # decode_plane="staged" and
                                             # DSA enabled.
    hybrid_plane: str = "mixed"              # "mixed" (default): ONE
                                             # layer-walk iteration carries
                                             # decode rows AND prefill
                                             # segments together
                                             # (core.hybrid_plane) — a
                                             # single per-layer host stage
                                             # fuses both planes' FlashD2H
                                             # and FlashH2D; "split": the
                                             # two-plane path (prefill
                                             # plane, then decode planes),
                                             # kept as the equivalence
                                             # oracle.  Configs the mixed
                                             # walk cannot drive (legacy /
                                             # chunked prefill, non-staged
                                             # or unbatched decode) resolve
                                             # to "split" automatically.
    stage_dispatch: str = "async"            # "async" (default): the
                                             # per-layer host stage hands
                                             # the FlashD2H write-back to a
                                             # HostStageWorker thread and
                                             # never blocks the dispatch
                                             # thread on the device beyond
                                             # np.asarray(selected ids) —
                                             # attend(l) / select(l+1)
                                             # dispatch while layer l's
                                             # stripe conversion + DRAM
                                             # staging run off-thread,
                                             # fenced before any gather of
                                             # the same layer and drained
                                             # before sampling; "sync": the
                                             # fully blocking host stage,
                                             # kept as the equivalence
                                             # oracle (async must be
                                             # greedy-token-identical).
                                             # See docs/architecture.md §10.
    drop_evicted_device_blocks: Optional[bool] = None
    # True: HBM-evicted blocks are physically zeroed on device and restored
    # from the host pool via the fused H2D gather when re-selected.  On the
    # STAGED plane the restore lands between a layer's select and attend
    # stages — before use — so the physical drop is oracle-exact and the
    # knob defaults ON (None -> resolved to decode_plane == "staged").  On
    # the fused "persistent" plane a restore can only land AFTER the forward
    # that re-selected the block, so the forward reads zeros under eviction
    # pressure and outputs diverge — supported for demonstration, default
    # off.  See docs/architecture.md §3.
    offload_quant: str = "none"
    # DRAM offload tier storage format: "none" (default — host pools store
    # fp blocks; every greedy-equivalence oracle runs here) | "int8"
    # (pools store symmetric int8 with one f32 scale per (layer, kv-head,
    # block) per tensor; blocks quantize on the FlashD2H save path and
    # dequantize on the FlashH2D restore path, so D2H+H2D wire bytes —
    # TransferStats, obs spans, and the cost model's per-layer transfer
    # charges — shrink ~dtype_bytes x while decode output stays within the
    # bench_accuracy cosine bound).  See docs/architecture.md §12.
    obs: Optional[bool] = None
    # True: the obs layer is live — the engine builds a Tracer (Chrome
    # trace-event JSON, one lane per thread; see src/repro/obs/) and
    # installs it on the planes, the KV manager and the HostStageWorker,
    # and per-iteration scheduler gauges flow into the MetricsRegistry.
    # None resolves from the environment (REPRO_OBS=1 enables) into a
    # COPY, same as the knobs above.  Default off: hot paths pay one
    # `tracer.enabled` attribute read per instrumentation point and emit
    # nothing (NULL_TRACER), keeping greedy tokens byte-identical.
    # `engine.metrics_snapshot()` works either way.
    # See docs/architecture.md §11.


@dataclasses.dataclass
class _ReqState:
    """Engine-side state for one request."""
    req: Request
    tokens: np.ndarray                              # prompt token ids
    inputs_extra: Dict[str, Any]                    # frames / patch_embeds
    decode_state: Optional[Dict] = None             # model DecodeState (B=1;
                                                    # stacked per iteration)
    lp: Optional[LayerPrefillState] = None          # layer-segmented cursor
                                                    # (legacy executor)
    prefill_carry: int = 0                          # plane executor: unspent
                                                    # token-layer budget
                                                    # carried across iters
    chunk_ctx: Optional[List] = None                # chunked: per-layer kv ctx
    chunk_rec: Optional[List] = None                # chunked: recurrent states
    last_logits: Optional[jax.Array] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    num_blocks: int = 0
    group_key: Optional[Tuple] = None               # batched-decode grouping
                                                    # (cached before the plane
                                                    # takes state ownership)


class ServingEngine:
    """Continuous-batching engine over real model forwards."""

    def __init__(self, params: Dict, cfg: ModelConfig, eng: EngineConfig,
                 hw: cm.HardwareSpec = cm.TPU_V5E):
        self.params = params
        self.cfg = cfg
        self.eng = eng
        self.hw = hw
        if eng.decode_plane not in ("staged", "persistent", "stacked"):
            raise ValueError(f"unknown decode_plane {eng.decode_plane!r}; "
                             f"expected 'staged', 'persistent' or 'stacked'")
        if eng.prefill_exec not in ("plane", "legacy"):
            raise ValueError(f"unknown prefill_exec {eng.prefill_exec!r}; "
                             f"expected 'plane' or 'legacy'")
        self.plane_mesh = PlaneMesh.resolve(eng.mesh_spec)
        if self.plane_mesh is not None:
            if not (eng.batched_decode and eng.decode_plane == "staged"):
                raise ValueError(
                    "mesh_spec shards the STAGED decode plane: it requires "
                    "batched_decode=True and decode_plane='staged'")
            if not cfg.dsa.enabled:
                raise ValueError(
                    "mesh_spec requires DSA (cfg.dsa.enabled): the sharded "
                    "attend stage has no dense fallback")
            if eng.attn_impl != "ref":
                raise ValueError(
                    "mesh_spec requires attn_impl='ref': the sharded "
                    "attend stage runs the reference block-sparse "
                    "attention inside shard_map (no Pallas-kernel path)")
        if eng.hybrid_plane not in ("mixed", "split"):
            raise ValueError(f"unknown hybrid_plane {eng.hybrid_plane!r}; "
                             f"expected 'mixed' or 'split'")
        if eng.stage_dispatch not in ("async", "sync"):
            raise ValueError(f"unknown stage_dispatch "
                             f"{eng.stage_dispatch!r}; "
                             f"expected 'async' or 'sync'")
        if eng.offload_quant not in ("none", "int8"):
            raise ValueError(f"unknown offload_quant "
                             f"{eng.offload_quant!r}; "
                             f"expected 'none' or 'int8'")
        if eng.hybrid_plane == "mixed" and not (
                eng.batched_decode and eng.decode_plane == "staged"
                and eng.prefill_mode == "layer_segmented"
                and eng.prefill_exec == "plane"):
            # the mixed walk drives exactly the staged decode plane and
            # the batched prefill plane; every other executor combination
            # falls back to the split two-plane path.  Resolve into a COPY
            # (same rationale as drop_evicted_device_blocks below).
            eng = dataclasses.replace(eng, hybrid_plane="split")
            self.eng = eng
        if eng.prefill_mode == "chunked" and cfg.attention_type == "mla":
            # the chunked baseline carries dense (k, v) context between
            # chunks; MLA's latent cache has no chunked-context path yet
            raise NotImplementedError(
                "chunked prefill does not support MLA models; use "
                "prefill_mode='layer_segmented'")
        if eng.drop_evicted_device_blocks is None:
            # the staged plane restores evicted blocks BEFORE the attention
            # that re-selects them, so the physical drop is oracle-exact
            # there and on by default; everywhere else it would change
            # outputs (or has no device plane to act on).  Resolve into a
            # COPY — mutating the caller's config would leak the resolved
            # value into configs reused for other planes.
            eng = dataclasses.replace(eng, drop_evicted_device_blocks=(
                eng.decode_plane == "staged" and eng.batched_decode
                and eng.decode_write_back))
            self.eng = eng
        if eng.drop_evicted_device_blocks and not eng.decode_write_back:
            raise ValueError(
                "drop_evicted_device_blocks requires decode_write_back: "
                "restores come from the host pool, which is only a superset "
                "of device KV when decode write-back is on")
        if eng.drop_evicted_device_blocks and not (
                eng.batched_decode
                and eng.decode_plane in ("staged", "persistent")):
            raise ValueError(
                "drop_evicted_device_blocks only acts on a device plane "
                "(batched_decode=True, decode_plane='staged' or "
                "'persistent')")
        if eng.obs is None:
            # env opt-in so benches/CI can trace without touching configs;
            # resolve into a COPY (same rationale as the knobs above)
            eng = dataclasses.replace(
                eng, obs=os.environ.get("REPRO_OBS", "") == "1")
            self.eng = eng
        self.tracer = Tracer() if eng.obs else NULL_TRACER
        self.metrics = MetricsRegistry()
        self.mc = cm.ModelCost.from_config(cfg)
        self.rng = np.random.default_rng(eng.seed)

        L_attn = max(cfg.num_attention_layers(), 1)
        self.geom = KVGeometry(
            num_layers=L_attn, num_kv_heads=max(cfg.num_kv_heads, 1),
            block_size=cfg.dsa.block_size, head_dim=cfg.kv_cache_dim,
            kv_factor=1 if cfg.attention_type == "mla" else 2)
        inject = (eng.max_inject_tokens if eng.max_inject_tokens > 0
                  else eng.chunk_size * cfg.num_layers)
        seg_tokens = (eng.prefill_max_tokens_per_step
                      if (eng.prefill_mode == "layer_segmented"
                          and eng.prefill_exec == "plane"
                          and cfg.attention_type != "mla") else 0)
        self.scheduler = Scheduler(
            SchedulerConfig(
                r_max=eng.r_max, t_max=eng.t_max,
                m_avl_bytes=eng.hbm_budget_bytes if eng.ws_control else 0,
                prefill_mode=eng.prefill_mode, chunk_size=eng.chunk_size,
                max_inject_tokens=inject, segment_tokens=seg_tokens,
                ws_control=eng.ws_control),
            self.geom, cfg.num_layers, cfg.dsa.top_k_blocks)
        self.kv_mgr = KVCacheManager(self.geom, eng.hbm_budget_bytes,
                                     offload_quant=eng.offload_quant)
        self.kv_mgr.tracer = self.tracer
        # wire bytes of one (layer, block) transfer at the offload tier's
        # STORED size — what the cost model charges per moved block (int8
        # payload + scales under offload_quant="int8"; the modeled bf16
        # size otherwise)
        self._offload_block_bytes = cm.offload_block_bytes(
            self.geom.num_kv_heads, self.geom.head_dim,
            self.geom.block_size, kv_factor=self.geom.kv_factor,
            dtype_bytes=self.geom.dtype_bytes, quant=eng.offload_quant)
        self.states: Dict[str, _ReqState] = {}
        self._pending: List[Request] = []      # not yet arrived
        self.now = 0.0
        self.iterations = 0
        self.loads_per_iter: List[int] = []
        self.prefill_hbm_peak_tokens: int = 0    # Fig. 16a rationale metric
        self.decode_step_calls = 0               # model forwards (decode)
        self.decode_tokens = 0                   # tokens those calls produced
        self.stack_calls = 0                     # full-pool stack/unstack
                                                 # round-trips (0 on the
                                                 # persistent plane)
        self.planes: Dict[Tuple, DevicePoolPlane] = {}   # group_key -> plane
        self._req_plane: Dict[str, DevicePoolPlane] = {}
        self.prefill_planes: Dict[Tuple, PrefillPlane] = {}
        self._req_prefill_plane: Dict[str, PrefillPlane] = {}
        self.prefill_launches = 0                # batched plane launches
        self.admit_embed_launches = 0            # batched admission embeds
        self.hybrid = (HybridPlane(cfg)
                       if eng.hybrid_plane == "mixed" else None)
        if self.hybrid is not None:
            self.hybrid.tracer = self.tracer
        # async dispatch pipeline (stage_dispatch="async", the default):
        # per-layer FlashD2H write-back staging runs on this worker so the
        # dispatch thread's only per-layer device block is np.asarray(idx)
        self._stage_async = eng.stage_dispatch == "async"
        self._worker: Optional[HostStageWorker] = None
        self.worker_jobs_run = 0      # folded in from retired workers at
        self.worker_busy_s = 0.0      # close() so stats survive run()
        # per-iteration scheduler/batch gauges (memoized instruments:
        # one .set() per iteration, no name lookups on the hot path)
        _m = self.metrics
        self._g_queue = _m.gauge(
            "sched.queue_depth", "requests waiting for admission")
        self._g_running = _m.gauge(
            "sched.running", "requests admitted (prefill+decode)")
        self._g_batch_decode = _m.gauge(
            "sched.batch_decode_rows", "decode rows this iteration")
        self._g_batch_prefill = _m.gauge(
            "sched.batch_prefill_rows", "prefill rows this iteration")
        self._g_ws_decode = _m.gauge(
            "sched.ws_decode_bytes", "estimated decode working set")
        self._g_ws_prefill = _m.gauge(
            "sched.ws_prefill_bytes", "estimated prefill working set")
        self._g_hbm_used = _m.gauge(
            "kv.hbm_used_bytes", "actual HBM residency after the iteration")
        self._h_iter = _m.histogram(
            "engine.iteration_s", "wall-clock seconds per engine iteration")
        self.mixed_iter_log: List[Dict[str, Any]] = []
        # per mixed iteration: per-layer fused d2h/h2d call counts, group
        # counts and the measured jitted-launch total — what
        # tests/planeasserts.assert_mixed_launch_invariant checks against
        # plane_contract.mixed_launches_per_iteration
        self._staged_layer_bytes: Dict[int, int] = {}    # model layer ->
                                                         # H2D restore bytes
                                                         # this iteration
                                                         # (staged charging)
        self.staged_probe = None   # test hook: called between a layer's
                                   # restore and attend as probe(engine,
                                   # plane, layer, sts, blocks_by_req) —
                                   # the restore-ordering window
        # model layer -> attn-layer ordinal (hot path: per layer per decode
        # iteration) and its inverse (maps HBMCache eviction keys back to
        # plane cache indices), both precomputed once
        self._layer_to_lidx: Dict[int, int] = {}
        self._lidx_to_layer: Dict[int, int] = {}
        n = 0
        for i in range(cfg.num_layers):
            lidx = min(n, self.geom.num_layers - 1)
            self._layer_to_lidx[i] = lidx
            if M.layer_kind(cfg, i) == "attn":
                self._lidx_to_layer.setdefault(lidx, i)
                n += 1

    # ------------------------------------------------------------------
    # Request intake
    # ------------------------------------------------------------------
    def submit(self, req: Request, tokens: Optional[np.ndarray] = None,
               **inputs_extra) -> None:
        """Register a request with the engine (it joins the scheduler queue
        at ``req.arrival_time``, in engine-clock SECONDS).

        tokens: prompt token ids, length ``req.prompt_len`` (random ids are
        drawn when omitted).  inputs_extra: frontend tensors (``frames`` for
        whisper, ``patch_embeds`` for VLMs), leading batch axis 1.

        Capacity contract: the KV manager registers a host pool sized for
        ``prompt_len + max_new_tokens`` (+ patches) TOKENS — every later
        stage (FlashD2H staging, fused gathers, device-plane restores)
        bounds-checks block ranges against that registration, so exceeding
        it raises instead of corrupting pool state."""
        if tokens is None:
            tokens = self.rng.integers(
                4, self.cfg.vocab_size, size=req.prompt_len).astype(np.int32)
        assert len(tokens) == req.prompt_len
        st = _ReqState(req=req, tokens=np.asarray(tokens, np.int32),
                       inputs_extra=dict(inputs_extra))
        total = req.prompt_len + req.max_new_tokens
        if self.cfg.frontend == "vit_patch_stub":
            total += self.cfg.num_patches
        st.num_blocks = -(-total // self.cfg.dsa.block_size) + 1
        self.states[req.req_id] = st
        self._pending.append(st.req)
        self._pending.sort(key=lambda r: r.arrival_time)
        self.kv_mgr.register(req.req_id, total, self.eng.hbm_blocks_per_request)
        if self.eng.drop_evicted_device_blocks:
            self.kv_mgr.caches[req.req_id].track_evictions = True

    def _admit_arrivals(self) -> None:
        while self._pending and self._pending[0].arrival_time <= self.now:
            self.scheduler.add_request(self._pending.pop(0))

    # ------------------------------------------------------------------
    # Prefill execution
    # ------------------------------------------------------------------
    def _model_inputs(self, st: _ReqState) -> Dict[str, Any]:
        d = {"tokens": jnp.asarray(st.tokens[None, :])}
        d.update({k: jnp.asarray(v) for k, v in st.inputs_extra.items()})
        return d

    def _start_layer_segmented(self, st: _ReqState, tokens_per_step: int):
        h, positions, enc_kvs = M.prefill_embed(
            self.params, self.cfg, self._model_inputs(st))
        segs = plan_segments(st.req.prompt_len, self.cfg.num_layers,
                             tokens_per_step)
        st.lp = LayerPrefillState(segments=segs, hidden=h,
                                  positions=positions, enc_kvs=enc_kvs,
                                  rec_states=M._init_rec_states(
                                      self.cfg, 1, h.dtype))
        # decode-state extra keeps enc_kvs in per-layer LIST form so every
        # leaf's axis 0 is the batch axis (stacked form leads with L, which
        # would break the batched-decode concat)
        enc_list = ([M.index_enc_kvs(enc_kvs, i)
                     for i in range(self.cfg.num_layers)]
                    if enc_kvs is not None else None)
        st.decode_state = {"caches": [None] * self.cfg.num_layers,
                           "cur_len": None,
                           "extra": ({"enc_kvs": enc_list} if enc_list
                                     else {})}

    def _run_layer_segment(self, st: _ReqState) -> bool:
        """Execute the next layer segment.  Returns True when prefill done.

        One segment = one whole layer over the whole prompt (the
        chunk-hybridised variant splits within a layer; we execute whole
        layers here because the residual-carry makes intra-layer chunks of
        *different* layers equivalent work — the scheduler already
        charges token work per segment)."""
        cfg = self.cfg
        seg = st.lp.advance()
        l = seg.layer
        enc_kv = M.index_enc_kvs(st.lp.enc_kvs, l)
        h, kv_out, new_rec = M.prefill_layer(
            self.params, cfg, l, st.lp.hidden, st.lp.positions,
            rec_state=st.lp.rec_states[l], enc_kv=enc_kv,
            moe_drop_free=True)
        st.lp.hidden = h
        st.lp.rec_states[l] = new_rec

        # FlashD2H: save this layer's KV contiguously to the host pool, then
        # evict from HBM — the paper's one-layer HBM bound.
        if kv_out is not None:
            pool_kv, meta = self._kv_to_layer_cache(st, kv_out)
            st.decode_state["caches"][l] = pool_kv
            host = self.kv_mgr.pools.get(st.req.req_id)
            cache = self.kv_mgr.caches.get(st.req.req_id)
            if host is not None:
                k_arr = np.asarray(kv_out[0][0], np.float32)   # (S,Hkv,D)
                if k_arr.ndim == 2:            # MLA latent: (S, lat) -> 1 head
                    k_arr = k_arr[:, None, :]
                lidx = self._attn_layer_index(l)
                v_arr = None
                if len(kv_out) > 1:
                    v_arr = np.transpose(
                        np.asarray(kv_out[1][0], np.float32), (1, 0, 2))
                # plane-contract: allow(fused-transfer) legacy per-request executor; the prefill plane owns the fused path
                host.save_contiguous(lidx, 0,
                                     np.transpose(k_arr, (1, 0, 2)), v_arr)
                host.flush()
            if cache is not None:
                cache.drop_layer(self._attn_layer_index(l))
        else:
            st.decode_state["caches"][l] = new_rec

        if seg.is_last:
            logits = M.prefill_finalize(self.params, cfg, st.lp.hidden)
            st.last_logits = logits
            st.decode_state["cur_len"] = jnp.full(
                (1,), st.lp.hidden.shape[1], jnp.int32)
            st.lp = None
            return True
        return False

    def _attn_layer_index(self, model_layer: int) -> int:
        """Map model layer id -> attention-layer ordinal (geom.num_layers).
        Precomputed in __init__ — called per layer per decode iteration."""
        return self._layer_to_lidx[model_layer]

    def _kv_to_layer_cache(self, st: _ReqState, kv_out: Tuple):
        cfg = self.cfg
        if cfg.attention_type == "mla":
            (latent,) = kv_out
            kpool, meta = M._kv_to_pool(cfg, latent[:, :, None, :],
                                        st.num_blocks, jnp.float32)
            return {"k": kpool, "meta": meta}, meta
        k, v = kv_out
        kpool, meta = M._kv_to_pool(cfg, k, st.num_blocks, jnp.float32)
        vpool, _ = M._kv_to_pool(cfg, v, st.num_blocks, jnp.float32)
        return {"k": kpool, "v": vpool, "meta": meta}, meta

    def _run_chunked_prefill(self, st: _ReqState, inject: int) -> bool:
        """Chunked-prefill baseline: process `inject` new prompt tokens
        through ALL layers, carrying per-layer dense KV context."""
        cfg = self.cfg
        r = st.req
        start = r.prefill_tokens_done
        end = min(start + inject, r.prompt_len)
        chunk_tokens = st.tokens[start:end]

        if st.chunk_ctx is None:
            st.chunk_ctx = [None] * cfg.num_layers
            st.chunk_rec = M._init_rec_states(cfg, 1, jnp.float32)
            if cfg.is_encoder_decoder or cfg.frontend == "vit_patch_stub":
                # run embed of full prompt once is cheating for VLM; for the
                # chunked baseline we only support pure-text archs' frontends
                pass

        h = self.params["embed"][jnp.asarray(chunk_tokens[None, :])]
        positions = jnp.arange(start, end, dtype=jnp.int32)[None, :]
        from repro.models import attention as attn_mod
        from repro.models import ffn as ffn_mod
        for l in range(cfg.num_layers):
            p = M.get_layer(self.params, l)
            kind = M.layer_kind(cfg, l)
            if kind == "attn" and cfg.attention_type != "mla":
                h_in = M._norm(cfg, p["attn_norm"], h)
                ctx = st.chunk_ctx[l]
                out, k, v = attn_mod.gqa_self_attention(
                    p["attn"], cfg, h_in, positions,
                    k_ctx=None if ctx is None else ctx[0],
                    v_ctx=None if ctx is None else ctx[1],
                    q_offset=start, return_kv=True)
                st.chunk_ctx[l] = (
                    k if ctx is None else jnp.concatenate([ctx[0], k], axis=1),
                    v if ctx is None else jnp.concatenate([ctx[1], v], axis=1))
                h = h + out
                h_in = M._norm(cfg, p["ffn_norm"], h)
                if "moe" in p:
                    # drop-free like every serving prefill path: capacity
                    # must not couple chunk size to routing drops
                    f, _ = ffn_mod.moe_apply(p["moe"], cfg, h_in,
                                             drop_free=True)
                else:
                    f = ffn_mod.ffn_apply(p["ffn"], h_in)
                h = h + f
            else:
                # recurrent / MLA layers fall back to full-layer forward
                h, _, _, new_rec = M.layer_forward(
                    p, cfg, h, positions, kind=kind,
                    rec_state=st.chunk_rec[l], return_kv=False,
                    moe_drop_free=True)
                st.chunk_rec[l] = new_rec
        r.prefill_tokens_done = end
        if end >= r.prompt_len:
            st.last_logits = M.lm_head(self.params, cfg, h[:, -1:, :])[:, 0]
            # build the decode state from accumulated ctx
            caches = []
            host = self.kv_mgr.pools.get(r.req_id)
            for l in range(cfg.num_layers):
                kind = M.layer_kind(cfg, l)
                if kind == "attn" and cfg.attention_type != "mla":
                    k, v = st.chunk_ctx[l]
                    kp, meta = M._kv_to_pool(cfg, k, st.num_blocks, jnp.float32)
                    vp, _ = M._kv_to_pool(cfg, v, st.num_blocks, jnp.float32)
                    caches.append({"k": kp, "v": vp, "meta": meta})
                    if host is not None:
                        # FlashD2H: the chunked baseline also leaves a DRAM
                        # copy of the prompt KV (one contiguous save per
                        # layer) so decode-time H2D restores stay exact
                        # plane-contract: allow(fused-transfer) chunked baseline runs one request at a time; nothing to fuse across
                        host.save_contiguous(
                            self._attn_layer_index(l), 0,
                            np.transpose(np.asarray(k[0], np.float32),
                                         (1, 0, 2)),
                            np.transpose(np.asarray(v[0], np.float32),
                                         (1, 0, 2)))
                else:
                    caches.append(st.chunk_rec[l])
            if host is not None:
                host.flush()
            st.decode_state = {
                "caches": caches,
                "cur_len": jnp.full((1,), r.prompt_len, jnp.int32),
                "extra": {}}
            st.chunk_ctx = None
            return True
        return False

    # ------------------------------------------------------------------
    # Prefill plane (batched jitted layer-segmented prefill, the default)
    # ------------------------------------------------------------------
    def _prefill_group_key(self, enc_list) -> Tuple:
        """Requests share a PrefillPlane when their whisper encoder KV
        shapes agree (mirrors the decode plane's grouping)."""
        if not enc_list:
            return ()
        return tuple((tuple(a.shape[1:]), str(a.dtype))
                     for kv in enc_list for a in kv)

    def _batched_admit_embed(self, sts: List[_ReqState]
                             ) -> Dict[str, jax.Array]:
        """{req_id: h (1, S, d)} for an admission batch's pure-text rows,
        embedded in ONE jitted bucketed launch (admission used to embed
        eagerly one request at a time).  Requests with frontend tensors
        (whisper frames, VLM patches) fall back to the per-request
        ``prefill_embed`` inside ``_admit_prefill_plane``."""
        cfg = self.cfg
        text = [st for st in sts
                if not st.inputs_extra and cfg.frontend == "none"
                and not cfg.is_encoder_decoder]
        if not text:
            return {}
        pol = self.eng.bucketing
        n_cap = pol.bucket_batch(len(text))
        s_cap = pol.bucket_tokens(max(len(st.tokens) for st in text))
        toks = np.zeros((n_cap, s_cap), np.int32)
        for i, st in enumerate(text):
            toks[i, :len(st.tokens)] = st.tokens
        h_all = admit_embed_fns_for(cfg).embed(self.params,
                                               jnp.asarray(toks))
        self.admit_embed_launches += 1
        return {st.req.req_id: h_all[i:i + 1, :len(st.tokens)]
                for i, st in enumerate(text)}

    def _admit_prefill_plane(self, st: _ReqState,
                             h: Optional[jax.Array] = None) -> PrefillPlane:
        """Plan the request's (layer, chunk) segments and admit it into its
        group's PrefillPlane row.  ``h``: the admission batch's pre-embedded
        residual stream (``_batched_admit_embed``); None falls back to the
        per-request embed (frontend inputs)."""
        cfg = self.cfg
        if h is None:
            h, _, enc_kvs = M.prefill_embed(self.params, cfg,
                                            self._model_inputs(st))
        else:
            enc_kvs = None
        S = int(h.shape[1])                     # prompt (+ patches)
        step = S
        if (self.eng.prefill_max_tokens_per_step > 0
                and cfg.attention_type != "mla"):
            # MLA keeps whole-layer segments: the latent cache has no
            # chunked-context attention path (same restriction as the
            # chunked baseline)
            step = self.eng.prefill_max_tokens_per_step
        segs = plan_segments(S, cfg.num_layers, step)
        enc_list = ([M.index_enc_kvs(enc_kvs, i)
                     for i in range(cfg.num_layers)]
                    if enc_kvs is not None else None)
        key = self._prefill_group_key(enc_list)
        plane = self.prefill_planes.get(key)
        if plane is None:
            plane = self.prefill_planes[key] = PrefillPlane(
                cfg, self.eng.bucketing, plane_mesh=self.plane_mesh)
            plane.tracer = self.tracer
        plane.admit(st.req.req_id, h, segs, enc_list)
        self._req_prefill_plane[st.req.req_id] = plane
        st.decode_state = {"caches": [None] * cfg.num_layers,
                           "cur_len": None,
                           "extra": ({"enc_kvs": enc_list} if enc_list
                                     else {})}
        return plane

    def _prefill_plane_iteration(self, prefill_reqs
                                 ) -> Tuple[float, List[Request], int]:
        """Run one iteration of batched plane prefill for the scheduled
        requests.  Per executed (layer, chunk) group: ONE jitted bucketed
        launch over the whole batch, ONE fused FlashD2H save of the group's
        KV stripes (``save_new_tokens_fused``), and — at each row's last
        chunk of the layer — the decode pool build plus HBM eviction of the
        layer (the one-layer bound).  Rows whose final segment ran share
        one finalize (logits) launch.

        Returns (modeled time, finished requests, iteration HBM footprint
        in token-layer units summed over every admitted prefill row)."""
        L = self.cfg.num_layers
        t = 0.0
        done: List[Request] = []
        fp = 0
        # batch admission-time embedding: every pure-text request admitted
        # this iteration shares ONE bucketed embedding launch
        pre_h = self._batched_admit_embed(
            [self.states[req.req_id] for req, _ in prefill_reqs
             if req.req_id not in self._req_prefill_plane])
        by_plane: Dict[int, Tuple[PrefillPlane, Dict[str, int]]] = {}
        for req, inject in prefill_reqs:
            st = self.states[req.req_id]
            if req.scheduled_time is None:
                req.scheduled_time = self.now
            plane = self._req_prefill_plane.get(req.req_id)
            if plane is None:
                plane = self._admit_prefill_plane(st,
                                                  h=pre_h.get(req.req_id))
            st.prefill_carry += max(int(inject), 1)
            _, allow = by_plane.setdefault(id(plane), (plane, {}))
            allow[req.req_id] = st.prefill_carry
        for plane, allow in by_plane.values():
            spent: Dict[str, int] = {}
            t_acc = [0.0]

            def group_cb(g, plane=plane, spent=spent, t_acc=t_acc):
                # runs in the window right after the group's launch, while
                # the plane's ONE-layer context still holds this layer
                n_shards, ag_bytes = 1, 0
                if (self.plane_mesh is not None and g.kind == "attn"
                        and self.cfg.attention_type != "mla"):
                    # sequence-sharded launch: attention compute splits
                    # across the model axis; the sharded attention outputs
                    # are re-gathered (charged like one layer of KV)
                    n_shards = self.plane_mesh.model_size
                    tok = sum(g.segs[rid].chunk_len for rid in g.req_ids)
                    ag_bytes = int(tok * self.mc.kv_bytes_per_token
                                   / max(self.geom.num_layers, 1))
                t_acc[0] += cm.batched_prefill_time(
                    self.hw, self.mc,
                    [(g.segs[rid].chunk_len,
                      g.chunk_start + g.segs[rid].chunk_len)
                     for rid in g.req_ids], layers=1,
                    n_shards=n_shards, allgather_bytes=ag_bytes)
                self.prefill_launches += 1
                for rid in g.req_ids:
                    spent[rid] = spent.get(rid, 0) + g.segs[rid].chunk_len
                if g.kind != "attn":
                    return
                lidx = self._attn_layer_index(g.layer)
                # FlashD2H: ONE fused save of the whole group's stripes
                kv_by_req = plane.read_group_kv(g)
                self.kv_mgr.save_new_tokens_fused(lidx, {
                    rid: (g.chunk_start, k, v)
                    for rid, (k, v) in kv_by_req.items()})
                for rid in g.req_ids:
                    pool = self.kv_mgr.pools.get(rid)
                    if pool is not None:
                        pool.flush()
                # end of layer: build the decode pool from the plane's
                # one-layer context, then evict the layer from HBM
                for rid in g.req_ids:
                    if not g.segs[rid].is_last_chunk_of_layer:
                        continue
                    st_r = self.states[rid]
                    pool_kv, _ = self._kv_to_layer_cache(
                        st_r, plane.layer_ctx(rid))
                    st_r.decode_state["caches"][g.layer] = pool_kv
                    cache = self.kv_mgr.caches.get(rid)
                    if cache is not None:
                        cache.drop_layer(lidx)

            res = plane.run_iteration(self.params, allow, group_cb)
            t += t_acc[0]
            for rid in allow:
                st_r = self.states[rid]
                st_r.prefill_carry = max(
                    0, st_r.prefill_carry - spent.get(rid, 0))
                # mirror the plane cursor into the scheduler's pacing state
                req = st_r.req
                if not plane.done(rid):
                    seg = plane.segments[rid][plane.next_idx[rid]]
                    req.prefill_layer = seg.layer
                    req.prefill_layer_tokens_done = min(
                        seg.chunk_start, max(req.prompt_len - 1, 0))
            for rid, peak in res.peaks.items():
                fp += hbm_footprint_tokens(
                    plane.tok_len[rid], "layer_segmented", L,
                    layer_tokens_resident=peak)
            for rid in res.finished:
                st_r = self.states[rid]
                row = plane.rows[rid]
                st_r.last_logits = res.logits[row:row + 1]
                caches = st_r.decode_state["caches"]
                for l in range(L):
                    if caches[l] is None and M.layer_kind(self.cfg,
                                                          l) != "attn":
                        caches[l] = plane.rec_state(rid, l)
                st_r.decode_state["cur_len"] = jnp.full(
                    (1,), plane.tok_len[rid], jnp.int32)
                st_r.req.prefill_layer = L
                st_r.req.prefill_layer_tokens_done = 0
                plane.release(rid)
                self._req_prefill_plane.pop(rid, None)
                done.append(st_r.req)
        # planes with NO scheduled request this iteration still hold their
        # rows' mid-layer chunk residency — count it into the watermark
        for plane in self.prefill_planes.values():
            if id(plane) in by_plane:
                continue
            for rid, resident in plane.resident_tokens().items():
                fp += hbm_footprint_tokens(
                    plane.tok_len[rid], "layer_segmented", L,
                    layer_tokens_resident=resident)
        return t, done, fp

    # ------------------------------------------------------------------
    # Mixed iteration (hybrid plane)
    # ------------------------------------------------------------------
    def _mixed_iteration(self, plan: BatchPlan
                         ) -> Tuple[int, List[Request], int, List[float]]:
        """One MIXED iteration: every decode group's staged pipeline and
        every prefill plane's (layer, chunk) groups ride the SAME layer
        walk (``HybridPlane.run_iteration``), sharing one per-layer host
        stage.  Per attention layer the ``layer_cb`` below does, in order:

        1. ONE merged fused FlashD2H: decode write-back of the layer's
           just-appended KV (every decode plane) PLUS the layer's fresh
           prefill-chunk KV (``read_group_kv`` per group, same-rid chunks
           concatenated — chunks of one layer are contiguous), in a single
           ``save_new_tokens_fused`` call;
        2. LRU residency for every decode plane's selections, then at most
           ONE merged fused FlashH2D (``load_blocks_fused``) covering all
           planes' misses, scattered into each plane's slots BEFORE the
           attention that selected them;
        3. the one-stage-deferred eviction drop (``protect=``) and the
           ``staged_probe`` hook, per decode plane;
        4. prefill end-of-layer pool builds + HBM layer eviction (the
           one-layer bound), exactly as the split path's group callback.

        Returns (blocks loaded, finished prefill requests, iteration HBM
        footprint in token-layer units, per-model-layer modeled prefill
        seconds for ``costmodel.mixed_iteration_time``)."""
        L = self.cfg.num_layers
        done: List[Request] = []
        fp = 0
        drop = self.eng.drop_evicted_device_blocks
        per_block_bytes = self._offload_block_bytes
        prefill_by_layer = [0.0] * L
        loads_total = [0]
        spent: Dict[str, int] = {}

        # prefill jobs (admission mirrors _prefill_plane_iteration)
        pre_h = self._batched_admit_embed(
            [self.states[req.req_id] for req, _ in plan.prefill_reqs
             if req.req_id not in self._req_prefill_plane])
        by_plane: Dict[int, Tuple[PrefillPlane, Dict[str, int]]] = {}
        for req, inject in plan.prefill_reqs:
            st = self.states[req.req_id]
            if req.scheduled_time is None:
                req.scheduled_time = self.now
            plane = self._req_prefill_plane.get(req.req_id)
            if plane is None:
                plane = self._admit_prefill_plane(st,
                                                  h=pre_h.get(req.req_id))
            st.prefill_carry += max(int(inject), 1)
            _, allow = by_plane.setdefault(id(plane), (plane, {}))
            allow[req.req_id] = st.prefill_carry
        prefill_jobs = [PrefillJob(plane, allow)
                        for plane, allow in by_plane.values()]

        # decode jobs (grouping mirrors step()'s split decode dispatch)
        groups: Dict[Tuple, List[_ReqState]] = {}
        for req in plan.decode_reqs:
            st = self.states[req.req_id]
            if st.group_key is None:
                st.group_key = self._decode_group_key(st)
            groups.setdefault(st.group_key, []).append(st)
        decode_jobs: List[DecodeJob] = []
        decode_sts: List[List[_ReqState]] = []
        pending_evict: Dict[int, Dict[str, set]] = {}
        sel_pairs: Dict[str, List[Tuple[int, int]]] = {}
        for key, sts in groups.items():
            plane = self._plane_for(key, sts)
            decode_jobs.append(DecodeJob(plane, {
                st.req.req_id: st.out_tokens[-1] for st in sts}))
            decode_sts.append(sts)
            pending_evict[id(plane)] = {st.req.req_id: set() for st in sts}
            sel_pairs.update({st.req.req_id: [] for st in sts})

        entry: Dict[str, Any] = {
            "layers": {}, "decode_planes": len(decode_jobs),
            "decode_rows": len(plan.decode_reqs),
            "prefill_rows": len(plan.prefill_reqs),
            "groups": 0, "finalize": 0, "launches": 0}

        worker = self._stage_worker() if self._stage_async else None

        def _layer_log_and_budget(win: LayerWindow, lidx: int) -> Dict:
            """Shared pure-host head of both layer callbacks: the
            per-layer log entry, modeled prefill launch cost, and the
            prefill token-budget spend."""
            lay_log = {"d2h": 0, "h2d": 0, "groups": len(win.groups),
                       "attn": win.kind == "attn",
                       "decode": bool(win.selections)}
            entry["layers"][win.layer] = lay_log
            for plane, g in win.groups:
                n_shards, ag_bytes = 1, 0
                if (self.plane_mesh is not None and g.kind == "attn"
                        and self.cfg.attention_type != "mla"):
                    n_shards = self.plane_mesh.model_size
                    tok = sum(g.segs[rid].chunk_len for rid in g.req_ids)
                    ag_bytes = int(tok * self.mc.kv_bytes_per_token
                                   / max(self.geom.num_layers, 1))
                prefill_by_layer[win.layer] += cm.batched_prefill_time(
                    self.hw, self.mc,
                    [(g.segs[rid].chunk_len,
                      g.chunk_start + g.segs[rid].chunk_len)
                     for rid in g.req_ids], layers=1,
                    n_shards=n_shards, allgather_bytes=ag_bytes)
                self.prefill_launches += 1
                for rid in g.req_ids:
                    spent[rid] = spent.get(rid, 0) + g.segs[rid].chunk_len
            return lay_log

        def layer_cb_sync(win: LayerWindow) -> None:
            lidx = (self._attn_layer_index(win.layer)
                    if win.kind == "attn" else -1)
            lay_log = _layer_log_and_budget(win, lidx)
            # 1. ONE merged fused FlashD2H: decode write-back + fresh
            #    prefill-chunk KV of THIS layer, single save call
            kv_merge: Dict[str, Tuple[int, Any, Any]] = {}
            for d, sel in win.selections:
                if not self.eng.decode_write_back:
                    continue
                k, v = d.plane.new_token_kv(d.req_ids, d.prev,
                                            layers=[win.layer])[win.layer]
                for i, rid in enumerate(d.req_ids):
                    kv_merge[rid] = (d.prev[rid], k[i][:, None, :],
                                     None if v is None else v[i][:, None, :])
            for plane, g in win.groups:
                if g.kind != "attn":
                    continue
                for rid, (k, v) in plane.read_group_kv(g).items():
                    cur = kv_merge.get(rid)
                    if cur is None:
                        kv_merge[rid] = (g.chunk_start, k, v)
                    else:
                        # same-rid chunks of one layer are contiguous in
                        # plan order: extend the stripe along tokens
                        s0, k0, v0 = cur
                        kv_merge[rid] = (
                            s0, np.concatenate([k0, k], axis=1),
                            None if v is None
                            else np.concatenate([v0, v], axis=1))
            if kv_merge:
                self.kv_mgr.save_new_tokens_fused(lidx, kv_merge)
                lay_log["d2h"] += 1
                for rid in kv_merge:
                    pool = self.kv_mgr.pools.get(rid)
                    if pool is not None:
                        pool.flush()
            # 2. LRU per decode plane, then at most ONE merged FlashH2D
            merged_missing: Dict[str, List[int]] = {}
            rounds = []
            for d, sel in win.selections:
                if sel is None:
                    continue
                blocks_by_req: Dict[str, List[int]] = {}
                for rid in d.req_ids:
                    blocks = dsa_mod.selected_block_ids(
                        sel[d.plane.rows[rid]])
                    blocks_by_req[rid] = blocks
                    sel_pairs[rid].extend((lidx, x) for x in blocks)
                missing_by_req, evicted_by_req = self.kv_mgr.access_layer(
                    lidx, blocks_by_req, drain_evicted=drop)
                pe = pending_evict[id(d.plane)]
                for rid, ev in evicted_by_req.items():
                    pe[rid].update(ev)
                loads_total[0] += sum(len(m)
                                      for m in missing_by_req.values())
                merged_missing.update(missing_by_req)
                rounds.append((d, blocks_by_req, missing_by_req))
            if merged_missing:
                self._staged_layer_bytes[win.layer] = (
                    self._staged_layer_bytes.get(win.layer, 0)
                    + sum(len(m) for m in merged_missing.values())
                    * per_block_bytes)
                payloads = self.kv_mgr.load_blocks_fused(lidx,
                                                         merged_missing)
                lay_log["h2d"] += 1
                if self.eng.decode_write_back:
                    for d, _, missing_by_req in rounds:
                        if missing_by_req:
                            d.plane.restore_blocks_fused(
                                win.layer,
                                {rid: (missing_by_req[rid], k, v)
                                 for rid, (k, v) in payloads.items()
                                 if rid in missing_by_req},
                                before_use=True)
            # 3. deferred eviction drop + probe, per decode plane
            for d, blocks_by_req, _ in rounds:
                sts_d = [self.states[rid] for rid in d.req_ids]
                if drop:
                    self._drop_pending_evictions(
                        d.plane, sts_d, pending_evict[id(d.plane)],
                        protect=(lidx, blocks_by_req))
                if self.staged_probe is not None:
                    self.staged_probe(self, d.plane, win.layer, sts_d,
                                      blocks_by_req)
            # 4. prefill end-of-layer: decode pool builds + HBM layer evict
            for plane, g in win.groups:
                if g.kind != "attn":
                    continue
                for rid in g.req_ids:
                    if not g.segs[rid].is_last_chunk_of_layer:
                        continue
                    st_r = self.states[rid]
                    pool_kv, _ = self._kv_to_layer_cache(
                        st_r, plane.layer_ctx(rid))
                    st_r.decode_state["caches"][g.layer] = pool_kv
                    cache = self.kv_mgr.caches.get(rid)
                    if cache is not None:
                        cache.drop_layer(lidx)

        def layer_cb_async(win: LayerWindow) -> None:
            # The DISPATCH WINDOW (see stage_cb_async): no device sync
            # beyond the selection arrays the driver already converted —
            # counted here as this layer's allowed host syncs.
            for d, sel in win.selections:
                if sel is not None:
                    d.plane.host_syncs += 1
            lidx = (self._attn_layer_index(win.layer)
                    if win.kind == "attn" else -1)
            lay_log = _layer_log_and_budget(win, lidx)
            with jax.transfer_guard_device_to_host("disallow"):
                # 1. ONE merged fused FlashD2H per layer, staged on the
                #    worker: dispatch every decode plane's stripe gather
                #    and every prefill group's chunk gather, submit one
                #    merging job (same single save_new_tokens_fused shape
                #    as the sync path)
                parts: List[Tuple] = []
                finishers: List[Tuple] = []
                for d, sel in win.selections:
                    if not self.eng.decode_write_back:
                        continue
                    kv_dev = d.plane.new_token_kv_async(
                        d.req_ids, d.prev, layers=[win.layer])[win.layer]
                    parts.append((list(d.req_ids), dict(d.prev), kv_dev))
                for plane, g in win.groups:
                    if g.kind != "attn":
                        continue
                    finishers.append(
                        (g.chunk_start, plane.read_group_kv_async(g)))
                if parts or finishers:
                    self._stage_writeback_async_merged(worker, lidx,
                                                       parts, finishers)
                    lay_log["d2h"] += 1
                # 2. LRU per decode plane (dispatch thread: access order
                #    stays byte-identical to sync), then at most ONE
                #    merged FlashH2D behind the per-layer fence
                merged_missing: Dict[str, List[int]] = {}
                rounds = []
                for d, sel in win.selections:
                    if sel is None:
                        continue
                    blocks_by_req: Dict[str, List[int]] = {}
                    for rid in d.req_ids:
                        blocks = dsa_mod.selected_block_ids(
                            sel[d.plane.rows[rid]])
                        blocks_by_req[rid] = blocks
                        sel_pairs[rid].extend((lidx, x) for x in blocks)
                    missing_by_req, evicted_by_req = \
                        self.kv_mgr.access_layer(lidx, blocks_by_req,
                                                 drain_evicted=drop)
                    pe = pending_evict[id(d.plane)]
                    for rid, ev in evicted_by_req.items():
                        pe[rid].update(ev)
                    loads_total[0] += sum(len(m)
                                          for m in missing_by_req.values())
                    merged_missing.update(missing_by_req)
                    rounds.append((d, blocks_by_req, missing_by_req))
                if merged_missing:
                    self._staged_layer_bytes[win.layer] = (
                        self._staged_layer_bytes.get(win.layer, 0)
                        + sum(len(m) for m in merged_missing.values())
                        * per_block_bytes)
                    # restore-before-use fence: this layer's outstanding
                    # merged write-back must land in DRAM before gathering
                    worker.fence(lidx)
                    payloads = self.kv_mgr.load_blocks_fused(
                        lidx, merged_missing)
                    lay_log["h2d"] += 1
                    if self.eng.decode_write_back:
                        for d, _, missing_by_req in rounds:
                            if missing_by_req:
                                d.plane.restore_blocks_fused(
                                    win.layer,
                                    {rid: (missing_by_req[rid], k, v)
                                     for rid, (k, v) in payloads.items()
                                     if rid in missing_by_req},
                                    before_use=True)
                # 3. deferred eviction drop, per decode plane (the probe
                #    runs outside the guard, below)
                for d, blocks_by_req, _ in rounds:
                    if drop:
                        self._drop_pending_evictions(
                            d.plane, [self.states[rid]
                                      for rid in d.req_ids],
                            pending_evict[id(d.plane)],
                            protect=(lidx, blocks_by_req))
                # 4. prefill end-of-layer: decode pool builds (device
                #    slices only, no sync) + HBM layer eviction
                for plane, g in win.groups:
                    if g.kind != "attn":
                        continue
                    for rid in g.req_ids:
                        if not g.segs[rid].is_last_chunk_of_layer:
                            continue
                        st_r = self.states[rid]
                        pool_kv, _ = self._kv_to_layer_cache(
                            st_r, plane.layer_ctx(rid))
                        st_r.decode_state["caches"][g.layer] = pool_kv
                        cache = self.kv_mgr.caches.get(rid)
                        if cache is not None:
                            cache.drop_layer(lidx)
            if self.staged_probe is not None and rounds:
                worker.fence(lidx)   # probes compare device vs host pools
                for d, blocks_by_req, _ in rounds:
                    self.staged_probe(self, d.plane, win.layer,
                                      [self.states[rid]
                                       for rid in d.req_ids],
                                      blocks_by_req)

        involved: Dict[int, Any] = {}
        for job in decode_jobs:
            involved[id(job.plane.staged_fns)] = job.plane.staged_fns
        for pj in prefill_jobs:
            involved[id(pj.plane.fns)] = pj.plane.fns
        calls0 = sum(f.calls for f in involved.values())
        res = self.hybrid.run_iteration(
            self.params, decode_jobs, prefill_jobs,
            layer_cb_async if worker is not None else layer_cb_sync)
        if worker is not None:
            # iteration fence: every merged write-back has landed before
            # the epilogues sample logits or release DRAM pools
            worker.drain()
        entry["launches"] = sum(f.calls
                                for f in involved.values()) - calls0

        # decode epilogue (mirrors _decode_batch_staged's tail)
        for (plane, logits, _info, _prev), sts in zip(res.decode,
                                                      decode_sts):
            self.decode_step_calls += 1
            self.decode_tokens += len(sts)
            if drop:
                self._drop_pending_evictions(plane, sts,
                                             pending_evict[id(plane)])
            for st in sts:
                row = plane.rows[st.req.req_id]
                st.last_logits = logits[row:row + 1]
                st.out_tokens.append(self._sample(st))
                if sel_pairs[st.req.req_id]:
                    self.scheduler.observe_selection(
                        st.req, sel_pairs[st.req.req_id])

        # prefill epilogue (mirrors _prefill_plane_iteration's tail)
        for plane, pres in res.prefill:
            entry["groups"] += len(pres.groups)
            entry["finalize"] += 1 if pres.finished else 0
            _, allow = by_plane[id(plane)]
            for rid in allow:
                st_r = self.states[rid]
                st_r.prefill_carry = max(
                    0, st_r.prefill_carry - spent.get(rid, 0))
                req = st_r.req
                if not plane.done(rid):
                    seg = plane.segments[rid][plane.next_idx[rid]]
                    req.prefill_layer = seg.layer
                    req.prefill_layer_tokens_done = min(
                        seg.chunk_start, max(req.prompt_len - 1, 0))
            for rid, peak in pres.peaks.items():
                fp += hbm_footprint_tokens(
                    plane.tok_len[rid], "layer_segmented", L,
                    layer_tokens_resident=peak)
            for rid in pres.finished:
                st_r = self.states[rid]
                row = plane.rows[rid]
                st_r.last_logits = pres.logits[row:row + 1]
                caches = st_r.decode_state["caches"]
                for l in range(L):
                    if caches[l] is None and M.layer_kind(self.cfg,
                                                          l) != "attn":
                        caches[l] = plane.rec_state(rid, l)
                st_r.decode_state["cur_len"] = jnp.full(
                    (1,), plane.tok_len[rid], jnp.int32)
                st_r.req.prefill_layer = L
                st_r.req.prefill_layer_tokens_done = 0
                plane.release(rid)
                self._req_prefill_plane.pop(rid, None)
                done.append(st_r.req)
        for plane in self.prefill_planes.values():
            if id(plane) in by_plane:
                continue
            for rid, resident in plane.resident_tokens().items():
                fp += hbm_footprint_tokens(
                    plane.tok_len[rid], "layer_segmented", L,
                    layer_tokens_resident=resident)
        self.mixed_iter_log.append(entry)
        return loads_total[0], done, fp, prefill_by_layer

    # ------------------------------------------------------------------
    # Decode execution
    # ------------------------------------------------------------------
    def _sample(self, st: _ReqState) -> int:
        logits = np.asarray(st.last_logits, np.float32)[0]
        if self.eng.greedy:
            return int(np.argmax(logits))
        z = logits - logits.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self.rng.choice(len(p), p=p))

    def _account_selections(self, sts: List[_ReqState],
                            selected: Dict[int, Any],
                            plane: Optional[DevicePoolPlane] = None) -> int:
        """DSA selections -> LRU residency, fused FlashH2D loads, and the
        working-set estimator.

        `selected[l]` is (B, Hkv, K); batch row `b` belongs to ``sts[b]``
        unless `plane` is given, in which case rows follow the plane's slot
        assignment.  For each layer, every request's misses are loaded by
        ONE fused launch (`KVCacheManager.load_blocks_fused`) — h2d_calls
        scale per-layer-per-iteration, not per-request — and, on the
        persistent plane, the gathered payloads are scattered DIRECTLY into
        the requests' device slots (`DevicePoolPlane.restore_blocks`).
        With ``drop_evicted_device_blocks`` the blocks the LRU evicted this
        iteration are then zeroed on device.  Returns blocks loaded."""
        loads = 0
        sel_pairs: Dict[str, List[Tuple[int, int]]] = \
            {st.req.req_id: [] for st in sts}
        evicted: Dict[str, set] = {st.req.req_id: set() for st in sts}
        for l in sorted(selected):
            sel = np.asarray(selected[l])
            lidx = self._attn_layer_index(l)
            blocks_by_req: Dict[str, List[int]] = {}
            for b, st in enumerate(sts):
                row = b if plane is None else plane.rows[st.req.req_id]
                blocks = dsa_mod.selected_block_ids(sel[row])
                blocks_by_req[st.req.req_id] = blocks
                sel_pairs[st.req.req_id].extend((lidx, x) for x in blocks)
            missing_by_req, evicted_by_req = self.kv_mgr.access_layer(
                lidx, blocks_by_req,
                drain_evicted=self.eng.drop_evicted_device_blocks)
            for rid, ev in evicted_by_req.items():
                evicted[rid].update(ev)
            loads += sum(len(m) for m in missing_by_req.values())
            if missing_by_req:
                payloads = self.kv_mgr.load_blocks_fused(lidx, missing_by_req)
                if plane is not None and self.eng.decode_write_back:
                    # FlashH2D lands in the device slots, not a side buffer
                    # — ONE fused scatter per layer covering every request.
                    # Gated on write-back: only then is the host pool a
                    # superset of device KV (scattering stale host data
                    # over decode-appended tokens would corrupt the pool).
                    plane.restore_blocks_fused(
                        l, {rid: (missing_by_req[rid], k, v)
                            for rid, (k, v) in payloads.items()})
        if plane is not None and self.eng.drop_evicted_device_blocks:
            self._drop_pending_evictions(plane, sts, evicted)
        for st in sts:
            if sel_pairs[st.req.req_id]:
                self.scheduler.observe_selection(st.req,
                                                 sel_pairs[st.req.req_id])
        return loads

    def _drop_pending_evictions(self, plane: DevicePoolPlane,
                                sts: List[_ReqState],
                                pending: Dict[str, set],
                                protect: Optional[Tuple[int, Dict[str, List[int]]]] = None) -> None:
        """Physically zero LRU-evicted blocks on device, mutating `pending`
        ((layer, block) keys per request) in place.

        A key is skipped (kept pending) when it was re-loaded since eviction
        (LRU-resident again) — its device data is current — or when
        ``protect`` = (lidx, blocks_by_req) marks it as selected by the
        attention stage ABOUT to run (staged plane: the block was evicted by
        its own access but its device copy is valid and needed now; it is
        dropped at the next stage boundary if still non-resident)."""
        for st in sts:
            rid = st.req.req_id
            cache = self.kv_mgr.caches.get(rid)
            if cache is None:
                pending[rid].clear()
                continue
            keep: set = set()
            by_layer: Dict[int, List[int]] = {}
            for elidx, blk in pending[rid]:
                if cache.resident(elidx, blk):      # re-loaded since
                    continue
                if (protect is not None and elidx == protect[0]
                        and blk in protect[1].get(rid, ())):
                    keep.add((elidx, blk))
                    continue
                by_layer.setdefault(elidx, []).append(blk)
            for elidx, blks in by_layer.items():
                layer = self._lidx_to_layer.get(elidx)
                if layer is not None:
                    plane.drop_blocks(rid, layer, sorted(set(blks)))
            pending[rid] = keep

    # ------------------------------------------------------------------
    # Async host stage (stage_dispatch="async")
    # ------------------------------------------------------------------
    def _stage_worker(self) -> HostStageWorker:
        """The engine's host-stage worker, created lazily (and re-created
        after ``close()``, so a closed engine can still step)."""
        if self._worker is None or self._worker.closed:
            self._worker = HostStageWorker(name=f"host-stage-{id(self):x}",
                                           tracer=self.tracer)
        return self._worker

    def close(self) -> None:
        """Shut down the host-stage worker: drains outstanding write-back
        jobs (re-raising their errors) and joins the thread.  Idempotent;
        ``run()`` calls it on exit.  The worker's job/busy counters fold
        into engine-level totals so ``metrics_snapshot()`` and the overlap
        instruments keep working after shutdown."""
        if self._worker is not None:
            self._worker.close()
            self.worker_jobs_run += self._worker.jobs_run
            self.worker_busy_s += self._worker.busy_s
            self._worker = None

    def _stage_writeback_async(self, worker: HostStageWorker, lidx: int,
                               req_ids: List[str], prev: Dict[str, int],
                               kv_dev: Tuple) -> None:
        """DISPATCH layer ``lidx``'s FlashD2H write-back: the stripe
        conversion (the blocking np.asarray of the device gather) plus
        ``save_new_tokens_fused`` + pool flush run on the host-stage
        worker, off the dispatch thread.  Ordering contract: this is where
        the fused d2h *starts* (plane-contract sequences it like the sync
        save, before any drop); completion is closed by ``fence(lidx)``
        before any same-layer DRAM gather and ``drain()`` before
        sampling/release."""
        k_dev, v_dev = kv_dev

        def job() -> None:
            k = np.asarray(k_dev)
            v = None if v_dev is None else np.asarray(v_dev)
            self.kv_mgr.save_new_tokens_fused(lidx, {
                rid: (prev[rid], k[i][:, None, :],
                      None if v is None else v[i][:, None, :])
                for i, rid in enumerate(req_ids)})
            for rid in req_ids:
                pool = self.kv_mgr.pools.get(rid)
                if pool is not None:
                    pool.flush()
        worker.submit(lidx, job)

    def _stage_writeback_async_merged(self, worker: HostStageWorker,
                                      lidx: int, parts: List[Tuple],
                                      finishers: List[Tuple]) -> None:
        """Mixed-iteration variant of ``_stage_writeback_async``: ONE
        worker job per layer merges every decode plane's stripe
        (``parts``: (req_ids, prev, kv_dev)) with every prefill group's
        fresh-chunk KV (``finishers``: (chunk_start, finish) from
        ``read_group_kv_async``) into a single ``save_new_tokens_fused``
        call — the same one-fused-FlashD2H-per-layer shape as the sync
        path, just converted and staged off-thread."""

        def job() -> None:
            kv_merge: Dict[str, Tuple[int, Any, Any]] = {}
            for req_ids, prev, (k_dev, v_dev) in parts:
                k = np.asarray(k_dev)
                v = None if v_dev is None else np.asarray(v_dev)
                for i, rid in enumerate(req_ids):
                    kv_merge[rid] = (prev[rid], k[i][:, None, :],
                                     None if v is None
                                     else v[i][:, None, :])
            for chunk_start, finish in finishers:
                for rid, (k, v) in finish().items():
                    cur = kv_merge.get(rid)
                    if cur is None:
                        kv_merge[rid] = (chunk_start, k, v)
                    else:
                        # same-rid chunks of one layer are contiguous in
                        # plan order: extend the stripe along tokens
                        s0, k0, v0 = cur
                        kv_merge[rid] = (
                            s0, np.concatenate([k0, k], axis=1),
                            None if v is None
                            else np.concatenate([v0, v], axis=1))
            if kv_merge:
                self.kv_mgr.save_new_tokens_fused(lidx, kv_merge)
                for rid in kv_merge:
                    pool = self.kv_mgr.pools.get(rid)
                    if pool is not None:
                        pool.flush()
        worker.submit(lidx, job)

    def _decode_one(self, st: _ReqState) -> Tuple[int, int]:
        """Legacy sequential decode step (B=1): feed the last generated
        token, sample the next.  Returns (token, blocks_loaded)."""
        tok = st.out_tokens[-1]        # last generated token is the input
        logits, new_state, info = M.decode_step(
            self.params, self.cfg, jnp.asarray([tok], jnp.int32),
            st.decode_state, attn_impl=self.eng.attn_impl, return_info=True)
        self.decode_step_calls += 1
        self.decode_tokens += 1
        st.decode_state = new_state
        st.last_logits = logits
        nxt = self._sample(st)
        st.out_tokens.append(nxt)
        loads = self._account_selections([st], info["selected"])
        return nxt, loads

    def _decode_group_key(self, st: _ReqState) -> Tuple:
        """Requests batch together when their non-pool state agrees in
        every per-request shape except batch (e.g. whisper encoder length);
        pool block counts may differ (padded to the batch max)."""
        extra = st.decode_state.get("extra") or {}
        return tuple((tuple(leaf.shape[1:]), str(leaf.dtype))
                     for leaf in jax.tree.leaves(extra))

    def _decode_batch(self, sts: List[_ReqState]) -> int:
        """Legacy batched path (``decode_plane="stacked"``): ONE batched
        model forward, but per-request KV pools are re-stacked into a fresh
        padded paged pool and unstacked again EVERY iteration — an
        O(batch x pool) device copy per generated token.  Kept as the
        equivalence oracle for the persistent plane.  Returns blocks
        loaded."""
        toks = jnp.asarray([st.out_tokens[-1] for st in sts], jnp.int32)
        batched, layout = M.stack_decode_states(
            [st.decode_state for st in sts])
        self.stack_calls += 1                  # full-pool stack + unstack
        logits, new_state, info = M.decode_step(
            self.params, self.cfg, toks, batched,
            attn_impl=self.eng.attn_impl, return_info=True)
        self.decode_step_calls += 1
        self.decode_tokens += len(sts)
        for st, ns, row in zip(sts, M.unstack_decode_states(new_state, layout),
                               range(len(sts))):
            st.decode_state = ns
            st.last_logits = logits[row:row + 1]
            st.out_tokens.append(self._sample(st))
        return self._account_selections(sts, info["selected"])

    def _plane_for(self, key: Tuple, sts: List[_ReqState]) -> DevicePoolPlane:
        """Get (or create) the group's DevicePoolPlane and admit any of
        `sts` not yet resident — the only full-pool copy in a request's
        decode lifetime; the plane owns the state afterwards."""
        plane = self.planes.get(key)
        if plane is None:
            plane = self.planes[key] = DevicePoolPlane(
                self.cfg, self.eng.bucketing, attn_impl=self.eng.attn_impl,
                plane_mesh=self.plane_mesh)
            plane.tracer = self.tracer
        for st in sts:
            rid = st.req.req_id
            if rid not in plane.rows:
                plane.admit(rid, st.decode_state)
                st.decode_state = None           # the plane owns it now
                self._req_plane[rid] = plane
        return plane

    def _decode_batch_persistent(self, key: Tuple,
                                 sts: List[_ReqState]) -> int:
        """Fused plane: requests live in a persistent ``DevicePoolPlane`` —
        admitted once, stepped via ONE jitted bucketed forward per iteration
        with zero per-iteration stack/unstack copies, released when finished
        (slots reused by later admissions).  Newly generated KV is written
        back to the host pool (fused FlashD2H) and fused FlashH2D payloads
        land directly in device slots — but only AFTER the forward that
        selected them, which is why ``drop_evicted_device_blocks`` is not
        oracle-exact here (use the staged plane).  Returns blocks loaded."""
        plane = self._plane_for(key, sts)
        tok_by_req = {st.req.req_id: st.out_tokens[-1] for st in sts}
        logits, info, prev = plane.step(self.params, tok_by_req)
        self.decode_step_calls += 1
        self.decode_tokens += len(sts)
        if self.eng.decode_write_back:
            self._write_back_new_kv(plane, sts, prev)
        for st in sts:
            row = plane.rows[st.req.req_id]
            st.last_logits = logits[row:row + 1]
            st.out_tokens.append(self._sample(st))
        return self._account_selections(sts, info["selected"], plane=plane)

    def _write_back_new_kv(self, plane: DevicePoolPlane,
                           sts: List[_ReqState],
                           prev: Dict[str, int]) -> None:
        """FlashD2H decode save: this iteration's appended KV goes to the
        host pools with ONE fused d2h call per attention layer, keeping
        DRAM a byte-exact superset of device KV (the invariant that makes
        H2D restores safe to scatter straight into device slots)."""
        req_ids = [st.req.req_id for st in sts]
        payload = plane.new_token_kv(req_ids, prev)
        for l, (k, v) in payload.items():
            lidx = self._attn_layer_index(l)
            kv_by_req = {
                rid: (prev[rid], k[i][:, None, :],
                      None if v is None else v[i][:, None, :])
                for i, rid in enumerate(req_ids)}
            self.kv_mgr.save_new_tokens_fused(lidx, kv_by_req)
        for rid in req_ids:
            pool = self.kv_mgr.pools.get(rid)
            if pool is not None:
                pool.flush()

    def _decode_batch_staged(self, key: Tuple, sts: List[_ReqState]) -> int:
        """Tentpole hot path: the staged per-layer pipeline over the
        persistent device plane — select -> restore -> attend per attention
        layer (``DevicePoolPlane.step_staged``).

        Between a layer's DSA selection and its attention, the stage
        callback below (host side) does, in order:

        1. FlashD2H write-back of THIS layer's just-appended KV (one fused
           save + flush) so DRAM stays a byte-exact superset of device KV
           before any restore of the layer;
        2. LRU residency for the layer's selections
           (``KVCacheManager.access_layer``), ONE fused FlashH2D load of
           the misses, and a fused scatter of the payloads into the plane's
           slots — the restore lands BEFORE the attention that selected the
           blocks, which is what makes ``drop_evicted_device_blocks``
           oracle-exact on this plane;
        3. physical drop of this access round's LRU evictions, except
           blocks the imminent attention selected (deferred one stage).

        Returns blocks loaded; per-layer restore bytes are accumulated in
        ``_staged_layer_bytes`` for the max(compute, transfer) overlap
        charge."""
        plane = self._plane_for(key, sts)
        tok_by_req = {st.req.req_id: st.out_tokens[-1] for st in sts}
        req_ids = [st.req.req_id for st in sts]
        sel_pairs: Dict[str, List[Tuple[int, int]]] = \
            {rid: [] for rid in req_ids}
        pending_evict: Dict[str, set] = {rid: set() for rid in req_ids}
        drop = self.eng.drop_evicted_device_blocks
        per_block_bytes = self._offload_block_bytes
        loads_total = [0]

        worker = self._stage_worker() if self._stage_async else None

        def stage_cb_sync(layer: int, sel: np.ndarray,
                          prev: Dict[str, int]) -> None:
            lidx = self._attn_layer_index(layer)
            if self.eng.decode_write_back:
                # FlashD2H phase for THIS layer only (per-layer pipeline)
                k, v = plane.new_token_kv(req_ids, prev,
                                          layers=[layer])[layer]
                self.kv_mgr.save_new_tokens_fused(lidx, {
                    rid: (prev[rid], k[i][:, None, :],
                          None if v is None else v[i][:, None, :])
                    for i, rid in enumerate(req_ids)})
                for rid in req_ids:
                    pool = self.kv_mgr.pools.get(rid)
                    if pool is not None:
                        pool.flush()
            if sel is None:          # DSA off: nothing to stage or restore
                return
            blocks_by_req: Dict[str, List[int]] = {}
            for st in sts:
                rid = st.req.req_id
                blocks = dsa_mod.selected_block_ids(sel[plane.rows[rid]])
                blocks_by_req[rid] = blocks
                sel_pairs[rid].extend((lidx, x) for x in blocks)
            missing_by_req, evicted_by_req = self.kv_mgr.access_layer(
                lidx, blocks_by_req, drain_evicted=drop)
            for rid, ev in evicted_by_req.items():
                pending_evict[rid].update(ev)
            loads_total[0] += sum(len(m) for m in missing_by_req.values())
            if missing_by_req:
                self._staged_layer_bytes[layer] = (
                    self._staged_layer_bytes.get(layer, 0)
                    + sum(len(m) for m in missing_by_req.values())
                    * per_block_bytes)
                payloads = self.kv_mgr.load_blocks_fused(lidx,
                                                         missing_by_req)
                if self.eng.decode_write_back:
                    plane.restore_blocks_fused(
                        layer, {rid: (missing_by_req[rid], k, v)
                                for rid, (k, v) in payloads.items()},
                        before_use=True)
            if drop:
                self._drop_pending_evictions(plane, sts, pending_evict,
                                             protect=(lidx, blocks_by_req))
            if self.staged_probe is not None:
                self.staged_probe(self, plane, layer, sts, blocks_by_req)

        def stage_cb_async(layer: int, sel: np.ndarray,
                           prev: Dict[str, int]) -> None:
            # The DISPATCH WINDOW: between the driver's np.asarray(idx)
            # and the attend dispatch that follows, nothing here may block
            # on the device (plane-contract: no-sync-in-dispatch-window).
            # The transfer guard turns a stray device->host sync into an
            # error on accelerator backends (on CPU device buffers ARE
            # host memory, so it cannot trip — the analyzer rule and the
            # host_syncs counter pin the invariant there).
            if sel is not None:
                plane.host_syncs += 1     # the driver's idx sync, the ONE
                                          # per-layer block we allow
            lidx = self._attn_layer_index(layer)
            with jax.transfer_guard_device_to_host("disallow"):
                if self.eng.decode_write_back:
                    # FlashD2H: dispatch the stripe gather, stage it on
                    # the worker; the dispatch thread never converts it
                    kv_dev = plane.new_token_kv_async(
                        req_ids, prev, layers=[layer])[layer]
                    self._stage_writeback_async(worker, lidx, req_ids,
                                                dict(prev), kv_dev)
                if sel is None:      # DSA off: nothing to stage or restore
                    return
                blocks_by_req: Dict[str, List[int]] = {}
                for st in sts:
                    rid = st.req.req_id
                    blocks = dsa_mod.selected_block_ids(
                        sel[plane.rows[rid]])
                    blocks_by_req[rid] = blocks
                    sel_pairs[rid].extend((lidx, x) for x in blocks)
                # LRU bookkeeping stays on the dispatch thread (pure host
                # work; keeps access order byte-identical to sync mode)
                missing_by_req, evicted_by_req = self.kv_mgr.access_layer(
                    lidx, blocks_by_req, drain_evicted=drop)
                for rid, ev in evicted_by_req.items():
                    pending_evict[rid].update(ev)
                loads_total[0] += sum(len(m)
                                      for m in missing_by_req.values())
                if missing_by_req:
                    self._staged_layer_bytes[layer] = (
                        self._staged_layer_bytes.get(layer, 0)
                        + sum(len(m) for m in missing_by_req.values())
                        * per_block_bytes)
                    # restore-before-use fence: this layer's outstanding
                    # write-back must land in DRAM before we gather from
                    # it (a 1-block LRU can miss on the block the current
                    # token was just appended to)
                    worker.fence(lidx)
                    payloads = self.kv_mgr.load_blocks_fused(
                        lidx, missing_by_req)
                    if self.eng.decode_write_back:
                        plane.restore_blocks_fused(
                            layer, {rid: (missing_by_req[rid], k, v)
                                    for rid, (k, v) in payloads.items()},
                            before_use=True)
                if drop:
                    self._drop_pending_evictions(
                        plane, sts, pending_evict,
                        protect=(lidx, blocks_by_req))
            if self.staged_probe is not None:
                worker.fence(lidx)   # probes compare device vs host pools
                self.staged_probe(self, plane, layer, sts, blocks_by_req)

        logits, info, prev = plane.step_staged(
            self.params, tok_by_req,
            stage_cb_async if worker is not None else stage_cb_sync)
        if worker is not None:
            # iteration fence: every write-back has landed before sampling
            # reads logits and before finish/release can retire a DRAM pool
            worker.drain()
        self.decode_step_calls += 1
        self.decode_tokens += len(sts)
        if drop:
            # evictions deferred past their own attend stage: safe to zero
            # now that every layer's compute has run
            self._drop_pending_evictions(plane, sts, pending_evict)
        for st in sts:
            row = plane.rows[st.req.req_id]
            st.last_logits = logits[row:row + 1]
            st.out_tokens.append(self._sample(st))
            if sel_pairs[st.req.req_id]:
                self.scheduler.observe_selection(st.req,
                                                 sel_pairs[st.req.req_id])
        return loads_total[0]

    # ------------------------------------------------------------------
    # Iteration
    # ------------------------------------------------------------------
    def step(self) -> Optional[BatchPlan]:
        """Run ONE engine iteration (hybrid batch).  Returns the executed
        plan, or None when no work remains.

        Order within the iteration: admit arrivals -> schedule (Algorithm 1
        working-set admission) -> prefill segments (layer-segmented prefill
        FlashD2H-saves each layer's KV to DRAM and evicts it from HBM) ->
        batched decode -> sample -> finish/release -> charge time.  On the
        staged plane (default) the decode phase interleaves per attention
        layer: select -> FlashD2H write-back of that layer's new KV -> DSA
        selection accounting (LRU residency; misses load via ONE fused
        FlashH2D, landing in the device plane's slots BEFORE the layer's
        attention) -> attend.  The fused planes run one forward and do
        write-back + selection accounting afterwards.

        Time is charged from the analytic cost model in engine-clock
        seconds (``charge_real_time=True`` uses wall clock); transfer stats
        are in bytes/calls/blocks with each moved block counted exactly
        once (see ``KVCacheManager``)."""
        self._admit_arrivals()
        plan = self.scheduler.schedule()
        if not plan.decode_reqs and not plan.prefill_reqs:
            if self._pending:      # idle until the next arrival
                self.now = max(self.now, self._pending[0].arrival_time)
                return self.step()
            return None
        t0 = time.perf_counter()
        iter_loads = 0
        self._staged_layer_bytes = {}
        mixed = self.hybrid is not None

        # --- prefill segments ------------------------------------------
        t_prefill = 0.0
        prefill_by_layer: Optional[List[float]] = None
        prefill_done: List[Request] = []
        iter_prefill_fp = 0          # HBM watermark, token-layer units,
                                     # summed over the iteration's batch
        scheduled_prefill = {req.req_id for req, _ in plan.prefill_reqs}
        if mixed:
            # ONE mixed iteration carries BOTH phases: decode groups and
            # prefill planes share one layer walk and one per-layer host
            # stage (hybrid_plane.HybridPlane); decode sampling and the
            # prefill epilogue already ran inside
            iter_loads, prefill_done, iter_prefill_fp, prefill_by_layer = \
                self._mixed_iteration(plan)
        elif (self.eng.prefill_mode == "layer_segmented"
                and self.eng.prefill_exec == "plane"):
            # with no scheduled prefill this still books the watermark of
            # rows parked mid-layer in the planes
            t_prefill, prefill_done, iter_prefill_fp = \
                self._prefill_plane_iteration(plan.prefill_reqs)
        else:
            for req, inject in plan.prefill_reqs:
                st = self.states[req.req_id]
                if req.scheduled_time is None:
                    req.scheduled_time = self.now
                if self.eng.prefill_mode == "layer_segmented":
                    if st.lp is None:
                        # whole-layer segments; inject (token-layers)
                        # decides how many run per iteration
                        self._start_layer_segmented(st, req.prompt_len)
                    # advance the scheduler cursor by `inject` token-layers
                    # (cursor = source of truth; >=1 whole layer/iteration)
                    req.prefill_layer_tokens_done += max(inject,
                                                         req.prompt_len)
                    while (req.prefill_layer_tokens_done >= req.prompt_len
                           and req.prefill_layer < self.cfg.num_layers):
                        req.prefill_layer += 1
                        req.prefill_layer_tokens_done -= req.prompt_len
                    # run segments to catch the cursor up
                    done = False
                    ran = False
                    while (st.lp is not None and not done
                           and st.lp.next_idx < req.prefill_layer):
                        done = self._run_layer_segment(st)
                        ran = True
                        t_prefill += cm.batched_prefill_time(
                            self.hw, self.mc,
                            [(req.prompt_len, req.prompt_len)], layers=1)
                    if ran:
                        # the whole layer's KV is live while segments run
                        iter_prefill_fp += hbm_footprint_tokens(
                            req.prompt_len, "layer_segmented",
                            self.cfg.num_layers)
                else:
                    done = self._run_chunked_prefill(st, inject)
                    ctx = req.prefill_tokens_done
                    t_prefill += cm.prefill_time(self.hw, self.mc, inject,
                                                 ctx)
                    iter_prefill_fp += hbm_footprint_tokens(
                        req.prompt_len, "chunked", self.cfg.num_layers,
                        req.prefill_tokens_done)
                if done:
                    prefill_done.append(req)
        # chunked prefill keeps every processed token's KV (all layers)
        # resident BETWEEN iterations too — count unscheduled holders
        for st in self.states.values():
            if (st.chunk_ctx is not None
                    and st.req.req_id not in scheduled_prefill):
                iter_prefill_fp += hbm_footprint_tokens(
                    st.req.prompt_len, "chunked", self.cfg.num_layers,
                    st.req.prefill_tokens_done)
        self.prefill_hbm_peak_tokens = max(self.prefill_hbm_peak_tokens,
                                           iter_prefill_fp)
        for req in prefill_done:
            st = self.states[req.req_id]
            req.phase = Phase.DECODE
            req.prefill_tokens_done = req.prompt_len
            st.out_tokens.append(self._sample(st))       # the first token
            req.generated = 1
            req.first_token_time = self.now   # charged below
            req.token_times.append(self.now)

        # --- decode steps ----------------------------------------------
        if mixed:
            pass       # decode rode the mixed iteration above
        elif self.eng.batched_decode:
            # ONE scheduler-planned batched forward over all running decode
            # requests (grouped only when per-request extra shapes differ,
            # e.g. whisper encoder lengths)
            groups: Dict[Tuple, List[_ReqState]] = {}
            for req in plan.decode_reqs:
                st = self.states[req.req_id]
                if st.group_key is None:
                    st.group_key = self._decode_group_key(st)
                groups.setdefault(st.group_key, []).append(st)
            for key, sts in groups.items():
                if self.eng.decode_plane == "staged":
                    iter_loads += self._decode_batch_staged(key, sts)
                elif self.eng.decode_plane == "persistent":
                    iter_loads += self._decode_batch_persistent(key, sts)
                else:
                    iter_loads += self._decode_batch(sts)
        else:
            for req in plan.decode_reqs:
                st = self.states[req.req_id]
                _, loads = self._decode_one(st)
                iter_loads += loads
        for req in plan.decode_reqs:
            req.generated += 1
            req.token_times.append(self.now)
            if req.generated >= req.max_new_tokens:
                req.finish_time = self.now
                self.scheduler.finish_request(req)
                self.kv_mgr.release(req.req_id)
                plane = self._req_plane.pop(req.req_id, None)
                if plane is not None:
                    plane.release(req.req_id)   # device slots reusable

        # --- charge time -------------------------------------------------
        if self.eng.charge_real_time:
            t_iter = time.perf_counter() - t0
        else:
            attended = min(self.cfg.dsa.token_budget, 1 << 30) \
                if self.cfg.dsa.enabled else 4096
            if mixed:
                # one shared walk: per layer, the union of decode+prefill
                # compute overlaps the ONE fused transfer stage
                n_shards = (self.plane_mesh.model_size
                            if self.plane_mesh is not None else 1)
                ag_bytes = None
                if n_shards > 1 and plan.decode_reqs:
                    sel_bytes = (len(plan.decode_reqs)
                                 * self.geom.num_kv_heads
                                 * self.cfg.dsa.top_k_blocks * 4)
                    ag_bytes = [
                        sel_bytes if M.layer_kind(self.cfg, l) == "attn"
                        else 0 for l in range(self.cfg.num_layers)]
                t_iter = cm.mixed_iteration_time(
                    self.hw, self.mc, len(plan.decode_reqs), attended,
                    [self._staged_layer_bytes.get(l, 0)
                     for l in range(self.cfg.num_layers)],
                    prefill_time_by_layer=prefill_by_layer,
                    n_shards=n_shards, allgather_bytes_by_layer=ag_bytes)
            elif (plan.decode_reqs and self.eng.batched_decode
                    and self.eng.decode_plane == "staged"):
                # staged pipeline: per layer, H2D restores overlap compute
                # -> charge max(compute, transfer) per layer, not the sum.
                # Sharded plane: each shard restores only its own slots
                # (per-shard transfer), plus one all-gather of the selected
                # block ids per attention layer (the host needs GLOBAL ids
                # for the LRU and the FlashH2D staging).
                n_shards = (self.plane_mesh.model_size
                            if self.plane_mesh is not None else 1)
                ag_bytes = None
                if n_shards > 1:
                    sel_bytes = (len(plan.decode_reqs)
                                 * self.geom.num_kv_heads
                                 * self.cfg.dsa.top_k_blocks * 4)
                    ag_bytes = [
                        sel_bytes if M.layer_kind(self.cfg, l) == "attn"
                        else 0 for l in range(self.cfg.num_layers)]
                t_dec = cm.overlapped_decode_time(
                    self.hw, self.mc, max(len(plan.decode_reqs), 1),
                    attended,
                    [self._staged_layer_bytes.get(l, 0)
                     for l in range(self.cfg.num_layers)],
                    n_shards=n_shards, allgather_bytes_by_layer=ag_bytes)
                t_iter = t_dec + t_prefill
            else:
                t_dec = cm.decode_time(
                    self.hw, self.mc, max(len(plan.decode_reqs), 1),
                    attended) if plan.decode_reqs else 0.0
                t_load = cm.fused_transfer_time(
                    self.hw,
                    iter_loads * self._offload_block_bytes) \
                    if iter_loads else 0.0
                t_iter = t_dec + t_load + t_prefill
        self.now += max(t_iter, 1e-9)
        # stamp the times that were logically produced "at end of iteration"
        for req in plan.decode_reqs + [r for r, _ in plan.prefill_reqs]:
            if req.token_times and req.token_times[-1] != self.now:
                req.token_times[-1] = self.now
            if req.first_token_time is not None and req.generated == 1:
                req.first_token_time = self.now
            if req.finish_time is not None and req.phase == Phase.FINISHED:
                req.finish_time = self.now
        self.loads_per_iter.append(iter_loads)
        self.iterations += 1
        # obs epilogue: outside the dispatch windows by construction (the
        # planes and the worker have all returned / been drained)
        wall_s = time.perf_counter() - t0
        self._h_iter.observe(wall_s)
        waiting, running = self.scheduler.queue_depths()
        self._g_queue.set(waiting)
        self._g_running.set(running)
        self._g_batch_decode.set(len(plan.decode_reqs))
        self._g_batch_prefill.set(len(plan.prefill_reqs))
        self._g_ws_decode.set(plan.ws_decode_bytes)
        self._g_ws_prefill.set(plan.ws_prefill_bytes)
        self._g_hbm_used.set(self.kv_mgr.hbm_used_bytes())
        if self.tracer.enabled:
            self.tracer.complete_at(
                "iteration", "engine", t0, wall_s, i=self.iterations - 1,
                decode_rows=len(plan.decode_reqs),
                prefill_rows=len(plan.prefill_reqs))
        return plan

    def run(self, max_iters: int = 10_000) -> ServingMetrics:
        """Step until idle (every submitted request finished) or
        ``max_iters`` iterations, then return aggregate metrics (TTFT/TBT
        in engine-clock seconds, token throughput in tokens/s)."""
        try:
            for _ in range(max_iters):
                if self.step() is None:
                    break
        finally:
            self.close()        # joins the host-stage worker; errors from
                                # outstanding write-back jobs surface here
        return compute_metrics([st.req for st in self.states.values()],
                               max(self.now, 1e-9))

    # ------------------------------------------------------------------
    def transfer_stats(self) -> TransferStats:
        return self.kv_mgr.total_stats()

    # ------------------------------------------------------------------
    # Observability surface (src/repro/obs, docs/architecture.md §11)
    # ------------------------------------------------------------------
    def metrics_snapshot(self) -> Dict[str, float]:
        """One flat dict over every subsystem's counters — the single obs
        surface (naming scheme: obs/metrics.py).  Registry instruments
        (sched.* gauges, engine.iteration_s histogram) merge with derived
        reads of the hot counters, which stay where the hot paths already
        increment them.  Works with obs disabled; blocking-free but not
        for dispatch windows (the analyzer flags it there)."""
        self._g_hbm_used.set(self.kv_mgr.hbm_used_bytes())
        snap = self.metrics.snapshot()
        ts = self.kv_mgr.total_stats()
        snap.update({
            "kv.h2d_calls": float(ts.h2d_calls),
            "kv.h2d_blocks": float(ts.h2d_blocks),
            "kv.h2d_bytes": float(ts.h2d_bytes),
            "kv.d2h_calls": float(ts.d2h_calls),
            "kv.d2h_blocks": float(ts.d2h_blocks),
            "kv.d2h_bytes": float(ts.d2h_bytes),
            "kv.hits": float(ts.hits),
            "kv.misses": float(ts.misses),
            "kv.evictions": float(ts.evictions),
            "kv.hbm_budget_bytes": float(self.eng.hbm_budget_bytes),
            # wire bytes one (layer, block) transfer moves at the offload
            # tier's stored size (int8 + scales when offload_quant="int8")
            "kv.offload_block_bytes": float(self._offload_block_bytes),
            "engine.iterations": float(self.iterations),
            "engine.now_s": float(self.now),
            "engine.decode_step_calls": float(self.decode_step_calls),
            "engine.decode_tokens": float(self.decode_tokens),
            "engine.stack_calls": float(self.stack_calls),
            "engine.prefill_launches": float(self.prefill_launches),
            "engine.admit_embed_launches": float(self.admit_embed_launches),
            "engine.prefill_hbm_peak_tokens":
                float(self.prefill_hbm_peak_tokens),
        })
        host_syncs = d2h_rb = dropped = restored = before = steps = 0
        sync_s = stage_s = 0.0
        fns_seen: Dict[int, Any] = {}      # StageFns are shared per-config;
        for plane in self.planes.values():  # dedup before summing traces
            host_syncs += plane.host_syncs
            d2h_rb += plane.d2h_readback_bytes
            dropped += plane.blocks_dropped
            restored += plane.blocks_restored
            before += plane.blocks_restored_before_use
            steps += plane.steps
            sync_s += plane.dispatch_sync_s
            stage_s += plane.host_stage_s
            fns_seen[id(plane.staged_fns)] = plane.staged_fns
        for pplane in self.prefill_planes.values():
            fns_seen[id(pplane.fns)] = pplane.fns
        if self.hybrid is not None:
            sync_s += self.hybrid.dispatch_sync_s
            stage_s += self.hybrid.host_stage_s
        snap.update({
            "plane.count": float(len(self.planes)),
            "plane.steps": float(steps),
            "plane.host_syncs": float(host_syncs),
            "plane.d2h_readback_bytes": float(d2h_rb),
            "plane.blocks_dropped": float(dropped),
            "plane.blocks_restored": float(restored),
            "plane.blocks_restored_before_use": float(before),
            "plane.trace_count": float(sum(f.trace_count
                                           for f in fns_seen.values())),
            "plane.dispatch_sync_s": sync_s,
            "plane.host_stage_s": stage_s,
        })
        w = self._worker
        live = w is not None and not w.closed
        snap.update({
            "worker.jobs_run": float(self.worker_jobs_run
                                     + (w.jobs_run if live else 0)),
            "worker.busy_s": (self.worker_busy_s
                              + (w.busy_s if live else 0.0)),
            "obs.enabled": 1.0 if self.tracer.enabled else 0.0,
            "obs.trace_events": float(len(self.tracer.events())),
        })
        return snap

    def metrics_prometheus(self) -> str:
        """Prometheus text exposition of :meth:`metrics_snapshot`."""
        snap = self.metrics_snapshot()
        reg_keys = set(self.metrics.snapshot())
        extra = {k: v for k, v in snap.items() if k not in reg_keys}
        return self.metrics.prometheus_text(extra)

    def stage_overlap_measured(self) -> Optional[float]:
        """Counter instrument for achieved async overlap: the fraction of
        host-stage work that ran on the HostStageWorker thread,
        ``busy_s / (busy_s + dispatch host_stage_s)``.  ``None`` when no
        worker job ran (sync mode, or no staged decode).  Cross-checked
        against the trace instrument (:meth:`stage_overlap_from_trace`)
        by bench_overlap and the nightly assert."""
        w = self._worker
        busy = self.worker_busy_s + (w.busy_s if w is not None
                                     and not w.closed else 0.0)
        if busy <= 0.0:
            return None
        stage_s = sum(p.host_stage_s for p in self.planes.values())
        if self.hybrid is not None:
            stage_s += self.hybrid.host_stage_s
        return busy / (busy + stage_s)

    def stage_overlap_from_trace(self) -> Optional[float]:
        """Trace instrument: span-interval overlap of worker-thread
        host-stage spans with dispatch-thread iteration spans (see
        obs/trace_analysis.py).  ``None`` with obs disabled or no worker
        spans."""
        from repro.obs.trace_analysis import achieved_overlap_fraction
        if not self.tracer.enabled:
            return None
        return achieved_overlap_fraction(self.tracer.events())

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (empty when obs is off)."""
        return self.tracer.chrome_trace()

    def dump_trace(self, path: str) -> int:
        """Write the Chrome trace JSON; returns the event count.
        Blocking file I/O — only call between/after iterations (the
        analyzer flags it inside async dispatch windows)."""
        return self.tracer.dump_trace(path)
