"""Request lifecycle (vLLM-style) with SparseServe prefill progress state."""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import List, Optional

_id_counter = itertools.count()


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    prompt_len: int
    max_new_tokens: int
    arrival_time: float = 0.0
    req_id: str = dataclasses.field(
        default_factory=lambda: f"req{next(_id_counter)}")
    phase: Phase = Phase.WAITING

    # --- prefill progress ---------------------------------------------------
    # chunked prefill: tokens processed so far
    prefill_tokens_done: int = 0
    # layer-segmented prefill: (layer, token-chunk-within-layer) cursor
    prefill_layer: int = 0
    prefill_layer_tokens_done: int = 0

    # --- decode progress ------------------------------------------------
    generated: int = 0

    # --- metrics ---------------------------------------------------------
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    scheduled_time: Optional[float] = None

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.generated

    def prefill_done(self, num_layers: int, mode: str) -> bool:
        if mode == "layer_segmented":
            return self.prefill_layer >= num_layers
        return self.prefill_tokens_done >= self.prompt_len

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tbts(self) -> List[float]:
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]
