"""Analytic cost model for iteration latencies and transfer times.

The paper's wall-clock figures come from an A100-40GB + PCIe Gen4 testbed;
this container is CPU-only, so the discrete-event simulator replays the
paper's experiments against this calibrated model instead.  Default
constants are the A100 testbed (to reproduce the paper's numbers); a TPU
v5e preset is provided for the deployment target.

Transfer model (paper Fig. 4): per-copy fixed overhead dominates small
fragmented block copies —

    t(copy of b bytes) = overhead + b / peak_bw
    memcpy path:   one copy PER BLOCK (per head)   -> effective bw collapses
    FlashH2D/D2H:  ONE fused launch for all blocks -> near-peak bw

With 16 KB blocks and ~8 us per-call overhead the memcpy path yields
~2-4 GB/s and the fused path >20 GB/s, matching Fig. 4.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float              # FLOP/s (bf16/fp16 dense)
    hbm_bw: float                  # bytes/s
    hbm_capacity: float            # bytes
    host_link_bw: float            # bytes/s (PCIe / host DMA)
    host_capacity: float           # bytes (DRAM)
    per_copy_overhead: float       # seconds per individual memcpy call
    kernel_launch_overhead: float  # seconds per fused-kernel launch
    mfu: float = 0.45              # achievable fraction of peak flops
    mbu: float = 0.70              # achievable fraction of hbm bw
    link_eff_fused: float = 0.75   # fused transfers reach this of link peak
    ici_bw: float = 90e9           # bytes/s inter-chip interconnect (per
                                   # link: NVLink / TPU ICI) — collective
                                   # charging for the sharded planes
    collective_overhead: float = 5e-6  # seconds per collective launch


A100_40G = HardwareSpec(
    name="a100-40g", peak_flops=312e12, hbm_bw=1.555e12,
    hbm_capacity=40e9, host_link_bw=32e9, host_capacity=256e9,
    per_copy_overhead=8e-6, kernel_launch_overhead=12e-6)

TPU_V5E = HardwareSpec(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
    hbm_capacity=16e9, host_link_bw=32e9, host_capacity=192e9,
    per_copy_overhead=6e-6, kernel_launch_overhead=10e-6)


# ---------------------------------------------------------------------------
# Transfer times (Fig. 4 / §3.2)
# ---------------------------------------------------------------------------

def memcpy_transfer_time(hw: HardwareSpec, n_copies: int,
                         bytes_per_copy: int) -> float:
    """Per-block cudaMemcpy path: overhead paid per fragment."""
    return n_copies * (hw.per_copy_overhead
                       + bytes_per_copy / hw.host_link_bw)


def fused_transfer_time(hw: HardwareSpec, total_bytes: int) -> float:
    """FlashH2D / FlashD2H: one launch, streaming at link_eff_fused."""
    return (hw.kernel_launch_overhead
            + total_bytes / (hw.host_link_bw * hw.link_eff_fused))


QUANT_SCALE_BYTES = 4  # f32 scale per (kv-head, block) per tensor (int8 tier)


def offload_block_bytes(n_kv_heads: int, head_dim: int, block_size: int,
                        kv_factor: int = 2, dtype_bytes: int = 2,
                        quant: str = "none") -> int:
    """Wire bytes of ONE KV block (one layer, all kv heads, K+V) as stored
    in the DRAM offload tier — what one FlashH2D/FlashD2H block transfer
    actually moves.

    ``quant="none"``: elements x ``dtype_bytes``.  ``quant="int8"``: 1 B
    per element + ``QUANT_SCALE_BYTES`` per (kv-head, block) per tensor —
    a ~``dtype_bytes``x shrink for realistic block sizes.  The engine
    charges the overlap model's per-layer transfer bytes with this, so the
    modeled transfer time reflects the tier."""
    elems_per_head = block_size * head_dim
    if quant == "int8":
        per_head = elems_per_head + QUANT_SCALE_BYTES
    elif quant == "none":
        per_head = elems_per_head * dtype_bytes
    else:
        raise ValueError(f"offload_block_bytes: unknown quant {quant!r}")
    return n_kv_heads * per_head * kv_factor


def offload_bytes_per_token(n_kv_heads: int, head_dim: int, block_size: int,
                            kv_factor: int = 2, dtype_bytes: int = 2,
                            quant: str = "none") -> float:
    """Per-token amortized wire bytes of the offload tier (one layer, all
    kv heads, K+V): ``offload_block_bytes / block_size``.  The scale
    overhead amortizes across the block's tokens, so int8 approaches
    exactly half the bf16 size as ``block_size`` grows."""
    return offload_block_bytes(n_kv_heads, head_dim, block_size,
                               kv_factor=kv_factor, dtype_bytes=dtype_bytes,
                               quant=quant) / block_size


def allgather_time(hw: HardwareSpec, total_bytes: int,
                   n_shards: int) -> float:
    """Ring all-gather of `total_bytes` (the FULL gathered size) across
    `n_shards`: each shard sends/receives (n-1)/n of the result over the
    interconnect.  The sharded planes move only small tensors this way —
    selected block ids, block scores, one window of fresh prefill K/V —
    never a pool."""
    if n_shards <= 1 or total_bytes <= 0:
        return 0.0
    return (hw.collective_overhead
            + total_bytes * (n_shards - 1) / n_shards / hw.ici_bw)


def effective_bandwidth(hw: HardwareSpec, n_copies: int, bytes_per_copy: int,
                        fused: bool) -> float:
    total = n_copies * bytes_per_copy
    t = (fused_transfer_time(hw, total) if fused
         else memcpy_transfer_time(hw, n_copies, bytes_per_copy))
    return total / t if t > 0 else 0.0


# ---------------------------------------------------------------------------
# Model compute / memory times
# ---------------------------------------------------------------------------

def layer_flops_per_token(d_model: int, d_ff: int, n_heads: int,
                          n_kv_heads: int, head_dim: int,
                          context: int, moe_top_k: int = 0,
                          moe_dense_residual: bool = False) -> float:
    """Forward FLOPs for one token through one layer (matmul 2x factor)."""
    qo = 2 * d_model * (n_heads * head_dim) * 2          # Wq + Wo
    kv = 2 * d_model * (n_kv_heads * head_dim) * 2       # Wk + Wv
    attn = 2 * 2 * n_heads * head_dim * context          # qk + pv
    ff_mult = (moe_top_k if moe_top_k else 1) + (1 if moe_dense_residual else 0)
    ffn = 3 * 2 * d_model * d_ff * ff_mult
    return qo + kv + attn + ffn


@dataclasses.dataclass(frozen=True)
class ModelCost:
    """Per-model constants the simulator needs (derived from ModelConfig)."""
    num_layers: int
    d_model: int
    d_ff: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    vocab: int
    param_bytes: float            # total weight bytes (bf16)
    active_param_bytes: float     # MoE: active path only
    kv_bytes_per_token: float     # all layers, all kv heads, k+v
    moe_top_k: int = 0
    moe_dense_residual: bool = False

    @classmethod
    def from_config(cls, cfg, dtype_bytes: int = 2) -> "ModelCost":
        kv_per_tok = (cfg.num_attention_layers() * max(cfg.num_kv_heads, 1)
                      * cfg.kv_cache_dim * dtype_bytes
                      * (1 if cfg.attention_type == "mla" else 2))
        return cls(
            num_layers=cfg.num_layers, d_model=cfg.d_model, d_ff=cfg.d_ff,
            n_heads=max(cfg.num_heads, 1),
            n_kv_heads=max(cfg.num_kv_heads, 1),
            head_dim=max(cfg.head_dim, 1), vocab=cfg.vocab_size,
            param_bytes=cfg.param_count() * dtype_bytes,
            active_param_bytes=cfg.active_param_count() * dtype_bytes,
            kv_bytes_per_token=kv_per_tok,
            moe_top_k=cfg.top_k_experts,
            moe_dense_residual=cfg.moe_dense_residual)


def prefill_time(hw: HardwareSpec, mc: ModelCost, new_tokens: int,
                 context: int, layers: int = -1) -> float:
    """Compute-bound prefill of `new_tokens` attending to `context` total."""
    L = mc.num_layers if layers < 0 else layers
    per_tok = layer_flops_per_token(
        mc.d_model, mc.d_ff, mc.n_heads, mc.n_kv_heads, mc.head_dim,
        context, mc.moe_top_k, mc.moe_dense_residual)
    flops = new_tokens * per_tok * L
    return flops / (hw.peak_flops * hw.mfu)


def batched_prefill_time(hw: HardwareSpec, mc: ModelCost,
                         segs, layers: int = 1, n_shards: int = 1,
                         allgather_bytes: int = 0) -> float:
    """ONE batched prefill-plane launch (layer-segmented prefill §3.4).

    segs: [(new_tokens, context)] — one entry per request row in the
    launch.  The plane batches every same-layer segment of the prefill
    batch into a single jitted launch, so the kernel launch overhead is
    paid ONCE per (layer, chunk) group instead of once per request segment;
    compute is charged on each row's REAL tokens (padding is bucketed and
    masked, not charged).  The legacy per-request executor is charged with
    the same formula at batch 1, so the modeled plane-vs-legacy difference
    is exactly the launch amortization.

    n_shards > 1: the launch runs sequence-sharded across the plane mesh's
    model axis — but ONLY the O(tokens x context) attention term splits
    over the shards (projections and the FFN/MoE epilogue run replicated
    by design, for bitwise exactness; see
    ``model._prefill_attn_layer_batched_cp``), and the sharded attention
    outputs are re-gathered once per launch (`allgather_bytes`, the full
    gathered size)."""
    n = max(n_shards, 1)
    t = hw.kernel_launch_overhead
    for new_tokens, context in segs:
        t_full = prefill_time(hw, mc, new_tokens, context, layers=layers)
        if n > 1:
            # context-independent terms (projections, FFN/MoE) stay
            # replicated; the attention term (t_full - t_ctx0) shards
            t_ctx0 = prefill_time(hw, mc, new_tokens, 0, layers=layers)
            t += t_ctx0 + (t_full - t_ctx0) / n
        else:
            t += t_full
    return t + allgather_time(hw, allgather_bytes, n_shards)


def overlapped_decode_time(hw: HardwareSpec, mc: ModelCost, batch: int,
                           attended_tokens_per_req: float,
                           transfer_bytes_by_layer, n_shards: int = 1,
                           allgather_bytes_by_layer=None) -> float:
    """Staged-pipeline decode charge (§3.2's H2D/compute overlap).

    The fused plane charges decode compute + ALL restore transfer serially
    (one forward, transfers can only land after it).  The staged plane
    restores layer l's missing blocks while adjacent layers compute, so
    each layer is charged max(layer compute, layer transfer) instead of the
    sum — the paper's pipelining bound.

    transfer_bytes_by_layer: H2D restore payload bytes per MODEL layer this
    iteration (0 for layers with no misses or no paged KV); entries beyond
    ``mc.num_layers`` are ignored, missing entries charge compute only.

    n_shards > 1 (sharded plane): each shard scatters only the restore
    payloads that land in ITS pool slots, so per-layer transfer divides by
    the shard count; ``allgather_bytes_by_layer`` adds the per-layer
    collective (selected block ids crossing the model axis so the host can
    stage GLOBAL ids), charged serially — the host sync sits between
    select and attend and cannot overlap the layer's own restore."""
    t_layer = decode_time(hw, mc, batch, attended_tokens_per_req) \
        / max(mc.num_layers, 1)
    n = max(n_shards, 1)
    ag = list(allgather_bytes_by_layer or [])
    t = 0.0
    per_layer = list(transfer_bytes_by_layer)[:mc.num_layers]
    for i, b in enumerate(per_layer):
        t_tx = fused_transfer_time(hw, b / n) if b > 0 else 0.0
        t += max(t_layer, t_tx)
        if i < len(ag):
            t += allgather_time(hw, ag[i], n)
    t += t_layer * max(0, mc.num_layers - len(per_layer))
    return t


def mixed_iteration_time(hw: HardwareSpec, mc: ModelCost, batch: int,
                         attended_tokens_per_req: float,
                         transfer_bytes_by_layer,
                         prefill_time_by_layer=None, n_shards: int = 1,
                         allgather_bytes_by_layer=None) -> float:
    """ONE mixed iteration of the hybrid plane (decode rows AND prefill
    segments in the same layer walk, ``core.hybrid_plane``).

    Per model layer the walk runs decode select/attend AND the layer's
    prefill groups, while the single per-layer host stage moves the
    layer's fused FlashD2H/H2D payloads — so each layer is charged
    max(decode layer compute + prefill layer compute, layer transfer),
    the union of both planes' compute overlapping the shared transfer
    (same pipelining bound as ``overlapped_decode_time``, with the
    prefill launches joining the compute side of the max).

    prefill_time_by_layer: modeled seconds of this iteration's prefill
    launches per MODEL layer (``batched_prefill_time`` per group, already
    including sharded allgathers); None or missing entries charge decode
    only.  ``batch == 0`` (pure-prefill iteration) degenerates to the sum
    of the prefill layer times vs the transfers."""
    t_layer = (decode_time(hw, mc, batch, attended_tokens_per_req)
               / max(mc.num_layers, 1)) if batch > 0 else 0.0
    n = max(n_shards, 1)
    ag = list(allgather_bytes_by_layer or [])
    pf = list(prefill_time_by_layer or [])
    t = 0.0
    per_layer = list(transfer_bytes_by_layer)[:mc.num_layers]
    for i in range(mc.num_layers):
        b = per_layer[i] if i < len(per_layer) else 0
        t_tx = fused_transfer_time(hw, b / n) if b > 0 else 0.0
        t_cmp = t_layer + (pf[i] if i < len(pf) else 0.0)
        t += max(t_cmp, t_tx)
        if batch > 0 and i < len(ag):
            t += allgather_time(hw, ag[i], n)
    return t


def decode_time(hw: HardwareSpec, mc: ModelCost, batch: int,
                attended_tokens_per_req: float) -> float:
    """Memory-bound decode iteration: weights read once per iteration +
    attended KV read per request.  attended = full context (vLLM) or the
    DSA token budget (sparse)."""
    weight_bytes = mc.active_param_bytes
    kv_bytes = batch * attended_tokens_per_req * mc.kv_bytes_per_token
    flops = batch * layer_flops_per_token(
        mc.d_model, mc.d_ff, mc.n_heads, mc.n_kv_heads, mc.head_dim,
        attended_tokens_per_req, mc.moe_top_k,
        mc.moe_dense_residual) * mc.num_layers
    t_mem = (weight_bytes + kv_bytes) / (hw.hbm_bw * hw.mbu)
    t_cmp = flops / (hw.peak_flops * hw.mfu)
    return max(t_mem, t_cmp)
