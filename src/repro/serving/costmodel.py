"""Analytic cost model for iteration latencies and transfer times.

The paper's wall-clock figures come from an A100-40GB + PCIe Gen4 testbed;
this container is CPU-only, so the discrete-event simulator replays the
paper's experiments against this calibrated model instead.  Default
constants are the A100 testbed (to reproduce the paper's numbers); a TPU
v5e preset is provided for the deployment target.

Transfer model (paper Fig. 4): per-copy fixed overhead dominates small
fragmented block copies —

    t(copy of b bytes) = overhead + b / peak_bw
    memcpy path:   one copy PER BLOCK (per head)   -> effective bw collapses
    FlashH2D/D2H:  ONE fused launch for all blocks -> near-peak bw

With 16 KB blocks and ~8 us per-call overhead the memcpy path yields
~2-4 GB/s and the fused path >20 GB/s, matching Fig. 4.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float              # FLOP/s (bf16/fp16 dense)
    hbm_bw: float                  # bytes/s
    hbm_capacity: float            # bytes
    host_link_bw: float            # bytes/s (PCIe / host DMA)
    host_capacity: float           # bytes (DRAM)
    per_copy_overhead: float       # seconds per individual memcpy call
    kernel_launch_overhead: float  # seconds per fused-kernel launch
    mfu: float = 0.45              # achievable fraction of peak flops
    mbu: float = 0.70              # achievable fraction of hbm bw
    link_eff_fused: float = 0.75   # fused transfers reach this of link peak


A100_40G = HardwareSpec(
    name="a100-40g", peak_flops=312e12, hbm_bw=1.555e12,
    hbm_capacity=40e9, host_link_bw=32e9, host_capacity=256e9,
    per_copy_overhead=8e-6, kernel_launch_overhead=12e-6)

TPU_V5E = HardwareSpec(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
    hbm_capacity=16e9, host_link_bw=32e9, host_capacity=192e9,
    per_copy_overhead=6e-6, kernel_launch_overhead=10e-6)


# ---------------------------------------------------------------------------
# Transfer times (Fig. 4 / §3.2)
# ---------------------------------------------------------------------------

def memcpy_transfer_time(hw: HardwareSpec, n_copies: int,
                         bytes_per_copy: int) -> float:
    """Per-block cudaMemcpy path: overhead paid per fragment."""
    return n_copies * (hw.per_copy_overhead
                       + bytes_per_copy / hw.host_link_bw)


def fused_transfer_time(hw: HardwareSpec, total_bytes: int) -> float:
    """FlashH2D / FlashD2H: one launch, streaming at link_eff_fused."""
    return (hw.kernel_launch_overhead
            + total_bytes / (hw.host_link_bw * hw.link_eff_fused))


def effective_bandwidth(hw: HardwareSpec, n_copies: int, bytes_per_copy: int,
                        fused: bool) -> float:
    total = n_copies * bytes_per_copy
    t = (fused_transfer_time(hw, total) if fused
         else memcpy_transfer_time(hw, n_copies, bytes_per_copy))
    return total / t if t > 0 else 0.0


# ---------------------------------------------------------------------------
# Model compute / memory times
# ---------------------------------------------------------------------------

def layer_flops_per_token(d_model: int, d_ff: int, n_heads: int,
                          n_kv_heads: int, head_dim: int,
                          context: int, moe_top_k: int = 0,
                          moe_dense_residual: bool = False) -> float:
    """Forward FLOPs for one token through one layer (matmul 2x factor)."""
    qo = 2 * d_model * (n_heads * head_dim) * 2          # Wq + Wo
    kv = 2 * d_model * (n_kv_heads * head_dim) * 2       # Wk + Wv
    attn = 2 * 2 * n_heads * head_dim * context          # qk + pv
    ff_mult = (moe_top_k if moe_top_k else 1) + (1 if moe_dense_residual else 0)
    ffn = 3 * 2 * d_model * d_ff * ff_mult
    return qo + kv + attn + ffn


@dataclasses.dataclass(frozen=True)
class ModelCost:
    """Per-model constants the simulator needs (derived from ModelConfig)."""
    num_layers: int
    d_model: int
    d_ff: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    vocab: int
    param_bytes: float            # total weight bytes (bf16)
    active_param_bytes: float     # MoE: active path only
    kv_bytes_per_token: float     # all layers, all kv heads, k+v
    moe_top_k: int = 0
    moe_dense_residual: bool = False

    @classmethod
    def from_config(cls, cfg, dtype_bytes: int = 2) -> "ModelCost":
        kv_per_tok = (cfg.num_attention_layers() * max(cfg.num_kv_heads, 1)
                      * cfg.kv_cache_dim * dtype_bytes
                      * (1 if cfg.attention_type == "mla" else 2))
        return cls(
            num_layers=cfg.num_layers, d_model=cfg.d_model, d_ff=cfg.d_ff,
            n_heads=max(cfg.num_heads, 1),
            n_kv_heads=max(cfg.num_kv_heads, 1),
            head_dim=max(cfg.head_dim, 1), vocab=cfg.vocab_size,
            param_bytes=cfg.param_count() * dtype_bytes,
            active_param_bytes=cfg.active_param_count() * dtype_bytes,
            kv_bytes_per_token=kv_per_tok,
            moe_top_k=cfg.top_k_experts,
            moe_dense_residual=cfg.moe_dense_residual)


def prefill_time(hw: HardwareSpec, mc: ModelCost, new_tokens: int,
                 context: int, layers: int = -1) -> float:
    """Compute-bound prefill of `new_tokens` attending to `context` total."""
    L = mc.num_layers if layers < 0 else layers
    per_tok = layer_flops_per_token(
        mc.d_model, mc.d_ff, mc.n_heads, mc.n_kv_heads, mc.head_dim,
        context, mc.moe_top_k, mc.moe_dense_residual)
    flops = new_tokens * per_tok * L
    return flops / (hw.peak_flops * hw.mfu)


def batched_prefill_time(hw: HardwareSpec, mc: ModelCost,
                         segs, layers: int = 1) -> float:
    """ONE batched prefill-plane launch (layer-segmented prefill §3.4).

    segs: [(new_tokens, context)] — one entry per request row in the
    launch.  The plane batches every same-layer segment of the prefill
    batch into a single jitted launch, so the kernel launch overhead is
    paid ONCE per (layer, chunk) group instead of once per request segment;
    compute is charged on each row's REAL tokens (padding is bucketed and
    masked, not charged).  The legacy per-request executor is charged with
    the same formula at batch 1, so the modeled plane-vs-legacy difference
    is exactly the launch amortization."""
    t = hw.kernel_launch_overhead
    for new_tokens, context in segs:
        t += prefill_time(hw, mc, new_tokens, context, layers=layers)
    return t


def overlapped_decode_time(hw: HardwareSpec, mc: ModelCost, batch: int,
                           attended_tokens_per_req: float,
                           transfer_bytes_by_layer) -> float:
    """Staged-pipeline decode charge (§3.2's H2D/compute overlap).

    The fused plane charges decode compute + ALL restore transfer serially
    (one forward, transfers can only land after it).  The staged plane
    restores layer l's missing blocks while adjacent layers compute, so
    each layer is charged max(layer compute, layer transfer) instead of the
    sum — the paper's pipelining bound.

    transfer_bytes_by_layer: H2D restore payload bytes per MODEL layer this
    iteration (0 for layers with no misses or no paged KV); entries beyond
    ``mc.num_layers`` are ignored, missing entries charge compute only.
    """
    t_layer = decode_time(hw, mc, batch, attended_tokens_per_req) \
        / max(mc.num_layers, 1)
    t = 0.0
    per_layer = list(transfer_bytes_by_layer)[:mc.num_layers]
    for b in per_layer:
        t += max(t_layer, fused_transfer_time(hw, b) if b > 0 else 0.0)
    t += t_layer * max(0, mc.num_layers - len(per_layer))
    return t


def decode_time(hw: HardwareSpec, mc: ModelCost, batch: int,
                attended_tokens_per_req: float) -> float:
    """Memory-bound decode iteration: weights read once per iteration +
    attended KV read per request.  attended = full context (vLLM) or the
    DSA token budget (sparse)."""
    weight_bytes = mc.active_param_bytes
    kv_bytes = batch * attended_tokens_per_req * mc.kv_bytes_per_token
    flops = batch * layer_flops_per_token(
        mc.d_model, mc.d_ff, mc.n_heads, mc.n_kv_heads, mc.head_dim,
        attended_tokens_per_req, mc.moe_top_k,
        mc.moe_dense_residual) * mc.num_layers
    t_mem = (weight_bytes + kv_bytes) / (hw.hbm_bw * hw.mbu)
    t_cmp = flops / (hw.peak_flops * hw.mfu)
    return max(t_mem, t_cmp)
