"""Synthetic LongBench-like workload traces (paper §4.1).

The paper mixes QA / summarization / code tasks from LongBench into one
trace and draws arrival times from a Poisson process at a configurable
request rate.  No datasets ship offline, so we synthesize the same
statistical shape: per-task-type lognormal prompt/output length
distributions calibrated to LongBench's published statistics, mixed
uniformly, Poisson arrivals, prompt lengths capped like the paper
(32k for LWM-7B, 128k for Llama3-8B).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.serving.request import Request

# (name, median prompt tokens, sigma, median output tokens)
TASK_MIX = [
    ("qasper",       4000, 0.6,  96),
    ("narrativeqa", 18000, 0.5, 64),
    ("multifieldqa", 5000, 0.6, 96),
    ("dureader",    14000, 0.4, 128),
    ("govreport",    9000, 0.5, 384),
    ("qmsum",       11000, 0.4, 256),
    ("multinews",    2200, 0.6, 320),
    ("vcsum",       16000, 0.4, 256),
    ("lcc",          2500, 0.8, 64),
    ("repobench-p", 10000, 0.6, 64),
]


@dataclasses.dataclass
class TraceConfig:
    request_rate: float = 0.25        # req/s (Poisson)
    num_requests: int = 64
    max_prompt_len: int = 32768       # paper: 32k (LWM) / 128k (Llama3)
    max_new_tokens: int = 512
    seed: int = 0


def generate_trace(cfg: TraceConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    reqs: List[Request] = []
    t = 0.0
    for i in range(cfg.num_requests):
        t += rng.exponential(1.0 / cfg.request_rate)
        name, med_p, sig, med_o = TASK_MIX[rng.integers(len(TASK_MIX))]
        plen = int(np.clip(rng.lognormal(np.log(med_p), sig), 128,
                           cfg.max_prompt_len))
        olen = int(np.clip(rng.lognormal(np.log(med_o), 0.5), 8,
                           cfg.max_new_tokens))
        reqs.append(Request(prompt_len=plen, max_new_tokens=olen,
                            arrival_time=t))
    return reqs


def tiny_trace(num_requests: int = 4, prompt_len: int = 96,
               max_new_tokens: int = 8, rate: float = 100.0,
               seed: int = 0) -> List[Request]:
    """Small fixed-shape trace for the real-execution engine tests."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for _ in range(num_requests):
        t += rng.exponential(1.0 / rate)
        out.append(Request(prompt_len=prompt_len,
                           max_new_tokens=max_new_tokens, arrival_time=t))
    return out
