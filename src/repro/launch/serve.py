"""Serving launcher: drive the real engine with a synthetic LongBench trace.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 8 --prompt 192 --gen 8 [--prefill chunked] [--no-ws]

Prints TTFT/TBT/throughput and the hierarchical-KV transfer statistics
(FlashH2D/D2H calls, hit rates) — the numbers the paper's Figs. 10–16
track.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.models import model as M
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=192)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--rate", type=float, default=100.0)
    ap.add_argument("--prefill", default="layer_segmented",
                    choices=["layer_segmented", "chunked"])
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--no-ws", action="store_true")
    ap.add_argument("--cache-blocks", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    eng = ServingEngine(params, cfg, EngineConfig(
        prefill_mode=args.prefill, chunk_size=args.chunk,
        ws_control=not args.no_ws,
        hbm_blocks_per_request=args.cache_blocks, seed=args.seed))

    rng = np.random.default_rng(args.seed)
    t = 0.0
    for _ in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        req = Request(prompt_len=args.prompt, max_new_tokens=args.gen,
                      arrival_time=t)
        extra = {}
        if cfg.is_encoder_decoder:
            extra["frames"] = np.ones((1, 16, cfg.d_model), np.float32) * .01
        if cfg.frontend == "vit_patch_stub":
            extra["patch_embeds"] = np.ones(
                (1, cfg.num_patches, cfg.d_model), np.float32) * .01
        eng.submit(req, **extra)

    m = eng.run()
    ts = eng.transfer_stats()
    print(f"arch={cfg.name} prefill={args.prefill} ws={not args.no_ws}")
    print(f"finished={m.num_finished}/{args.requests} iters={eng.iterations}")
    print(f"mean TTFT {m.mean_ttft*1e3:.2f} ms | mean TBT "
          f"{m.mean_tbt*1e3:.3f} ms | {m.token_throughput:.1f} tok/s")
    print(f"FlashH2D: {ts.h2d_calls} fused launches, {ts.h2d_blocks} blocks, "
          f"{ts.h2d_bytes/1e6:.2f} MB")
    print(f"FlashD2H: {ts.d2h_calls} saves, {ts.d2h_blocks} blocks, "
          f"{ts.d2h_bytes/1e6:.2f} MB")
    tot = max(ts.hits + ts.misses, 1)
    print(f"HBM cache: {ts.hits} hits / {ts.misses} misses "
          f"({100*ts.hits/tot:.1f}% hit rate), {ts.evictions} evictions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
