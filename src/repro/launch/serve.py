"""Serving launcher: drive the real engine with a synthetic LongBench trace.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 8 --prompt 192 --gen 8 [--prefill chunked] [--no-ws] \
        [--obs] [--trace-out run.trace.json] [--prom]

Prints TTFT/TBT/throughput and the hierarchical-KV transfer statistics
(FlashH2D/D2H calls, hit rates) — the numbers the paper's Figs. 10–16
track — all read from ``engine.metrics_snapshot()``, the one obs
surface.  ``--trace-out`` writes the run's Chrome trace-event JSON
(open in https://ui.perfetto.dev); ``--prom`` dumps the Prometheus text
exposition.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.models import model as M
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=192)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--rate", type=float, default=100.0)
    ap.add_argument("--prefill", default="layer_segmented",
                    choices=["layer_segmented", "chunked"])
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--no-ws", action="store_true")
    ap.add_argument("--cache-blocks", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", action="store_true",
                    help="enable the tracing+metrics layer (EngineConfig"
                         ".obs; also via REPRO_OBS=1)")
    ap.add_argument("--trace-out", default="",
                    help="write Chrome trace-event JSON here (implies "
                         "--obs; open in ui.perfetto.dev)")
    ap.add_argument("--prom", action="store_true",
                    help="print the Prometheus text exposition of the "
                         "final metrics snapshot")
    args = ap.parse_args(argv)

    obs = args.obs or bool(args.trace_out) or None   # None -> REPRO_OBS env
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    eng = ServingEngine(params, cfg, EngineConfig(
        prefill_mode=args.prefill, chunk_size=args.chunk,
        ws_control=not args.no_ws,
        hbm_blocks_per_request=args.cache_blocks, seed=args.seed, obs=obs))

    rng = np.random.default_rng(args.seed)
    t = 0.0
    for _ in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        req = Request(prompt_len=args.prompt, max_new_tokens=args.gen,
                      arrival_time=t)
        extra = {}
        if cfg.is_encoder_decoder:
            extra["frames"] = np.ones((1, 16, cfg.d_model), np.float32) * .01
        if cfg.frontend == "vit_patch_stub":
            extra["patch_embeds"] = np.ones(
                (1, cfg.num_patches, cfg.d_model), np.float32) * .01
        eng.submit(req, **extra)

    m = eng.run()
    s = eng.metrics_snapshot()
    print(f"arch={cfg.name} prefill={args.prefill} ws={not args.no_ws} "
          f"obs={int(s['obs.enabled'])}")
    print(f"finished={m.num_finished}/{args.requests} "
          f"iters={s['engine.iterations']:.0f}")
    print(f"mean TTFT {m.mean_ttft*1e3:.2f} ms | mean TBT "
          f"{m.mean_tbt*1e3:.3f} ms | {m.token_throughput:.1f} tok/s")
    print(f"FlashH2D: {s['kv.h2d_calls']:.0f} fused launches, "
          f"{s['kv.h2d_blocks']:.0f} blocks, {s['kv.h2d_bytes']/1e6:.2f} MB")
    print(f"FlashD2H: {s['kv.d2h_calls']:.0f} saves, "
          f"{s['kv.d2h_blocks']:.0f} blocks, {s['kv.d2h_bytes']/1e6:.2f} MB")
    tot = max(s["kv.hits"] + s["kv.misses"], 1)
    print(f"HBM cache: {s['kv.hits']:.0f} hits / {s['kv.misses']:.0f} "
          f"misses ({100*s['kv.hits']/tot:.1f}% hit rate), "
          f"{s['kv.evictions']:.0f} evictions")
    overlap = eng.stage_overlap_measured()
    if overlap is not None:
        print(f"async host-stage overlap: {100*overlap:.1f}% of host-stage "
              f"work off-thread ({s['worker.jobs_run']:.0f} worker jobs)")
    if args.trace_out:
        n = eng.dump_trace(args.trace_out)
        print(f"trace: {n} events -> {args.trace_out}")
    if args.prom:
        print(eng.metrics_prometheus(), end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
