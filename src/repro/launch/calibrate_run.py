import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Run the two-point cost calibration for the single-pod roofline table.

    PYTHONPATH=src python -m repro.launch.calibrate_run \
        --in results/dryrun_pod1.json --out results/roofline_pod1.json

Reads the raw dry-run records (whose scan-over-layers costs undercount by
~num_layers — see repro/roofline/calibrate.py), compiles the unrolled
u / 2u-layer calibration variants per (arch, shape), and rewrites the
roofline terms from the calibrated per-device costs.
"""
import argparse
import json
import sys
import time

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, model_flops)
from repro.roofline.calibrate import calibrated_cost


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun_pod1.json")
    ap.add_argument("--out", default="results/roofline_pod1.json")
    ap.add_argument("--only", default="", help="arch:shape filter")
    args = ap.parse_args(argv)

    with open(args.inp) as f:
        data = json.load(f)
    mesh = make_production_mesh(multi_pod=False)
    chips = mesh.devices.size

    out = []
    for rec in data["records"]:
        arch, shape = rec["arch"], rec["shape"]
        if args.only and f"{arch}:{shape}" != args.only:
            continue
        cfg = get_config(arch)
        t0 = time.perf_counter()
        try:
            cal = calibrated_cost(cfg, shape, mesh)
        except Exception as e:  # noqa: BLE001
            print(f"FAIL calib {arch} x {shape}: {type(e).__name__}: {e}",
                  flush=True)
            rec["calibrated"] = {"error": str(e)}
            out.append(rec)
            continue
        flops_g = cal["flops"] * chips
        bytes_g = cal["bytes"] * chips
        t_c = flops_g / (chips * PEAK_FLOPS)
        t_m = bytes_g / (chips * HBM_BW)
        t_x = cal["coll"] / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        from repro.launch.steps import SHAPES
        sp = SHAPES[shape]
        mf = model_flops(cfg, rec["kind"], sp.seq_len, sp.global_batch)
        rec["calibrated"] = {
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": max(terms, key=terms.get),
            "model_flops": mf,
            "hlo_flops_global": flops_g,
            "useful_flops_ratio": (mf / flops_g) if flops_g else 0.0,
            "hbm_bytes_per_device": cal["bytes"],
            "collective_bytes_per_device": cal["coll"],
            "unit_layers": cal["unit_layers"],
            "calib_seconds": round(time.perf_counter() - t0, 1),
        }
        c = rec["calibrated"]
        print(f"OK {arch:18s} {shape:12s} comp={t_c:9.4f}s mem={t_m:9.4f}s "
              f"coll={t_x:9.5f}s dom={c['dominant'][:6]} "
              f"useful={c['useful_flops_ratio']:.3f} "
              f"({c['calib_seconds']}s)", flush=True)
        out.append(rec)

    with open(args.out, "w") as f:
        json.dump({"records": out}, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
