"""PlaneMesh — explicit mesh threading for the jitted serving planes.

Before this module, context parallelism reached the model only through the
``attention.CP_AXES`` module global, mutated by ``launch/dryrun.py`` and
read at trace time by the FUSED decode step — the staged decode plane and
the prefill plane were single-device only.  ``PlaneMesh`` replaces the
global with an explicit value threaded through every entry point that can
shard:

* ``models.model.decode_step(..., plane_mesh=...)`` — the fused
  context-parallel decode path (what dryrun lowers);
* ``core.device_pool.DevicePoolPlane(..., plane_mesh=...)`` — the staged
  per-layer decode plane: ``select``/``attend`` stage jits run under
  ``shard_map`` with the KV pool sharded across the mesh's model axis;
* ``core.prefill_plane.PrefillPlane(..., plane_mesh=...)`` — per-(layer,
  chunk) prefill launches run under ``shard_map`` with the token window
  sharded (sequence parallel) across the model axis;
* ``serving.engine.EngineConfig.mesh_spec`` — resolved once per engine via
  ``PlaneMesh.resolve``.

Sharding layout (see docs/architecture.md §7):

* **Decode pool, head mode** (GQA with ``Hkv %% n_model == 0``): pool slots
  are KV-HEAD-sharded over the model axis.  The paper's head-major
  ``(B, Hkv, NB, bs, D)`` layout makes this the zero-movement layout —
  DSA scoring, top-k selection and block-sparse attention are all
  per-kv-head-local, so NO pool data ever crosses the mesh; only the
  selected block ids (tiny int32) and the per-head attention outputs are
  gathered across the model axis.
* **Decode pool, block mode** (MLA's single latent head; head counts that
  do not divide the axis): the BLOCK axis is sharded instead.  Each shard
  appends/scores its local blocks, the (small) block scores are
  all-gathered so every shard computes the same global top-k, each shard
  attends over its LOCAL selected blocks, and the flash-style partials
  merge with a logsumexp psum — the full pool never moves.
* **Prefill window**: each (layer, chunk) group's QUERIES are
  sequence-sharded over the model axis — every shard runs the blocked
  attention (the O(T^2) term) for its query slice against the full window
  K/V — and only the attention outputs are re-gathered; projections and
  the layer epilogue run replicated for bitwise exactness.  No pool and
  no residual stream ever crosses the mesh.

Batch rows additionally shard over the data axes whenever the padded row
count divides them.  Host stages (FlashD2H write-back, LRU access, fused
FlashH2D restores) keep addressing the GLOBAL arrays; jax routes each
block update to the shard that owns it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class PlaneMesh:
    """(mesh, dp_axes, model_axis) — everything a plane needs to shard.

    ``dp_axes`` are the pure data-parallel axes (batch rows); the
    ``model_axis`` carries the context-parallel dimension (KV heads,
    pool blocks, or prefill sequence, chosen per call site).
    """
    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    # -- construction ------------------------------------------------------

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "PlaneMesh":
        from repro.launch.mesh import dp_axes as _dp, model_axis as _ma
        return cls(mesh=mesh, dp_axes=_dp(mesh), model_axis=_ma(mesh))

    @classmethod
    def resolve(cls, spec: Any) -> Optional["PlaneMesh"]:
        """EngineConfig.mesh_spec -> PlaneMesh | None.

        Accepted specs: ``None`` (single-device planes, the default), a
        ``PlaneMesh``, a ``jax.sharding.Mesh``, an int K or the string
        ``"model=K"`` (a local mesh with a K-way model axis over this
        process's devices — ``launch.mesh.make_local_mesh``).
        """
        if spec is None:
            return None
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, Mesh):
            return cls.from_mesh(spec)
        if isinstance(spec, int):
            k = spec
        elif isinstance(spec, str):
            body = spec.strip()
            if "=" in body:
                key, _, val = body.partition("=")
                if key.strip() != "model":
                    raise ValueError(f"unknown mesh_spec {spec!r}; expected "
                                     f"'model=K', an int, a Mesh or a "
                                     f"PlaneMesh")
                k = int(val)
            else:
                k = int(body)
        else:
            raise ValueError(f"cannot resolve mesh_spec {spec!r}")
        n = len(jax.devices())
        if k < 1 or n % k != 0:
            raise ValueError(f"model axis {k} does not divide the "
                             f"{n} available devices")
        from repro.launch.mesh import make_local_mesh
        return cls.from_mesh(make_local_mesh(model_axis=k))

    # -- sizes -------------------------------------------------------------

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= int(self.mesh.shape[a])
        return n

    def key(self) -> Tuple:
        """Registry key: value-equal meshes (same axes over the same
        devices IN THE SAME ORDER) share one per-stage jit registry /
        compile cache; a permuted device assignment keys separately so a
        cached stage never places shards on another mesh's layout."""
        return (tuple(self.mesh.axis_names),
                tuple(int(s) for s in self.mesh.devices.shape),
                tuple(d.id for d in self.mesh.devices.flat),
                self.dp_axes, self.model_axis)

    # -- spec helpers ------------------------------------------------------

    def dp_entry(self, dim: int):
        """PartitionSpec entry for a batch-row axis of size ``dim``: the
        data axes when they divide it, else replicated (e.g. B_cap=2 on a
        4-way data axis)."""
        n = self.dp_size
        if n > 1 and dim % n == 0:
            return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        return None

    def pool_shard_mode(self, cfg) -> str:
        """'heads' | 'blocks' — which pool axis the model axis shards.

        KV-head sharding is communication-free for select+attend (head-major
        layout) but needs ``Hkv %% n_model == 0`` and a real head axis; MLA's
        latent pool has ONE head, so it (and non-dividing GQA head counts)
        falls back to block-axis sharding."""
        n = self.model_size
        if (cfg.attention_type != "mla" and cfg.num_kv_heads >= n
                and cfg.num_kv_heads % n == 0):
            return "heads"
        return "blocks"

    def round_blocks(self, cfg, nb: int) -> int:
        """Pool block capacity rounded so the sharded pool divides evenly
        (only block mode shards the block axis)."""
        if self.pool_shard_mode(cfg) != "blocks":
            return nb
        n = self.model_size
        return -(-nb // n) * n

    def stage_sharding(self, cfg, stage: str):
        """The plane contract's sharding rules for one stage jit lowered
        under this mesh: which collectives its jaxpr may contain and which
        output tree paths may stay sharded (everything else must be pinned
        via ``replicate``).  This is what the sharding-leak pass of
        ``tools/analysis`` verifies on the lowered jaxpr."""
        from repro.core import plane_contract as pc
        return pc.sharding_rules(stage, pc.stage_shard_mode(stage, cfg,
                                                            self))

    def replicate(self, tree):
        """Pin every leaf to fully-replicated sharding (an all-gather where
        the value was sharded).  Stage functions apply this to everything
        they hand BACK to replicated stages — without it a shard_map
        out-spec's sharding propagates into the next stage's jit and GSPMD
        partitions replicated code (e.g. a mamba scan sequence-sharded by a
        leaked prefill residual), changing numerics."""
        from jax.sharding import NamedSharding, PartitionSpec
        s = NamedSharding(self.mesh, PartitionSpec())
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, s), tree)
