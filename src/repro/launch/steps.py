"""Step functions + abstract input specs for every (arch × input shape).

The four assigned input shapes:

    train_4k      seq=4,096    global_batch=256   -> train_step
    prefill_32k   seq=32,768   global_batch=32    -> prefill_step
    decode_32k    seq=32,768   global_batch=128   -> serve_step (1 new token)
    long_500k     seq=524,288  global_batch=1     -> serve_step (1 new token)

All specs are ShapeDtypeStructs (no allocation) — the multi-pod dry-run
lowers + compiles each (arch, shape, mesh) from these.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.common import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Abstract params / optimizer / decode-state
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, dtype=PARAM_DTYPE,
                    stacked: Optional[bool] = None):
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0), dtype,
                              stacked=stacked))


def abstract_opt_state(params_shape):
    return jax.eval_shape(init_opt_state, params_shape)


def _enc_kv_shapes(cfg: ModelConfig, batch: int, stacked: bool = True):
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    if stacked:                    # stacked (L, B, S_enc, Hkv, hd)
        sh = (cfg.num_layers, batch, cfg.encoder_seq_len, hkv, hd)
        return (sds(sh, PARAM_DTYPE), sds(sh, PARAM_DTYPE))
    return [(sds((batch, cfg.encoder_seq_len, hkv, hd), PARAM_DTYPE),
             sds((batch, cfg.encoder_seq_len, hkv, hd), PARAM_DTYPE))
            for _ in range(cfg.num_layers)]


def abstract_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                          stacked: Optional[bool] = None):
    num_blocks = -(-seq_len // cfg.dsa.block_size)
    enc = None
    if cfg.is_encoder_decoder:
        enc = _enc_kv_shapes(
            cfg, batch,
            stacked=M.is_homogeneous(cfg) if stacked is None else stacked)
    return jax.eval_shape(
        lambda: M.init_decode_state(cfg, batch, num_blocks, CACHE_DTYPE,
                                    enc_kvs=enc, stacked=stacked))


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Returns the kwargs pytree the step function is lowered with."""
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    if sp.kind == "train":
        text = S - (cfg.num_patches if cfg.frontend == "vit_patch_stub" else 0)
        batch = {"tokens": sds((B, text), jnp.int32),
                 "labels": sds((B, text), jnp.int32)}
        if cfg.is_encoder_decoder:
            batch["frames"] = sds((B, cfg.encoder_seq_len, cfg.d_model),
                                  PARAM_DTYPE)
        if cfg.frontend == "vit_patch_stub":
            batch["patch_embeds"] = sds((B, cfg.num_patches, cfg.d_model),
                                        PARAM_DTYPE)
        return {"batch": batch}
    if sp.kind == "prefill":
        text = S - (cfg.num_patches if cfg.frontend == "vit_patch_stub" else 0)
        inputs = {"tokens": sds((B, text), jnp.int32)}
        if cfg.is_encoder_decoder:
            inputs["frames"] = sds((B, cfg.encoder_seq_len, cfg.d_model),
                                   PARAM_DTYPE)
        if cfg.frontend == "vit_patch_stub":
            inputs["patch_embeds"] = sds((B, cfg.num_patches, cfg.d_model),
                                         PARAM_DTYPE)
        return {"inputs": inputs}
    # decode: one new token against a seq_len KV cache
    return {"tokens": sds((B,), jnp.int32),
            "state": abstract_decode_state(cfg, B, S)}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig,
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    remat: bool = True) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, _ = M.forward_train(p, cfg, batch, remat=remat)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt2, metrics = adamw_update(opt_cfg, params, grads,
                                              opt_state)
        return params2, opt2, {"loss": loss, **metrics}
    return train_step


def make_prefill_step(cfg: ModelConfig, seq_len: int) -> Callable:
    num_blocks = -(-seq_len // cfg.dsa.block_size)

    def prefill_step(params, inputs):
        logits, state = M.prefill(params, cfg, inputs, num_blocks,
                                  cache_dtype=CACHE_DTYPE)
        return logits, state
    return prefill_step


def make_serve_step(cfg: ModelConfig, attn_impl: str = "ref",
                    plane_mesh=None) -> Callable:
    """plane_mesh: ``launch.plane_mesh.PlaneMesh`` — lower the decode step
    context-parallel (block-sharded pools) instead of plain GSPMD; replaces
    the former ``attention.CP_AXES`` module-global mutation."""
    def serve_step(params, tokens, state):
        logits, new_state = M.decode_step(params, cfg, tokens, state,
                                          attn_impl=attn_impl,
                                          plane_mesh=plane_mesh)
        return logits, new_state
    return serve_step


def step_and_specs(cfg: ModelConfig, shape_name: str, *, remat: bool = True,
                   stacked: Optional[bool] = None, plane_mesh=None
                   ) -> Tuple[Callable, Tuple, str]:
    """Returns (fn, ordered_args_specs, kind) for lowering."""
    sp = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    params = abstract_params(cfg, stacked=stacked)
    if sp.kind == "train":
        fn = make_train_step(cfg, remat=remat)
        opt = abstract_opt_state(params)
        return fn, (params, opt, specs["batch"]), "train"
    if sp.kind == "prefill":
        fn = make_prefill_step(cfg, sp.seq_len)
        return fn, (params, specs["inputs"]), "prefill"
    fn = make_serve_step(cfg, plane_mesh=plane_mesh)
    state = abstract_decode_state(cfg, sp.global_batch, sp.seq_len,
                                  stacked=stacked)
    return fn, (params, specs["tokens"], state), "decode"
