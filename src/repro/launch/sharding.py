"""Sharding rules: params / batches / decode state -> NamedSharding pytrees.

Baseline layout (paper-faithful adaptation, DESIGN §5):

  * params — tensor parallel on the ``model`` axis: column-parallel in
    projections (wq/wk/wv, FFN up/gate), row-parallel out projections
    (wo, FFN down).  Expert weights are EXPERT-parallel (leading E axis on
    ``model``).  Vocab (embed/lm_head) sharded on ``model``.
  * batch — data parallel over ('pod', 'data').
  * decode KV pools — batch over data axes, BLOCK axis over ``model``
    (context-sharded pool; the DSA gather over a block-sharded pool is the
    central distribution question the §Perf log studies).

Every rule degrades to replication when a dim is not divisible by the axis
size — e.g. GQA kv=8 heads on a 16-way model axis — so ``.lower()`` always
succeeds for every assigned architecture.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# weight-name classes -------------------------------------------------------

_COL_PARALLEL = {  # 2D (in, out): shard OUT dim
    "wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "w_dq", "w_dkv",
    "w_gate", "w_up", "x_proj", "in_proj", "cw_k",
    "w_r", "w_k", "w_v", "w_g", "decay_A", "dt_proj",
}
_ROW_PARALLEL = {  # 2D (in, out): shard IN dim
    "wo", "w_down", "out_proj", "cw_v", "w_o", "decay_B", "cw_r",
}
_REPLICATED = {
    "router", "conv_w", "conv_b", "dt_bias", "A_log", "D", "bonus_u",
    "q_norm", "kv_norm", "w_kr", "bq", "bk", "bv",
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def axis_divides(dim: int, mesh: Mesh, axis: str) -> bool:
    """True when `axis` exists, is >1-way, and evenly divides `dim` — the
    shard-or-replicate rule every spec in this module applies (the planes'
    ``PlaneMesh.dp_entry`` applies the same rule over the product of its
    data axes)."""
    n = _axis_size(mesh, axis)
    return n > 1 and dim % n == 0


_div = axis_divides


def dp_spec(mesh: Mesh, dim: int):
    """Longest prefix of data-parallel axes that divides `dim`."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    # try full product first, then just 'data', else replicate
    for cand in (tuple(axes), ("data",) if "data" in axes else ()):
        if not cand:
            continue
        n = 1
        for a in cand:
            n *= mesh.shape[a]
        if dim % n == 0:
            return cand if len(cand) > 1 else cand[0]
    return None


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def _is_stacked_layer_leaf(path: Tuple) -> bool:
    """True when the leaf lives under stacked layers (leading L axis):
    path ...DictKey('layers') followed by another DictKey (not an index)."""
    for i, p in enumerate(path[:-1]):
        if getattr(p, "key", None) == "layers":
            nxt = path[i + 1]
            return hasattr(nxt, "key")
    return False


def _param_spec(path: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    if _is_stacked_layer_leaf(path) and len(shape) >= 1:
        inner = _param_spec_base(path, tuple(shape[1:]), mesh)
        return P(None, *inner)
    return _param_spec_base(path, shape, mesh)


def _param_spec_base(path: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = str(p.key)
            break
    m = "model"
    if name in ("embed",):
        return P(m, None) if _div(shape[0], mesh, m) else P()
    if name in ("lm_head",):
        return P(None, m) if _div(shape[1], mesh, m) else P()
    if name in _REPLICATED or len(shape) <= 1:
        return P(*([None] * len(shape)))
    if len(shape) == 3:               # expert weights (E, a, b)
        if _div(shape[0], mesh, m):
            return P(m, None, None)   # expert parallel
        if _div(shape[2], mesh, m):
            return P(None, None, m)
        return P(None, None, None)
    if len(shape) == 2:
        if name in _COL_PARALLEL and _div(shape[1], mesh, m):
            return P(None, m)
        if name in _ROW_PARALLEL and _div(shape[0], mesh, m):
            return P(m, None)
        # unknown 2D weight: shard the bigger divisible dim
        if shape[1] >= shape[0] and _div(shape[1], mesh, m):
            return P(None, m)
        if _div(shape[0], mesh, m):
            return P(m, None)
        return P(None, None)
    return P(*([None] * len(shape)))


def _add_zero_axis(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-style extra sharding: also shard the largest still-unsharded
    divisible dim over 'data' (GSPMD all-gathers per use — ZeRO-3)."""
    if "data" not in mesh.axis_names or len(shape) < 2:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    cand = [(shape[i], i) for i, e in enumerate(entries)
            if e is None and _div(shape[i], mesh, "data")]
    if not cand:
        return spec
    _, i = max(cand)
    entries[i] = "data"
    return P(*entries)


def param_shardings(params_shape: Any, mesh: Mesh,
                    zero_data: bool = False) -> Any:
    """NamedSharding pytree matching the params (shape) pytree.

    zero_data=True additionally shards every weight over the 'data' axis
    (ZeRO-3: parameters/optimizer state fully sharded; all-gathered per
    layer during compute)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in leaves:
        spec = _param_spec(path, leaf.shape, mesh)
        if zero_data:
            spec = _add_zero_axis(spec, leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params_shape), out)


def opt_shardings(opt_shape: Any, mesh: Mesh, zero_data: bool = False) -> Any:
    """Optimizer state mirrors param sharding ('m'/'v' subtrees)."""
    def spec_for(path, leaf):
        # strip the leading 'm'/'v' key so the param rules apply
        sub = path[1:] if path and str(getattr(path[0], "key", "")) in (
            "m", "v") else path
        if not sub and leaf.ndim == 0:     # step counter
            return NamedSharding(mesh, P())
        spec = _param_spec(sub, leaf.shape, mesh)
        if zero_data:
            spec = _add_zero_axis(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    leaves, _ = jax.tree_util.tree_flatten_with_path(opt_shape)
    out = [spec_for(path, leaf) for path, leaf in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(opt_shape), out)


# ---------------------------------------------------------------------------
# Batches (train / prefill inputs)
# ---------------------------------------------------------------------------

def batch_shardings(batch_shape: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    out = {}
    for k, v in batch_shape.items():
        dp = dp_spec(mesh, v.shape[0])
        spec = [dp] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, P(*spec))
    return out


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

def _is_stacked_cache_leaf(path: Tuple) -> bool:
    for i, p in enumerate(path[:-1]):
        if getattr(p, "key", None) == "caches":
            nxt = path[i + 1]
            return hasattr(nxt, "key")
    return False


def _state_spec(path: Tuple, shape: Tuple[int, ...], mesh: Mesh,
                *, shard_blocks: bool = True) -> P:
    if _is_stacked_cache_leaf(path) and len(shape) >= 1:
        inner = _state_spec_base(path, tuple(shape[1:]), mesh,
                                 shard_blocks=shard_blocks)
        return P(None, *inner)
    return _state_spec_base(path, shape, mesh, shard_blocks=shard_blocks)


def _state_spec_base(path: Tuple, shape: Tuple[int, ...], mesh: Mesh,
                     *, shard_blocks: bool = True) -> P:
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = str(p.key)
            break
    m = "model"
    B = shape[0] if shape else 1
    dp = dp_spec(mesh, B) if shape else None
    if name == "cur_len":
        return P(dp)
    if name in ("k", "v") and len(shape) == 5:
        # (B, Hkv, NB, bs, D): batch over dp, blocks over model
        nb_ok = shard_blocks and _div(shape[2], mesh, m)
        return P(dp, None, m if nb_ok else None, None, None)
    if name == "meta":
        nb_ok = shard_blocks and _div(shape[2], mesh, m)
        spec = [dp, None, m if nb_ok else None] + [None] * (len(shape) - 3)
        return P(*spec)
    if name == "conv" and len(shape) == 3:      # (B, dc-1, di)
        return P(dp, None, m if _div(shape[2], mesh, m) else None)
    if name == "ssm" and len(shape) == 3:       # (B, di, ds)
        return P(dp, m if _div(shape[1], mesh, m) else None, None)
    if name == "S" and len(shape) == 4:         # (B, H, hd, hd)
        return P(dp, m if _div(shape[1], mesh, m) else None, None, None)
    if name in ("shift_t", "shift_c") and len(shape) == 2:
        return P(dp, m if _div(shape[1], mesh, m) else None)
    if name == "enc_kvs" and len(shape) == 5:    # stacked (L, B, S, Hkv, hd)
        dp5 = dp_spec(mesh, shape[1])
        return P(None, dp5, None, m if _div(shape[3], mesh, m) else None,
                 None)
    if len(shape) == 4:                          # enc_kvs (B, S, Hkv, hd)
        return P(dp, None, m if _div(shape[2], mesh, m) else None, None)
    return P(*([dp] + [None] * (len(shape) - 1))) if shape else P()


def state_shardings(state_shape: Any, mesh: Mesh,
                    *, shard_blocks: bool = True) -> Any:
    leaves, _ = jax.tree_util.tree_flatten_with_path(state_shape)
    out = [NamedSharding(mesh, _state_spec(path, leaf.shape, mesh,
                                           shard_blocks=shard_blocks))
           for path, leaf in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state_shape), out)


def tokens_sharding(batch: int, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(dp_spec(mesh, batch)))
