import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Dry-run sweep of the OPTIMIZED (§Perf) configuration — expert-parallel
MoE + context-parallel decode + ZeRO param sharding + state donation —
proving the beyond-paper distribution also lowers+compiles for every
(arch × shape), single- and multi-pod.

    PYTHONPATH=src python -m repro.launch.optimized_run --out results/optimized.json
"""
import argparse
import json
import sys

from repro.configs import ALL_ARCHS
from repro.launch.dryrun import lower_one
from repro.launch.steps import SHAPES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    archs = ALL_ARCHS[:10] if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            kind = SHAPES[shape].kind
            tag = f"{arch} x {shape}"
            try:
                rec = lower_one(
                    arch, shape, multi_pod=args.multi_pod,
                    moe_ep=True, cp_decode=(kind == "decode"),
                    donate_state=(kind == "decode"), zero_data=True,
                    verbose=False)
                records.append(rec)
                m = rec["memory"]
                print(f"OK  {tag:40s} variant={rec['variant']:18s} "
                      f"arg={m['argument_size_in_bytes']/1e9:7.1f}GB "
                      f"coll={rec['collectives']['bytes_per_device']/1e6:9.1f}MB "
                      f"compile={rec['compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append({"tag": tag, "error": f"{type(e).__name__}: {e}"})
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1)
    print(f"\n{len(records)} ok, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
