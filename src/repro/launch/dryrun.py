import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape decode_32k [--multi-pod] [--out results.json]

Succeeding here proves the distribution config is coherent: shardings are
accepted, the collectives lower, and compilation fits.  The compiled
artifact's ``memory_analysis()`` / ``cost_analysis()`` plus the HLO
collective parse feed EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import json
import sys
import time
from typing import Any, Dict

import jax

from repro.configs import ALL_ARCHS, get_config
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import SHAPES, step_and_specs
from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     extract_cost, extract_memory,
                                     roofline_report)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              compile_: bool = True, shard_blocks: bool = True,
              remat: bool = True, verbose: bool = True,
              moe_ep: bool = False, donate_state: bool = False,
              zero_data: bool = False, cp_decode: bool = False
              ) -> Dict[str, Any]:
    """Lower+compile one (arch, shape, mesh) and return the dry-run record.

    moe_ep / donate_state are the §Perf optimization variants (baseline is
    the paper-faithful GSPMD lowering)."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    from repro.launch.plane_mesh import PlaneMesh
    from repro.models import ffn as ffn_mod
    dp = ("pod", "data") if multi_pod else ("data",)
    if moe_ep:
        ffn_mod.EP_AXES = (dp, "model")
        ffn_mod.EP_MESH = mesh
    else:
        ffn_mod.EP_AXES = None
        ffn_mod.EP_MESH = None
    # context-parallel decode arrives as an EXPLICIT PlaneMesh threaded
    # through step_and_specs -> decode_step (the former attention.CP_AXES
    # module-global mutation is gone)
    pm = (PlaneMesh(mesh=mesh, dp_axes=dp, model_axis="model")
          if cp_decode else None)
    fn, args, kind = step_and_specs(cfg, shape_name, remat=remat,
                                    plane_mesh=pm)

    # shardings per argument pytree
    if kind == "train":
        params_s, opt_s, batch_s = (
            sh.param_shardings(args[0], mesh, zero_data=zero_data),
            sh.opt_shardings(args[1], mesh, zero_data=zero_data),
            sh.batch_shardings(args[2], mesh))
        in_shardings = (params_s, opt_s, batch_s)
        out_shardings = (params_s, opt_s, None)
    elif kind == "prefill":
        params_s = sh.param_shardings(args[0], mesh, zero_data=zero_data)
        batch_s = sh.batch_shardings(args[1], mesh)
        in_shardings = (params_s, batch_s)
        out_shardings = None
    else:
        params_s = sh.param_shardings(args[0], mesh, zero_data=zero_data)
        tok_s = sh.tokens_sharding(args[1].shape[0], mesh)
        state_s = sh.state_shardings(args[2], mesh, shard_blocks=shard_blocks)
        in_shardings = (params_s, tok_s, state_s)
        out_shardings = (None, state_s)

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": int(n_chips),
    }
    rec["variant"] = (("ep" if moe_ep else "")
                      + ("+cp" if cp_decode else "")
                      + ("+donate" if donate_state else "")
                      + ("+zero" if zero_data else "")) or "baseline"
    t0 = time.perf_counter()
    with mesh:
        donate = (2,) if (donate_state and kind == "decode") else ()
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        if not compile_:
            return rec
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)

    rec["memory"] = extract_memory(compiled)
    rec["cost"] = extract_cost(compiled)
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes_from_hlo(hlo)
    rec["roofline"] = roofline_report(cfg, rec, n_chips)
    if verbose:
        print(compiled.memory_analysis())
        print({k: v for k, v in rec["cost"].items()})
        print(json.dumps(rec["roofline"], indent=2))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help=f"one of {ALL_ARCHS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-shard-blocks", action="store_true",
                    help="replicate KV pool block axis (ablation)")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    archs = ALL_ARCHS[:10] if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch} x {shape} x {'2x16x16' if args.multi_pod else '16x16'}"
            print(f"=== {tag} ===", flush=True)
            try:
                rec = lower_one(arch, shape, multi_pod=args.multi_pod,
                                compile_=not args.lower_only,
                                shard_blocks=not args.no_shard_blocks,
                                remat=not args.no_remat)
                records.append(rec)
                print(f"OK  {tag} lower={rec.get('lower_s')}s "
                      f"compile={rec.get('compile_s')}s", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append({"tag": tag, "error": f"{type(e).__name__}: {e}"})
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1)
    print(f"\n{len(records)} ok, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
