"""Production mesh construction (multi-pod dry-run §e).

Defined as FUNCTIONS so importing this module never touches jax device
state; ``dryrun.py`` sets XLA_FLAGS before any jax import to get 512
placeholder host devices.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (16, 16) = 256 chips; multi-pod (2, 16, 16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever this process actually has (tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The pure-data-parallel axes of a mesh ('pod' is data-parallel)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model" if "model" in mesh.axis_names else mesh.axis_names[-1]
