"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --batch 4 --seq 128

Runs the real jit'd train step on the local device mesh (CPU here, TPU pod
in deployment — identical code path; only the mesh differs).  ``--smoke``
selects the reduced config; full configs are exercised via dryrun.py.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.checkpoint import save_checkpoint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh()
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"mesh={dict(mesh.shape)}")

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    opt_state = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    params_s = sh.param_shardings(jax.eval_shape(lambda: params), mesh)
    opt_s = sh.opt_shardings(jax.eval_shape(lambda: opt_state), mesh)

    from repro.data.pipeline import TokenStream
    stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch,
                                    seed=args.seed))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False),
                      in_shardings=(params_s, opt_s, None),
                      out_shardings=(params_s, opt_s, None))
    with mesh:
        params = jax.device_put(params, params_s)
        opt_state = jax.device_put(opt_state, opt_s)
        for step in range(args.steps):
            raw = stream.batch()
            batch = {"tokens": jnp.asarray(raw["tokens"]),
                     "labels": jnp.asarray(raw["labels"])}
            if cfg.is_encoder_decoder:
                batch["frames"] = jnp.ones(
                    (args.batch, 16, cfg.d_model), jnp.float32) * 0.01
            if cfg.frontend == "vit_patch_stub":
                batch["patch_embeds"] = jnp.ones(
                    (args.batch, cfg.num_patches, cfg.d_model),
                    jnp.float32) * 0.01
            params, opt_state, m = step_fn(params, opt_state, batch)
            if (step + 1) % max(args.steps // 10, 1) == 0 or step == 0:
                print(f"step {step+1:4d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params}, args.steps)
        print(f"saved {args.ckpt}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
