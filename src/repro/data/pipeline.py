"""Synthetic sharded token pipeline.

No datasets ship offline, so the pipeline synthesizes language-like token
streams with Zipfian unigram statistics and local repetition structure (so
the loss actually goes down during the example training runs).  The stream
is deterministic in (seed, host_id) and yields fixed-shape batches; for
multi-host data parallelism each host draws a disjoint shard of the global
batch — the same contract a real tokenized-shard loader would satisfy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    zipf_a: float = 1.2            # unigram skew
    repeat_p: float = 0.3          # prob. of copying a recent token
    repeat_window: int = 32

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0, \
            "global batch must divide hosts"
        return self.global_batch // self.num_hosts


class TokenStream:
    """Deterministic synthetic token batches: {"tokens", "labels"}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, cfg.host_id]))
        # Zipf unigram distribution over the vocab (ids 4.. reserved 0-3)
        ranks = np.arange(1, cfg.vocab_size - 4 + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, cfg.zipf_a)
        self._p = p / p.sum()
        self._ids = np.arange(4, cfg.vocab_size)

    def _sample_seq(self, n: int) -> np.ndarray:
        cfg = self.cfg
        base = self.rng.choice(self._ids, size=n, p=self._p)
        out = base.copy()
        # local repetition: with prob repeat_p copy a token from the window
        coin = self.rng.random(n) < cfg.repeat_p
        offs = self.rng.integers(1, cfg.repeat_window + 1, size=n)
        for i in range(1, n):
            if coin[i]:
                j = max(0, i - int(offs[i]))
                out[i] = out[j]
        return out.astype(np.int32)

    def batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        B, S = cfg.host_batch, cfg.seq_len
        toks = np.stack([self._sample_seq(S + 1) for _ in range(B)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch()


def eval_stream(cfg: DataConfig, num_batches: int = 4):
    """Fixed eval batches (separate seed stream)."""
    ev = TokenStream(dataclasses.replace(cfg, seed=cfg.seed + 10_000))
    return [ev.batch() for _ in range(num_batches)]
