"""Chrome trace-event tracer for the serving pipeline.

Emits the JSON Object Format of the Chrome trace-event spec — a
``{"traceEvents": [...]}`` dict of complete ("X") events with
microsecond ``ts``/``dur`` and one ``tid`` lane per OS thread — which
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` both load
directly.  The async host-stage overlap shows up as the worker thread's
lane running concurrently with the dispatch thread's iteration spans.

Design constraints, in order:

1. **Disabled mode is free.**  The default tracer is the shared
   ``NULL_TRACER`` (``enabled=False``).  Hot per-layer code guards every
   emission with ``if tracer.enabled:`` so the off path is one attribute
   read — no span objects, no perf_counter calls, no allocation.
2. **Span times are the measurement, not a copy of it.**  The planes
   already time their dispatch windows with ``time.perf_counter()`` for
   ``stage_timeline``; :meth:`Tracer.complete_at` takes those exact
   ``t0``/``dur`` values, so the trace and the counter instruments can
   never drift apart on the same run.
3. **Thread-safe.**  ``HostStageWorker`` emits from its own thread while
   the dispatch thread emits per-layer spans; a single lock guards the
   event list and the tid table.

Instrumentation must stay *outside* jitted stage bodies (a tracer call
inside one would fire once at trace time and never again) — the
``no-obs-in-jit`` analyzer rule enforces this statically.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List


class _NullSpan:
    """Reusable no-op context manager (one shared instance, no allocs)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Context-manager handle from :meth:`Tracer.span`."""
    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t0 = self._t0
        self._tracer.complete_at(self._name, self._cat, t0,
                                 time.perf_counter() - t0, **self._args)
        return False


class Tracer:
    """Thread-safe Chrome trace-event collector.

    ``ts``/``dur`` are microseconds relative to tracer construction so
    traces start near t=0 regardless of perf_counter's epoch.  Each OS
    thread gets a small stable ``tid`` plus an "M" ``thread_name``
    metadata event the first time it emits, so Perfetto labels the
    lanes ("MainThread", "host-stage-…").
    """

    enabled = True

    def __init__(self, process_name: str = "repro-engine"):
        self._events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._pid = os.getpid()
        self._tids: Dict[int, int] = {}
        self._events.append({
            "ph": "M", "name": "process_name", "pid": self._pid, "tid": 0,
            "args": {"name": process_name},
        })

    # -- emission ---------------------------------------------------------

    def begin(self) -> float:
        """Start a span by hand; pass the return value to :meth:`end`."""
        return time.perf_counter()

    def end(self, name: str, cat: str, t0: float, **args: Any) -> None:
        """Close a span opened with :meth:`begin` (dur = now - t0)."""
        self.complete_at(name, cat, t0, time.perf_counter() - t0, **args)

    def complete_at(self, name: str, cat: str, t0: float, dur_s: float,
                    **args: Any) -> None:
        """Record a complete ("X") event from perf_counter ``t0`` lasting
        ``dur_s`` seconds — the caller's own timing values, verbatim."""
        ev = {
            "ph": "X", "name": name, "cat": cat,
            "ts": (t0 - self._epoch) * 1e6, "dur": dur_s * 1e6,
            "pid": self._pid, "tid": 0,
        }
        if args:
            ev["args"] = args
        tident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(tident)
            if tid is None:
                tid = self._tids[tident] = len(self._tids) + 1
                self._events.append({
                    "ph": "M", "name": "thread_name", "pid": self._pid,
                    "tid": tid,
                    "args": {"name": threading.current_thread().name},
                })
            ev["tid"] = tid
            self._events.append(ev)

    def instant(self, name: str, cat: str = "", **args: Any) -> None:
        """Record an instant ("i") event at now."""
        ev = {
            "ph": "i", "name": name, "cat": cat,
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "pid": self._pid, "tid": 0, "s": "t",
        }
        if args:
            ev["args"] = args
        tident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(tident)
            if tid is None:
                tid = self._tids[tident] = len(self._tids) + 1
                self._events.append({
                    "ph": "M", "name": "thread_name", "pid": self._pid,
                    "tid": tid,
                    "args": {"name": threading.current_thread().name},
                })
            ev["tid"] = tid
            self._events.append(ev)

    def span(self, name: str, cat: str = "", **args: Any) -> _Span:
        """``with tracer.span("name"):`` — for cool paths; hot paths use
        the guarded begin/end pattern instead."""
        return _Span(self, name, cat, args)

    # -- export -----------------------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def chrome_trace(self) -> Dict[str, Any]:
        """The full Chrome trace-event JSON object."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def dump_trace(self, path: str) -> int:
        """Write the trace JSON to ``path``; returns the event count.
        Blocking file I/O — never call inside a dispatch window."""
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])


class NullTracer:
    """No-op stand-in with the full :class:`Tracer` surface.

    ``enabled`` is False so guarded hot paths skip emission entirely;
    the un-guarded methods are safe no-ops for cool paths.
    """

    enabled = False
    __slots__ = ()

    def begin(self) -> float:
        return 0.0

    def end(self, name: str, cat: str, t0: float, **args: Any) -> None:
        pass

    def complete_at(self, name: str, cat: str, t0: float, dur_s: float,
                    **args: Any) -> None:
        pass

    def instant(self, name: str, cat: str = "", **args: Any) -> None:
        pass

    def span(self, name: str, cat: str = "", **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def events(self) -> List[Dict[str, Any]]:
        return []

    def chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def dump_trace(self, path: str) -> int:
        return 0


NULL_TRACER = NullTracer()
