"""MetricsRegistry: counters / gauges / histograms, one flat snapshot.

The single home for the engine's previously-scattered telemetry.
Naming scheme (dotted, lowercase): ``engine.*`` iteration-level facts,
``sched.*`` scheduler decisions (queue depth, batch sizes, working-set
estimates), ``kv.*`` FlashH2D/FlashD2H transfer totals and HBM
residency, ``plane.*`` per-plane staged-decode counters aggregated,
``worker.*`` the HostStageWorker, ``obs.*`` the obs layer itself.

Instruments are memoized by name — ``registry.gauge("x")`` always
returns the same object, so hot paths resolve instruments once in
``__init__`` and call ``.set()``/``.inc()`` per iteration.  The whole
registry flattens to one ``{name: float}`` dict via :meth:`snapshot`
(histograms expand to ``_count/_sum/_min/_max/_mean``) and exports
Prometheus text exposition via :meth:`prometheus_text`.
"""
from __future__ import annotations

import re
import threading
from typing import Dict, Optional

_PROM_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")


class Counter:
    """Monotonically increasing value."""
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Point-in-time value (set, not accumulated)."""
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Streaming count/sum/min/max (no buckets — snapshot-oriented)."""
    __slots__ = ("name", "help", "count", "sum", "min", "max")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value


class MetricsRegistry:
    """Memoizing registry; thread-safe instrument creation.

    Individual ``inc``/``set``/``observe`` calls are plain float ops —
    atomic enough under the GIL for the counters here; the lock only
    guards the name table.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, help: str = "") -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name, help)
            return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, help)
            return g

    def histogram(self, name: str, help: str = "") -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, help)
            return h

    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name: value}``; histograms expand to five keys."""
        out: Dict[str, float] = {}
        with self._lock:
            for c in self._counters.values():
                out[c.name] = c.value
            for g in self._gauges.values():
                out[g.name] = g.value
            for h in self._histograms.values():
                out[h.name + "_count"] = float(h.count)
                out[h.name + "_sum"] = h.sum
                if h.count:
                    out[h.name + "_min"] = h.min
                    out[h.name + "_max"] = h.max
                    out[h.name + "_mean"] = h.sum / h.count
        return out

    def prometheus_text(self,
                        extra: Optional[Dict[str, float]] = None) -> str:
        """Prometheus text exposition format (dots become underscores).

        ``extra`` merges additional flat values (e.g. the engine's
        derived counters) as untyped samples.
        """
        lines = []
        with self._lock:
            items = (
                [(c, "counter") for c in self._counters.values()]
                + [(g, "gauge") for g in self._gauges.values()]
            )
            hists = list(self._histograms.values())
        for inst, kind in items:
            pname = _prom_name(inst.name)
            if inst.help:
                lines.append(f"# HELP {pname} {inst.help}")
            lines.append(f"# TYPE {pname} {kind}")
            lines.append(f"{pname} {_prom_value(inst.value)}")
        for h in hists:
            pname = _prom_name(h.name)
            if h.help:
                lines.append(f"# HELP {pname} {h.help}")
            lines.append(f"# TYPE {pname} summary")
            lines.append(f"{pname}_count {h.count}")
            lines.append(f"{pname}_sum {_prom_value(h.sum)}")
        if extra:
            for name in sorted(extra):
                lines.append(f"{_prom_name(name)} "
                             f"{_prom_value(extra[name])}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    name = _PROM_SANITIZE.sub("_", name.replace(".", "_"))
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _prom_value(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)
