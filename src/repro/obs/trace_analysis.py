"""Span-interval analysis over a Chrome trace: achieved overlap.

The quantity both instruments measure is *the fraction of host-stage
work that ran off the dispatch thread*:

    overlap = W / (W + D)

where ``W`` is host-stage time spent on the ``HostStageWorker`` thread
(cat ``host-stage-worker``) while the dispatch thread was inside an
engine iteration, and ``D`` is host-stage time the dispatch thread
spent itself (cat ``host-stage`` — the per-layer stage-callback
windows).  Sync mode has no worker spans, so the function returns
``None`` there; a fully-async run where every write-back moved to the
worker approaches 1 as the dispatch-side residue shrinks.

This is the *trace* instrument.  The independent counter instrument is
``ServingEngine.stage_overlap_measured()`` (HostStageWorker.busy_s vs
the planes' accumulated ``host_stage_s``); the nightly bench asserts
the two agree within 10% on the same run.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

Interval = Tuple[float, float]


def _union(intervals: List[Interval]) -> List[Interval]:
    """Merge into disjoint sorted intervals."""
    out: List[Interval] = []
    for s, e in sorted(intervals):
        if out and s <= out[-1][1]:
            if e > out[-1][1]:
                out[-1] = (out[-1][0], e)
        else:
            out.append((s, e))
    return out


def _total(intervals: List[Interval]) -> float:
    return sum(e - s for s, e in intervals)


def _intersect(a: List[Interval], b: List[Interval]) -> List[Interval]:
    """Intersection of two disjoint sorted interval lists."""
    out: List[Interval] = []
    i = j = 0
    while i < len(a) and j < len(b):
        s = max(a[i][0], b[j][0])
        e = min(a[i][1], b[j][1])
        if s < e:
            out.append((s, e))
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return out


def _spans(events: Sequence[Dict[str, Any]], *, cat: Optional[str] = None,
           name: Optional[str] = None) -> List[Interval]:
    out = []
    for ev in events:
        if ev.get("ph") != "X":
            continue
        if cat is not None and ev.get("cat") != cat:
            continue
        if name is not None and ev.get("name") != name:
            continue
        ts = ev["ts"]
        out.append((ts, ts + ev.get("dur", 0.0)))
    return out


def achieved_overlap_fraction(trace) -> Optional[float]:
    """Overlap fraction from span intervals; ``None`` if unmeasurable.

    ``trace`` is either the ``{"traceEvents": [...]}`` dict or the bare
    event list.  Numerator: worker-thread host-stage spans intersected
    with the dispatch thread's ``iteration`` spans (worker work done
    outside any iteration overlapped nothing).  Denominator adds the
    dispatch thread's own ``host-stage`` callback spans.
    """
    events = trace["traceEvents"] if isinstance(trace, dict) else trace
    worker = _union(_spans(events, cat="host-stage-worker"))
    if not worker:
        return None
    iters = _union(_spans(events, name="iteration"))
    dispatch_stage = _union(_spans(events, cat="host-stage"))
    overlapped = _total(_intersect(worker, iters))
    denom = overlapped + _total(dispatch_stage)
    if denom <= 0.0:
        return None
    return overlapped / denom
