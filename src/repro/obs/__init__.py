"""Observability: Chrome-trace spans + one metrics registry.

The engine's async pipeline (dispatch thread + ``HostStageWorker``) and
mixed hybrid iterations are concurrent by construction; this package is
how that concurrency becomes *visible*.  Two surfaces:

- :class:`~repro.obs.tracing.Tracer` — thread-safe Chrome trace-event
  JSON (Perfetto-loadable), one lane per thread.  Disabled by default;
  ``NULL_TRACER`` is the shared no-op so hot paths stay allocation-free.
- :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  histograms behind ``engine.metrics_snapshot()`` and a Prometheus text
  exporter.

Span-interval analysis (``achieved_overlap_fraction``) lives in
:mod:`repro.obs.trace_analysis` and cross-checks the counter-based
overlap measurement in ``benchmarks/bench_overlap.py``.
"""
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace_analysis import achieved_overlap_fraction
from repro.obs.tracing import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "achieved_overlap_fraction",
]
