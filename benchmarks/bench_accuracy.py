"""Paper Table 1 (adapted): DSA fidelity vs token budget.

No pretrained weights ship offline, so task accuracy is reproduced as
ATTENTION-OUTPUT FIDELITY: relative L2 error and cosine similarity of the
DSA decode output vs full attention, per token budget, on real model
forwards with adversarially long contexts.  The paper's claim (budget 2048
retains 99% accuracy) maps to cosine >= 0.99 at budget >= context/4.

quant_fidelity: the same bound for the int8 DRAM offload tier — the REAL
engine with ``offload_quant="int8"`` vs ``"none"`` under 1-block-LRU
eviction pressure (every selected block quantizes on FlashD2H save and
dequantizes on FlashH2D restore, every iteration).  Per decode position,
logits cosine is computed over the common greedy prefix (identical
contexts, so only quant noise separates the runs); the emitted
``min_cosine``/``mean_cosine`` must stay >= 0.99.
"""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_smoke_config
from repro.models import model as M


def quant_fidelity_section() -> None:
    """int8 offload tier fidelity vs the fp tier on the REAL engine (see
    module docstring for the methodology)."""
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request

    header("quant_fidelity: int8 offload tier vs fp, real engine decode "
           "(1-block LRU eviction pressure)")
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    def run(quant):
        eng = ServingEngine(params, cfg, EngineConfig(
            chunk_size=64, r_max=4, hbm_blocks_per_request=1,
            offload_quant=quant))
        rng = np.random.default_rng(7)
        order = []
        for _ in range(2):
            r = Request(prompt_len=64, max_new_tokens=10)
            eng.submit(r, tokens=rng.integers(4, cfg.vocab_size,
                                              64).astype(np.int32))
            order.append(r.req_id)
        logits = {rid: {} for rid in order}
        while eng.step() is not None:
            for rid in order:
                st = eng.states.get(rid)
                if st is None or st.last_logits is None \
                        or not st.out_tokens:
                    continue
                i = len(st.out_tokens) - 1
                if i not in logits[rid]:
                    logits[rid][i] = np.asarray(st.last_logits,
                                                np.float64).ravel()
        return ([eng.states[r].out_tokens for r in order],
                [logits[r] for r in order])

    toks_fp, log_fp = run("none")
    toks_q8, log_q8 = run("int8")
    cosines = []
    compared = matched = total = 0
    for tf, tq, lf, lq in zip(toks_fp, toks_q8, log_fp, log_q8):
        total += len(tf)
        # positions with identical context: the common greedy prefix plus
        # the first divergent position (same inputs, argmax flipped)
        div = next((i for i, (a, b) in enumerate(zip(tf, tq)) if a != b),
                   len(tf) - 1)
        matched += sum(a == b for a, b in zip(tf, tq))
        for i in range(div + 1):
            a, b = lf[i], lq[i]
            cosines.append(a @ b / (np.linalg.norm(a)
                                    * np.linalg.norm(b)))
            compared += 1
    emit("quant_fidelity", tier="int8",
         min_cosine=round(float(np.min(cosines)), 5),
         mean_cosine=round(float(np.mean(cosines)), 5),
         positions_compared=compared,
         greedy_match_frac=round(matched / max(total, 1), 3))


def main() -> None:
    header("table1_fidelity: DSA output fidelity vs token budget")
    base = get_smoke_config("qwen2-0.5b")
    S = 1024
    toks = np.random.default_rng(0).integers(4, base.vocab_size, S)
    nb = S // base.dsa.block_size + 2

    # full attention reference
    cfg_full = dataclasses.replace(
        base, dsa=dataclasses.replace(base.dsa, enabled=False))
    params = M.init_params(cfg_full, jax.random.PRNGKey(0), jnp.float32)
    inp = {"tokens": jnp.asarray(toks[None])}
    _, st_full = M.prefill(params, cfg_full, inp, nb, cache_dtype=jnp.float32)
    ref_logits, _ = M.decode_step(params, cfg_full, jnp.asarray([7]), st_full)
    ref = np.asarray(ref_logits, np.float64)[0]

    for budget in (64, 128, 256, 512, 1024):
        cfg = dataclasses.replace(
            base, dsa=dataclasses.replace(base.dsa, token_budget=budget))
        _, st = M.prefill(params, cfg, inp, nb, cache_dtype=jnp.float32)
        lg, _ = M.decode_step(params, cfg, jnp.asarray([7]), st)
        out = np.asarray(lg, np.float64)[0]
        rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
        cos = float(out @ ref / (np.linalg.norm(out) * np.linalg.norm(ref)))
        same_top1 = int(np.argmax(out) == np.argmax(ref))
        emit("table1", budget=budget, context=S,
             rel_l2=round(float(rel), 5), cosine=round(cos, 5),
             top1_match=same_top1)
    quant_fidelity_section()


if __name__ == "__main__":
    main()
