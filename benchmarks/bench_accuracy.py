"""Paper Table 1 (adapted): DSA fidelity vs token budget.

No pretrained weights ship offline, so task accuracy is reproduced as
ATTENTION-OUTPUT FIDELITY: relative L2 error and cosine similarity of the
DSA decode output vs full attention, per token budget, on real model
forwards with adversarially long contexts.  The paper's claim (budget 2048
retains 99% accuracy) maps to cosine >= 0.99 at budget >= context/4.
"""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_smoke_config
from repro.models import model as M


def main() -> None:
    header("table1_fidelity: DSA output fidelity vs token budget")
    base = get_smoke_config("qwen2-0.5b")
    S = 1024
    toks = np.random.default_rng(0).integers(4, base.vocab_size, S)
    nb = S // base.dsa.block_size + 2

    # full attention reference
    cfg_full = dataclasses.replace(
        base, dsa=dataclasses.replace(base.dsa, enabled=False))
    params = M.init_params(cfg_full, jax.random.PRNGKey(0), jnp.float32)
    inp = {"tokens": jnp.asarray(toks[None])}
    _, st_full = M.prefill(params, cfg_full, inp, nb, cache_dtype=jnp.float32)
    ref_logits, _ = M.decode_step(params, cfg_full, jnp.asarray([7]), st_full)
    ref = np.asarray(ref_logits, np.float64)[0]

    for budget in (64, 128, 256, 512, 1024):
        cfg = dataclasses.replace(
            base, dsa=dataclasses.replace(base.dsa, token_budget=budget))
        _, st = M.prefill(params, cfg, inp, nb, cache_dtype=jnp.float32)
        lg, _ = M.decode_step(params, cfg, jnp.asarray([7]), st)
        out = np.asarray(lg, np.float64)[0]
        rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
        cos = float(out @ ref / (np.linalg.norm(out) * np.linalg.norm(ref)))
        same_top1 = int(np.argmax(out) == np.argmax(ref))
        emit("table1", budget=budget, context=S,
             rel_l2=round(float(rel), 5), cosine=round(cos, 5),
             top1_match=same_top1)


if __name__ == "__main__":
    main()
