"""Paper Fig. 1: token throughput + KV blocks loaded/iter vs batch size.

Offloaded DSA serving (vLLM-SO+FT class) with a saturated queue and FIXED
parallel batch size: throughput first rises with batch size, then collapses
when the aggregate working set overflows the HBM cache (load storm).

The second section measures the REAL engine hot path: with batched
multi-request decode, one iteration runs ONE `decode_step` forward over the
whole decode batch, so decode_step invocations per generated token drop to
1/B — vs the 1-per-token Python loop of the sequential baseline.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace


def sim_section() -> None:
    header("fig1_batch_size: throughput & loads vs fixed batch size "
           "(LWM-7B, offload+FT, saturated queue)")
    cfg = get_config("lwm-7b")
    for bs in (2, 4, 6, 8, 12, 16, 24):
        sim = ServingSimulator(cfg, SYSTEMS["vllm-so+ft"],
                               sim=SimConfig(r_max=bs, seed=0))
        trace = generate_trace(TraceConfig(request_rate=100.0,
                                           num_requests=3 * bs, seed=1,
                                           max_new_tokens=256))
        m = sim.run(trace)
        loads = float(np.mean(sim.loads_per_iter)) if sim.loads_per_iter else 0
        emit("fig1", batch_size=bs,
             tok_per_s=round(m.token_throughput, 2),
             mean_blocks_loaded_per_iter=round(loads, 1))


def engine_section() -> None:
    """Real-execution engine: decode_step launches per generated token,
    batched (1 per iteration) vs sequential (1 per request-token)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request

    header("engine_batched_decode: decode_step invocations per token "
           "(smoke qwen2-0.5b, saturated decode batch)")
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    for bs in (1, 2, 4):
        row = {}
        for batched in (True, False):
            eng = ServingEngine(params, cfg, EngineConfig(
                chunk_size=64, r_max=bs, batched_decode=batched))
            for _ in range(bs):
                eng.submit(Request(prompt_len=64, max_new_tokens=8),
                           tokens=np.arange(5, 69, dtype=np.int32))
            eng.run()
            key = "batched" if batched else "sequential"
            row[f"calls_per_tok_{key}"] = round(
                eng.decode_step_calls / max(eng.decode_tokens, 1), 3)
        emit("engine_decode", batch_size=bs, **row)


def main() -> None:
    sim_section()
    engine_section()


if __name__ == "__main__":
    main()
