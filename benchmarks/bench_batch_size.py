"""Paper Fig. 1: token throughput + KV blocks loaded/iter vs batch size.

Offloaded DSA serving (vLLM-SO+FT class) with a saturated queue and FIXED
parallel batch size: throughput first rises with batch size, then collapses
when the aggregate working set overflows the HBM cache (load storm).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace


def main() -> None:
    header("fig1_batch_size: throughput & loads vs fixed batch size "
           "(LWM-7B, offload+FT, saturated queue)")
    cfg = get_config("lwm-7b")
    for bs in (2, 4, 6, 8, 12, 16, 24):
        sim = ServingSimulator(cfg, SYSTEMS["vllm-so+ft"],
                               sim=SimConfig(r_max=bs, seed=0))
        trace = generate_trace(TraceConfig(request_rate=100.0,
                                           num_requests=3 * bs, seed=1,
                                           max_new_tokens=256))
        m = sim.run(trace)
        loads = float(np.mean(sim.loads_per_iter)) if sim.loads_per_iter else 0
        emit("fig1", batch_size=bs,
             tok_per_s=round(m.token_throughput, 2),
             mean_blocks_loaded_per_iter=round(loads, 1))


if __name__ == "__main__":
    main()
