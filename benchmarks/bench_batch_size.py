"""Paper Fig. 1: token throughput + KV blocks loaded/iter vs batch size.

Offloaded DSA serving (vLLM-SO+FT class) with a saturated queue and FIXED
parallel batch size: throughput first rises with batch size, then collapses
when the aggregate working set overflows the HBM cache (load storm).

The second section measures the REAL engine hot path across all three
decode planes on the same workload:

* ``persistent`` — requests live in a jitted, bucketed DevicePoolPlane:
  ZERO per-iteration stack/unstack copies, jit retraces bounded by the
  bucket count (``jit_cache_hit`` is the fraction of iterations served by
  the compile cache).
* ``stacked`` — legacy: every iteration re-stacks all per-request pools
  into a fresh padded device pool and unstacks it afterwards (one
  ``stack_calls`` per iteration).
* ``sequential`` — one eager forward per request-token.

Run:  PYTHONPATH=src python -m benchmarks.run --only fig1
      (or directly: python benchmarks/bench_batch_size.py)
"""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace


def sim_section() -> None:
    header("fig1_batch_size: throughput & loads vs fixed batch size "
           "(LWM-7B, offload+FT, saturated queue)")
    cfg = get_config("lwm-7b")
    for bs in (2, 4, 6, 8, 12, 16, 24):
        sim = ServingSimulator(cfg, SYSTEMS["vllm-so+ft"],
                               sim=SimConfig(r_max=bs, seed=0))
        trace = generate_trace(TraceConfig(request_rate=100.0,
                                           num_requests=3 * bs, seed=1,
                                           max_new_tokens=256))
        m = sim.run(trace)
        loads = float(np.mean(sim.loads_per_iter)) if sim.loads_per_iter else 0
        emit("fig1", batch_size=bs,
             tok_per_s=round(m.token_throughput, 2),
             mean_blocks_loaded_per_iter=round(loads, 1))


def engine_section() -> None:
    """Real-execution engine: persistent DevicePoolPlane vs the legacy
    stacked path vs the sequential loop — decode_step launches per token,
    full-pool stack/unstack copies per iteration, and the jit compile-cache
    hit rate (retraces bounded by shape buckets)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request

    header("engine_decode_plane: staged vs persistent vs stacked vs "
           "sequential (smoke qwen2-0.5b, saturated decode batch)")
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    modes = (("staged", dict(batched_decode=True, decode_plane="staged")),
             ("persistent", dict(batched_decode=True,
                                 decode_plane="persistent")),
             ("stacked", dict(batched_decode=True, decode_plane="stacked")),
             ("sequential", dict(batched_decode=False)))
    for bs in (1, 2, 4, 8):
        for mode, kw in modes:
            eng = ServingEngine(params, cfg, EngineConfig(
                chunk_size=64, r_max=bs, **kw))
            for _ in range(bs):
                eng.submit(Request(prompt_len=64, max_new_tokens=8),
                           tokens=np.arange(5, 69, dtype=np.int32))
            from repro.core.device_pool import (decode_fn_for,
                                                staged_fns_for)
            fn = (staged_fns_for(cfg, eng.eng.attn_impl)
                  if mode == "staged"
                  else decode_fn_for(cfg, eng.eng.attn_impl))
            traces0, calls0 = fn.trace_count, fn.calls
            t0 = time.perf_counter()
            eng.run()
            wall = time.perf_counter() - t0
            row = dict(
                batch_size=bs, mode=mode,
                calls_per_tok=round(
                    eng.decode_step_calls / max(eng.decode_tokens, 1), 3),
                # per DECODE iteration (prefill-only iterations don't stack)
                stack_unstack_per_decode=round(
                    eng.stack_calls / max(eng.decode_step_calls, 1), 3),
                wall_s=round(wall, 2))
            if mode in ("staged", "persistent") and eng.planes:
                [plane] = eng.planes.values()
                # staged pays O(num_layers) LAUNCHES per iteration; both
                # planes keep TRACES bounded by the shape-bucket grid
                launches = fn.calls - calls0
                row.update(
                    jit_traces=fn.trace_count - traces0,
                    jit_cache_hit=round(
                        1.0 - (fn.trace_count - traces0)
                        / max(launches, 1), 3),
                    launches_per_iter=round(
                        launches / max(eng.decode_step_calls, 1), 2),
                    device_pool_mib=round(plane.device_bytes() / 2**20, 2),
                    rows_reused=plane.rows_reused)
            emit("engine_decode", **row)


def main() -> None:
    sim_section()
    engine_section()


if __name__ == "__main__":
    main()
