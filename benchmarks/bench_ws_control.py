"""Paper Fig. 15: working-set-aware batch size control — token throughput
and mean KV block loads/iteration, with and without WC, vs request rate."""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace


def main() -> None:
    header("fig15_ws_control: throughput & loads with/without WC")
    cfg = get_config("lwm-7b")
    for rate in (0.3, 0.5, 0.7, 1.0, 1.5):
        row = {"rate": rate}
        for label, system in (("no_wc", "vllm-so+ft"),
                              ("wc", "vllm-so+ft+wc")):
            sim = ServingSimulator(cfg, SYSTEMS[system], sim=SimConfig(seed=0))
            trace = generate_trace(TraceConfig(request_rate=rate,
                                               num_requests=24, seed=4))
            m = sim.run(trace)
            loads = float(np.mean(sim.loads_per_iter)) \
                if sim.loads_per_iter else 0.0
            row[f"tok_per_s_{label}"] = round(m.token_throughput, 2)
            row[f"loads_{label}"] = round(loads, 1)
        emit("fig15", **row)


if __name__ == "__main__":
    main()
