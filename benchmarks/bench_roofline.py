"""Roofline summary (deliverable g): prints the calibrated 3-term table
from the dry-run artifacts in results/.

    PYTHONPATH=src python -m benchmarks.bench_roofline

If results/roofline_pod1.json is missing, regenerate with:
    python -m repro.launch.dryrun --arch all --shape all --out results/dryrun_pod1.json
    python -m repro.launch.calibrate_run
"""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

import json
import os

from benchmarks.common import emit, header

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def main() -> None:
    path = os.path.join(RESULTS, "roofline_pod1.json")
    if not os.path.exists(path):
        print(f"roofline: {path} not found — run the dry-run + calibration "
              f"first (see module docstring); skipping")
        return
    header("roofline: calibrated terms per (arch x shape), 16x16 mesh")
    with open(path) as f:
        recs = json.load(f)["records"]
    for r in recs:
        c = r.get("calibrated") or {}
        if "t_compute_s" not in c:
            continue
        emit("roofline", arch=r["arch"], shape=r["shape"],
             t_compute_s=round(c["t_compute_s"], 6),
             t_memory_s=round(c["t_memory_s"], 6),
             t_collective_s=round(c["t_collective_s"], 6),
             dominant=c["dominant"],
             useful=round(c["useful_flops_ratio"], 3))
    opt = os.path.join(RESULTS, "optimized_pod1.json")
    if os.path.exists(opt):
        header("roofline: optimized (§Perf) variant per-device footprints")
        with open(opt) as f:
            orecs = json.load(f)["records"]
        for r in orecs:
            m = r.get("memory", {})
            emit("optimized", arch=r["arch"], shape=r["shape"],
                 variant=r["variant"],
                 arg_gb=round(m.get("argument_size_in_bytes", 0) / 1e9, 2),
                 coll_mb=round(r.get("collectives", {}).get(
                     "bytes_per_device", 0) / 1e6, 1))


if __name__ == "__main__":
    main()
