"""Paper Figs. 10-12: mean TTFT / token throughput / mean TBT vs request
rate for vLLM / vLLM-S / vLLM-SO / SparseServe (LWM-7B + Llama3-8B,
LongBench-shaped trace, discrete-event simulator on the A100 cost model)."""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace

RATES = {"lwm-7b": (0.05, 0.1, 0.125, 0.15, 0.2),
         "llama3-8b": (0.1, 0.2, 0.25, 0.3, 0.4)}
MAXLEN = {"lwm-7b": 32768, "llama3-8b": 131072}
SYSTEMS_RUN = ("vllm", "vllm-s", "vllm-so", "sparseserve")


def main(num_requests: int = 32) -> None:
    header("fig10-12_e2e: TTFT/throughput/TBT vs request rate")
    for model in ("lwm-7b", "llama3-8b"):
        cfg = get_config(model)
        for rate in RATES[model]:
            for name in SYSTEMS_RUN:
                sim = ServingSimulator(cfg, SYSTEMS[name], sim=SimConfig())
                trace = generate_trace(TraceConfig(
                    request_rate=rate, num_requests=num_requests,
                    max_prompt_len=MAXLEN[model], seed=2))
                m = sim.run(trace)
                emit("e2e", model=model, system=name, rate=rate,
                     ttft_s=round(m.mean_ttft, 3),
                     tbt_ms=round(m.mean_tbt * 1e3, 2),
                     tok_per_s=round(m.token_throughput, 2),
                     finished=m.num_finished)


if __name__ == "__main__":
    main()
