"""Paper Figs. 10-12: mean TTFT / token throughput / mean TBT vs request
rate for vLLM / vLLM-S / vLLM-SO / SparseServe (LWM-7B + Llama3-8B,
LongBench-shaped trace, discrete-event simulator on the A100 cost model).

Plus `hybrid_plane`: the REAL engine on a staggered-arrival workload under
the mixed single-iteration plane (prefill segments riding decode layer
walks, one fused host stage per layer) vs the "split" two-plane oracle —
TTFT/TBT, jitted launches per iteration, and fused FlashD2H/H2D call
counts (greedy outputs are asserted byte-identical in
tests/test_hybrid_plane.py)."""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace

RATES = {"lwm-7b": (0.05, 0.1, 0.125, 0.15, 0.2),
         "llama3-8b": (0.1, 0.2, 0.25, 0.3, 0.4)}
MAXLEN = {"lwm-7b": 32768, "llama3-8b": 131072}
SYSTEMS_RUN = ("vllm", "vllm-s", "vllm-so", "sparseserve")


def hybrid_plane_vs_split() -> None:
    """Real engine, staggered arrivals: the mixed single-iteration plane
    vs the split two-plane oracle on the same workload."""
    header("hybrid_plane: mixed single-iteration plane vs split oracle")
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request

    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = (96, 96, 64, 64, 96, 64)
    rows = {}
    for mode in ("split", "mixed"):
        eng = ServingEngine(params, cfg, EngineConfig(
            r_max=4, chunk_size=64, hybrid_plane=mode,
            prefill_max_tokens_per_step=32))
        rng = np.random.default_rng(0)
        for i, p in enumerate(prompts):
            # arrivals spaced so later admissions land mid-decode of the
            # earlier rows: every iteration kind (pure prefill, pure
            # decode, truly mixed) occurs
            eng.submit(Request(prompt_len=p, max_new_tokens=6,
                               arrival_time=i * 3e-5),
                       tokens=rng.integers(4, cfg.vocab_size,
                                           p).astype(np.int32))
        m = eng.run()
        s = eng.metrics_snapshot()
        log = eng.mixed_iter_log
        rows[mode] = dict(
            mode=mode,
            mean_ttft_s=round(m.mean_ttft, 6),
            mean_tbt_ms=round(m.mean_tbt * 1e3, 3),
            iterations=eng.iterations,
            launches_per_iter=(round(sum(e["launches"] for e in log)
                                     / max(len(log), 1), 2) if log else 0),
            mixed_iter_frac=(round(sum(1 for e in log
                                       if e["decode_rows"] > 0
                                       and e["prefill_rows"] > 0)
                                   / max(len(log), 1), 3) if log else 0.0),
            d2h_calls=int(s["kv.d2h_calls"]),
            h2d_calls=int(s["kv.h2d_calls"]))
    rows["mixed"]["ttft_split_over_mixed"] = round(
        rows["split"]["mean_ttft_s"]
        / max(rows["mixed"]["mean_ttft_s"], 1e-9), 3)
    for mode in ("split", "mixed"):
        emit("hybrid_plane", **rows[mode])


def main(num_requests: int = 32) -> None:
    header("fig10-12_e2e: TTFT/throughput/TBT vs request rate")
    for model in ("lwm-7b", "llama3-8b"):
        cfg = get_config(model)
        for rate in RATES[model]:
            for name in SYSTEMS_RUN:
                sim = ServingSimulator(cfg, SYSTEMS[name], sim=SimConfig())
                trace = generate_trace(TraceConfig(
                    request_rate=rate, num_requests=num_requests,
                    max_prompt_len=MAXLEN[model], seed=2))
                m = sim.run(trace)
                emit("e2e", model=model, system=name, rate=rate,
                     ttft_s=round(m.mean_ttft, 3),
                     tbt_ms=round(m.mean_tbt * 1e3, 2),
                     tok_per_s=round(m.token_throughput, 2),
                     finished=m.num_finished)
    hybrid_plane_vs_split()


if __name__ == "__main__":
    main()
