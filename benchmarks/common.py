"""Shared benchmark helpers: CSV emission, timing.

Output convention (consumed by benchmarks/README.md schemas and any
plotting scripts): one ``name,key=value,...`` line per data point on
stdout, where ``name`` identifies the series within the figure.  Section
headers are ``### title`` lines; everything else is free-form progress
text.  Stdout is flushed per line so long sweeps stream.
"""
from __future__ import annotations

import sys
import time
from typing import Any, Iterable


def emit(name: str, **fields: Any) -> None:
    """Print one CSV data point: ``name,key=value,...``."""
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{name},{kv}", flush=True)


def header(title: str) -> None:
    """Print a ``### title`` section header."""
    print(f"\n### {title}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
