"""Shared benchmark helpers: CSV emission, timing, JSON capture.

Output convention (consumed by benchmarks/README.md schemas and any
plotting scripts): one ``name,key=value,...`` line per data point on
stdout, where ``name`` identifies the series within the figure.  Section
headers are ``### title`` lines; everything else is free-form progress
text.  Stdout is flushed per line so long sweeps stream.

Machine-readable capture (``benchmarks/run.py --json PATH``): while a
capture is active, every ``emit`` call is ALSO recorded as a dict
(``{"series", "section", **fields}``) so the harness can dump the exact
same data points as JSON — the CSV lines on stdout stay byte-identical.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

_capture: Optional[List[Dict[str, Any]]] = None
_section: Optional[str] = None


def _jsonable(v: Any) -> Any:
    return v if isinstance(v, (int, float, str, bool)) or v is None else str(v)


def begin_capture() -> None:
    """Start recording emitted data points (run.py --json)."""
    global _capture, _section
    _capture = []
    _section = None


def end_capture() -> List[Dict[str, Any]]:
    """Stop recording; returns the rows captured since begin_capture."""
    global _capture, _section
    rows, _capture, _section = _capture or [], None, None
    return rows


def emit(name: str, **fields: Any) -> None:
    """Print one CSV data point: ``name,key=value,...``."""
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{name},{kv}", flush=True)
    if _capture is not None:
        row: Dict[str, Any] = {"series": name, "section": _section}
        row.update({k: _jsonable(v) for k, v in fields.items()})
        _capture.append(row)


def header(title: str) -> None:
    """Print a ``### title`` section header."""
    global _section
    print(f"\n### {title}", flush=True)
    if _capture is not None:
        _section = title


# Default engine-snapshot fields benches emit per run; dots become
# underscores so the CSV keys stay shell-friendly.
TRANSFER_KEYS = ("kv.h2d_calls", "kv.h2d_blocks", "kv.h2d_bytes",
                 "kv.d2h_calls", "kv.d2h_bytes",
                 "kv.hits", "kv.misses", "kv.evictions")


def emit_engine_metrics(name: str, eng: Any, keys=TRANSFER_KEYS,
                        **extra: Any) -> None:
    """Emit one row of ``engine.metrics_snapshot()`` fields — the obs
    surface replaces per-bench TransferStats plumbing (``s.h2d_calls``
    reads scattered through every bench)."""
    snap = eng.metrics_snapshot()
    fields: Dict[str, Any] = {k.replace(".", "_"): snap[k] for k in keys}
    fields.update(extra)
    emit(name, **fields)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
