"""Shared benchmark helpers: CSV emission, default model/trace configs."""
from __future__ import annotations

import sys
import time
from typing import Any, Iterable


def emit(name: str, **fields: Any) -> None:
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{name},{kv}", flush=True)


def header(title: str) -> None:
    print(f"\n### {title}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
