"""Paper Fig. 16: layer-segmented vs chunked prefill.

(a) mean TTFT vs request rate (simulator; layer-segmented avoids the
    whole-prompt HBM residency that head-of-line-blocks chunked prefill).
(b) prefill-attention overhead vs token chunk size, normalized to plain
    prefill: chunked re-reads all preceding chunks' KV (O(S^2/c) extra);
    layer-segmented processes each layer once (==plain).  Computed from
    exact attention FLOP accounting.
(c) REAL-execution cross-check on the tiny engine: HBM peak during prefill
    (token-layer units) for both modes.
(d) prefill_plane: the batched jitted PrefillPlane vs the legacy
    per-request executor on the same concurrent workload — jitted launches
    per executed segment (ONE per (layer, chunk) group vs none/legacy),
    jit traces vs shape signatures, fused-D2H launch counts, mean TTFT
    (modeled), and the batched HBM watermark.
"""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config, get_smoke_config
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace


def fig16a_ttft() -> None:
    """High request rates -> many decode working sets resident -> chunked
    prefill (whole-prompt WS) head-of-line blocks; layer-segmented prefill
    (one-layer WS) keeps admitting."""
    header("fig16a: mean TTFT, chunked vs layer-segmented prefill")
    cfg = get_config("lwm-7b")
    for rate in (0.4, 0.8, 1.2, 2.0):
        row = {"rate": rate}
        for label, system in (("chunked", "vllm-so+ft+wc"),
                              ("layer_seg", "sparseserve")):
            sim = ServingSimulator(cfg, SYSTEMS[system], sim=SimConfig(seed=0))
            trace = generate_trace(TraceConfig(request_rate=rate,
                                               num_requests=32, seed=5))
            m = sim.run(trace)
            row[f"ttft_{label}_s"] = round(m.mean_ttft, 3)
        row["speedup"] = round(row["ttft_chunked_s"]
                               / max(row["ttft_layer_seg_s"], 1e-9), 2)
        emit("fig16a", **row)


def fig16b_attention_overhead() -> None:
    """Chunked prefill re-READS the KV of all preceding chunks from HBM for
    every new chunk (the paper: 1.51x slowdown at chunk 512); plain and
    layer-segmented prefill stream each KV once.  Attention time is modeled
    as max(flops, kv-bytes) on A100 constants."""
    header("fig16b: prefill attention time normalized to plain prefill")
    from repro.serving import costmodel as cm
    cfg = get_config("lwm-7b")
    mc = cm.ModelCost.from_config(cfg)
    hw = cm.A100_40G
    S = 16384
    kv_tok = mc.kv_bytes_per_token / mc.num_layers     # one layer
    flops = 4 * mc.n_heads * mc.head_dim * (S * S / 2)  # qk+pv causal
    t_flops = flops / (hw.peak_flops * hw.mfu)
    # additive flops+reads: re-reading old KV is extra HBM traffic that the
    # low-arithmetic-intensity chunk kernels cannot hide
    t_plain = t_flops + S * kv_tok / (hw.hbm_bw * hw.mbu)
    for chunk in (512, 1024, 2048, 4096, 16384):
        n_chunks = S // chunk
        reads = sum((c + 1) * chunk for c in range(n_chunks)) * kv_tok
        t_chunked = t_flops + reads / (hw.hbm_bw * hw.mbu)
        emit("fig16b", chunk=chunk,
             chunked_norm=round(t_chunked / t_plain, 3),
             layer_segmented_norm=1.0)  # each layer streamed exactly once


def fig16c_real_hbm_peak() -> None:
    header("fig16c: real-engine prefill HBM peak (token-layer units)")
    from repro.models import model as M
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    for mode in ("chunked", "layer_segmented"):
        eng = ServingEngine(params, cfg, EngineConfig(
            prefill_mode=mode, chunk_size=64))
        eng.submit(Request(prompt_len=192, max_new_tokens=2))
        eng.run()
        emit("fig16c", mode=mode,
             hbm_peak_token_layers=eng.prefill_hbm_peak_tokens,
             bound=("one_layer(=prompt)" if mode == "layer_segmented"
                    else "prompt*layers"))


def prefill_plane_vs_legacy() -> None:
    """Real engine, 4 concurrent prompts: the batched plane vs the legacy
    per-request layer-segmented executor (greedy outputs are asserted
    token-identical in tests/test_prefill_plane.py)."""
    header("prefill_plane: batched jitted plane vs legacy executor")
    from repro.models import model as M
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request
    import numpy as np

    from repro.core.prefill_plane import prefill_fns_for

    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = (192, 192, 160, 160)
    fns = prefill_fns_for(cfg)          # process-global per config: report
                                        # per-mode DELTAS, not running totals
    for mode, kw in (("plane", {}),
                     ("plane_chunked",
                      {"prefill_max_tokens_per_step": 64}),
                     ("legacy", {"prefill_exec": "legacy"})):
        traces0 = fns.trace_count
        eng = ServingEngine(params, cfg, EngineConfig(
            r_max=4, max_inject_tokens=8192, **kw))
        rng = np.random.default_rng(0)
        for p in prompts:
            eng.submit(Request(prompt_len=p, max_new_tokens=2),
                       tokens=rng.integers(4, cfg.vocab_size,
                                           p).astype(np.int32))
        m = eng.run()
        n_segments = cfg.num_layers * sum(
            -(-p // (kw.get("prefill_max_tokens_per_step") or p))
            for p in prompts)
        emit("prefill_plane", mode=mode,
             launches=eng.prefill_launches,
             segments=n_segments,
             launches_per_segment=round(
                 eng.prefill_launches / max(n_segments, 1), 3),
             jit_traces=fns.trace_count - traces0,
             jit_cache_hit=int(fns.trace_count
                               == len(fns.shape_signatures)),
             d2h_calls=int(eng.metrics_snapshot()["kv.d2h_calls"]),
             mean_ttft_s=round(m.mean_ttft, 6),
             hbm_peak_token_layers=eng.prefill_hbm_peak_tokens)


def main() -> None:
    fig16a_ttft()
    fig16b_attention_overhead()
    fig16c_real_hbm_peak()
    prefill_plane_vs_legacy()


if __name__ == "__main__":
    main()
