"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,fig8,...]

Emits ``name,key=value,...`` CSV lines per figure (see each module's
docstring for the paper artifact it reproduces).
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = {
    "fig1": "benchmarks.bench_batch_size",
    "fig4_14": "benchmarks.bench_transfer",
    "fig8": "benchmarks.bench_overlap",
    "fig10_12": "benchmarks.bench_e2e",
    "fig13": "benchmarks.bench_goodput",
    "fig15": "benchmarks.bench_ws_control",
    "fig16": "benchmarks.bench_prefill",
    "table1": "benchmarks.bench_accuracy",
    "roofline": "benchmarks.bench_roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help=f"comma list of {list(MODULES)}")
    args = ap.parse_args()
    names = [n.strip() for n in args.only.split(",") if n.strip()] \
        or list(MODULES)
    import importlib
    t0 = time.perf_counter()
    failures = []
    for name in names:
        mod = importlib.import_module(MODULES[name])
        t = time.perf_counter()
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"BENCH FAIL {name}: {type(e).__name__}: {e}", flush=True)
        print(f"[{name} done in {time.perf_counter()-t:.1f}s]", flush=True)
    print(f"\nall benchmarks done in {time.perf_counter()-t0:.1f}s; "
          f"{len(failures)} failed {failures or ''}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
