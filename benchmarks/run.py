"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,fig8,...] [--list]

Also works as a plain script from ANY working directory (no PYTHONPATH
needed — the repo root and src/ are put on sys.path automatically):

    python benchmarks/run.py --only fig1

Every bench module is equally invocable on its own, either way:

    PYTHONPATH=src python -m benchmarks.bench_batch_size
    python benchmarks/bench_batch_size.py

Output is ``name,key=value,...`` CSV lines per figure on stdout (see
benchmarks/README.md for each module's output schema and the paper
artifact it reproduces).  Flags:

    --only   comma-separated subset of the names below (default: all)
    --list   print the available names and their modules, then exit
    --json   ALSO write every emitted data point to PATH as JSON
             ({"schema": 1, "benchmarks": {name: {"status", "seconds",
             "rows": [{"series", "section", ...fields}]}}}) — the
             machine-readable artifact nightly CI uploads so the perf
             trajectory accumulates (benchmarks/README.md §JSON schema)
"""
from __future__ import annotations

import argparse
import json
import os as _os
import sys
import time

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                if p not in sys.path]

MODULES = {
    "fig1": "benchmarks.bench_batch_size",
    "fig4_14": "benchmarks.bench_transfer",
    "fig8": "benchmarks.bench_overlap",
    "fig10_12": "benchmarks.bench_e2e",
    "fig13": "benchmarks.bench_goodput",
    "fig15": "benchmarks.bench_ws_control",
    "fig16": "benchmarks.bench_prefill",
    "table1": "benchmarks.bench_accuracy",
    "roofline": "benchmarks.bench_roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Run the paper-figure benchmarks (see benchmarks/"
                    "README.md for per-figure output schemas)")
    ap.add_argument("--only", default="",
                    help=f"comma list of {list(MODULES)} (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="list available benchmarks and exit")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write the emitted data points to PATH as "
                         "JSON (schema: benchmarks/README.md)")
    args = ap.parse_args()
    if args.list:
        for name, mod in MODULES.items():
            print(f"{name:10s} {mod}")
        return
    names = [n.strip() for n in args.only.split(",") if n.strip()] \
        or list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; choose from "
                 f"{list(MODULES)}")
    import importlib

    from benchmarks import common
    t0 = time.perf_counter()
    failures = []
    results = {}
    for name in names:
        t = time.perf_counter()
        common.begin_capture()
        err = ""
        try:
            mod = importlib.import_module(MODULES[name])
            mod.main()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            err = f"{type(e).__name__}: {e}"
            print(f"BENCH FAIL {name}: {err}", flush=True)
        dt = time.perf_counter() - t
        results[name] = {"status": "fail" if err else "ok",
                         "seconds": round(dt, 2),
                         "rows": common.end_capture()}
        if err:
            results[name]["error"] = err
        print(f"[{name} done in {dt:.1f}s]", flush=True)
    if args.json:
        out_dir = _os.path.dirname(_os.path.abspath(args.json))
        _os.makedirs(out_dir, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "benchmarks": results}, f, indent=1)
        print(f"wrote {args.json}", flush=True)
    print(f"\nall benchmarks done in {time.perf_counter()-t0:.1f}s; "
          f"{len(failures)} failed {failures or ''}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
