"""Paper Fig. 13: goodput ladder — max sustainable request rate under SLO
(P99 TBT <= 25x decode iter, mean queue delay <= 2 s) as each SparseServe
mechanism is added: SA -> +Offload -> +FT -> +WC -> +LP."""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.serving.metrics import meets_slo
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace

LADDER = ("vllm", "vllm-s", "vllm-so", "vllm-so+ft", "vllm-so+ft+wc",
          "sparseserve")


def max_goodput(model_cfg, system, rates, n=24) -> float:
    best = 0.0
    for rate in rates:
        sim = ServingSimulator(model_cfg, SYSTEMS[system],
                               sim=SimConfig(seed=0))
        trace = generate_trace(TraceConfig(request_rate=rate,
                                           num_requests=n, seed=3))
        m = sim.run(trace)
        lim = 25 * max(sim.decode_iter_time, 1e-3)
        reqs = trace
        if m.num_finished == n and meets_slo(reqs, m.total_time,
                                             p99_tbt_limit=lim):
            best = max(best, rate)
    return best


def main() -> None:
    header("fig13_goodput: max sustainable rate under SLO, mechanism ladder")
    rates = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.4, 0.5)
    for model in ("lwm-7b",):
        cfg = get_config(model)
        base = None
        for system in LADDER:
            g = max_goodput(cfg, system, rates)
            if base is None and g > 0:
                base = g
            emit("fig13", model=model, system=system, goodput_rps=g,
                 vs_vllm=round(g / base, 2) if base else 0.0)


if __name__ == "__main__":
    main()
