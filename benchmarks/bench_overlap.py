"""Paper Fig. 8 (selection overlap) + the staged-vs-fused decode plane.

fig8: runs the REAL tiny model: decode steps with DSA selection enabled,
then for each window size w computes the mean fraction of step-t selections
already present in the union of the previous w steps' selections — the
temporal locality that justifies the working-set estimator (w=12 plateaus).

overlap_plane: runs the REAL engine under eviction pressure (1-block LRU)
on the staged per-layer pipeline vs the fused persistent plane and reports,
per plane: jitted launches per decode iteration (staged pays O(num_layers)
launches to buy the restore window), the restore-before-use rate (fraction
of H2D block restores that landed between select and attend — 1.0 on the
staged plane, 0.0 on the fused plane, where restores can only land after
the forward), and the MODELED per-iteration decode time under the fused
plane's sum charging (compute + all transfers serial) vs the staged
pipeline's per-layer max(compute, transfer) overlap charging
(``modeled_*`` fields — cost-model numbers, not wall clock).

achieved_overlap: the MEASURED counterpart — runs the real engine under
the same 1-block-LRU pressure with ``stage_dispatch="sync"`` vs
``"async"`` (the default) and reports wall-clock tokens/s plus the
per-layer dispatch timeline the planes record (`stage_timeline`): how
much of each layer's host stage the async pipeline moved off the
dispatch thread (`measured_overlap_fraction`), next to the cost model's
max(compute, transfer) bound for reference.
"""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_smoke_config
from repro.models import model as M


def fig8_section() -> None:
    header("fig8_overlap: selection overlap vs window size (real decode)")
    base = get_smoke_config("qwen2-0.5b")
    # small budget so selection is actually sparse (8 of 24 blocks)
    cfg = dataclasses.replace(
        base, dsa=dataclasses.replace(base.dsa, token_budget=8 * 32))
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    S, steps = 736, 48
    toks = np.random.default_rng(0).integers(4, cfg.vocab_size, S)
    nb = S // cfg.dsa.block_size + 4
    logits, state = M.prefill(params, cfg,
                              {"tokens": jnp.asarray(toks[None])}, nb,
                              cache_dtype=jnp.float32)
    history = []          # per step: set of (layer, block)
    tok = int(jnp.argmax(logits[0]))
    for _ in range(steps):
        logits, state, info = M.decode_step(
            params, cfg, jnp.asarray([tok], jnp.int32), state,
            return_info=True)
        sel = set()
        for l, s in info["selected"].items():
            for b in np.asarray(s[0]).ravel():
                sel.add((int(l), int(b)))
        history.append(sel)
        tok = int(jnp.argmax(logits[0]))

    for w in (1, 2, 4, 8, 12, 16):
        ratios = []
        for t in range(w, len(history)):
            union = set()
            for s in history[t - w:t]:
                union |= s
            if history[t]:
                ratios.append(len(history[t] & union) / len(history[t]))
        emit("fig8", window=w, overlap=round(float(np.mean(ratios)), 4))


def staged_vs_fused_section() -> None:
    """Real-engine comparison of the staged per-layer pipeline against the
    fused persistent plane under eviction pressure (see module docstring
    for the emitted fields)."""
    from repro.core.device_pool import decode_fn_for, staged_fns_for
    from repro.serving import costmodel as cm
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request

    header("overlap_plane: staged vs fused decode plane "
           "(real engine, 1-block LRU eviction pressure)")
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    hw = cm.TPU_V5E
    for mode in ("staged", "persistent"):
        eng = ServingEngine(params, cfg, EngineConfig(
            chunk_size=64, r_max=4, decode_plane=mode,
            hbm_blocks_per_request=1))
        fns = staged_fns_for(cfg, "ref")
        fused = decode_fn_for(cfg, "ref")
        calls0 = fns.calls if mode == "staged" else fused.calls
        rng = np.random.default_rng(3)
        for _ in range(3):
            eng.submit(Request(prompt_len=64, max_new_tokens=12),
                       tokens=rng.integers(4, cfg.vocab_size,
                                           64).astype(np.int32))
        eng.run()
        iters = max(eng.decode_step_calls, 1)
        calls = (fns.calls if mode == "staged" else fused.calls) - calls0
        [plane] = eng.planes.values()
        rate = plane.blocks_restored_before_use \
            / max(plane.blocks_restored, 1)
        # modeled per-iteration decode time from the measured mean restore
        # traffic: fused = compute + all transfer serial; staged = per-layer
        # max(compute, transfer) with the traffic split across attn layers
        mean_loads = sum(eng.loads_per_iter) / max(len(eng.loads_per_iter), 1)
        bytes_per_iter = mean_loads * eng.geom.block_bytes_per_head \
            * eng.geom.num_kv_heads
        attended = min(cfg.dsa.token_budget, 1 << 30)
        t_sum = cm.decode_time(hw, eng.mc, 3, attended) \
            + cm.fused_transfer_time(hw, int(bytes_per_iter))
        n_attn = cfg.num_attention_layers()
        per_layer = [int(bytes_per_iter // n_attn)
                     if M.layer_kind(cfg, l) == "attn" else 0
                     for l in range(cfg.num_layers)]
        t_overlap = cm.overlapped_decode_time(hw, eng.mc, 3, attended,
                                              per_layer)
        emit("overlap_plane", mode=mode,
             launches_per_iter=round(calls / iters, 2),
             restore_before_use_rate=round(rate, 3),
             blocks_dropped=plane.blocks_dropped,
             modeled_t_iter_sum_ms=round(t_sum * 1e3, 4),
             modeled_t_iter_overlap_ms=round(t_overlap * 1e3, 4),
             modeled_overlap_speedup=round(t_sum / max(t_overlap, 1e-12), 3))


def achieved_overlap_section() -> None:
    """Measured (wall-clock) async-dispatch overlap: the same engine and
    eviction pressure as ``overlap_plane``, sync vs async stage dispatch,
    with the obs layer enabled so the async run produces a Chrome trace.

    Per mode: end-to-end wall seconds and decode tokens/s, plus the
    last-iteration per-layer dispatch timeline the staged plane records —
    ``dispatch_sync_ms`` (the driver's np.asarray of the selection
    tensor, the one allowed per-layer block) and ``host_stage_ms`` (the
    stage callback: FlashD2H write-back, LRU, FlashH2D restores).  The
    summary row pins the async run's achieved overlap with TWO
    independent instruments over the SAME run:

    - ``measured_overlap_fraction`` — counters: worker ``busy_s`` over
      (busy_s + the plane's accumulated ``host_stage_s``), the fraction
      of host-stage work that ran off the dispatch thread
      (``engine.stage_overlap_measured()``);
    - ``achieved_overlap_fraction`` — the trace: worker-span intervals
      intersected with iteration spans over (that + dispatch host-stage
      spans), from ``obs.trace_analysis`` — nightly asserts the two
      agree within 10%.

    ``host_stage_shrink_fraction`` keeps the old cross-run view (how much
    the dispatch-thread host stage shrank vs sync).  Wall speedups stay
    informational on CPU smoke hardware (noise); with
    ``REPRO_TRACE_DIR`` set the async run's ``.trace.json`` is written
    there (the nightly artifact next to BENCH_*.json)."""
    from benchmarks.common import Timer
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request

    header("achieved_overlap: sync vs async stage dispatch "
           "(real engine wall clock, 1-block LRU eviction pressure)")
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    stage_ms = {}
    wall = {}
    engines = {}
    for mode in ("sync", "async"):
        eng = ServingEngine(params, cfg, EngineConfig(
            chunk_size=64, r_max=4, hybrid_plane="split",
            hbm_blocks_per_request=1, stage_dispatch=mode, obs=True))
        rng = np.random.default_rng(3)
        for _ in range(3):
            eng.submit(Request(prompt_len=64, max_new_tokens=12),
                       tokens=rng.integers(4, cfg.vocab_size,
                                           64).astype(np.int32))
        with Timer() as t:
            eng.run()
        engines[mode] = eng
        [plane] = eng.planes.values()
        tl = plane.stage_timeline            # last decode iteration
        sync_ms = sum(s for _, s, _ in tl) * 1e3
        host_ms = sum(h for _, _, h in tl) * 1e3
        toks = eng.decode_tokens
        wall[mode] = t.dt
        stage_ms[mode] = host_ms
        emit("achieved_overlap", mode=mode,
             wall_s=round(t.dt, 3),
             decode_tok_per_s=round(toks / max(t.dt, 1e-9), 2),
             dispatch_sync_ms=round(sync_ms, 4),
             host_stage_ms=round(host_ms, 4),
             host_syncs=plane.host_syncs,
             timeline_layers=len(tl))
    a = engines["async"]
    measured = a.stage_overlap_measured()
    achieved = a.stage_overlap_from_trace()
    emit("achieved_overlap", mode="summary",
         measured_overlap_fraction=(round(measured, 6)
                                    if measured is not None else None),
         achieved_overlap_fraction=(round(achieved, 6)
                                    if achieved is not None else None),
         worker_jobs_run=a.worker_jobs_run,
         host_stage_shrink_fraction=round(
             max(0.0, 1.0 - stage_ms["async"] / max(stage_ms["sync"],
                                                    1e-12)), 3),
         async_wall_speedup=round(wall["sync"] / max(wall["async"], 1e-12),
                                  3))
    tdir = _os.environ.get("REPRO_TRACE_DIR", "")
    if tdir:
        _os.makedirs(tdir, exist_ok=True)
        path = _os.path.join(tdir, "fig8_achieved_overlap.trace.json")
        n = engines["async"].dump_trace(path)
        emit("achieved_overlap", mode="trace", path=path, events=n)


def main() -> None:
    fig8_section()
    staged_vs_fused_section()
    achieved_overlap_section()


if __name__ == "__main__":
    main()
