"""Paper Fig. 8: selection-overlap ratio vs history window size.

Runs the REAL tiny model: decode steps with DSA selection enabled, then for
each window size w computes the mean fraction of step-t selections already
present in the union of the previous w steps' selections — the temporal
locality that justifies the working-set estimator (w=12 plateaus).
"""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_smoke_config
from repro.models import model as M


def main() -> None:
    header("fig8_overlap: selection overlap vs window size (real decode)")
    base = get_smoke_config("qwen2-0.5b")
    # small budget so selection is actually sparse (8 of 24 blocks)
    cfg = dataclasses.replace(
        base, dsa=dataclasses.replace(base.dsa, token_budget=8 * 32))
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    S, steps = 736, 48
    toks = np.random.default_rng(0).integers(4, cfg.vocab_size, S)
    nb = S // cfg.dsa.block_size + 4
    logits, state = M.prefill(params, cfg,
                              {"tokens": jnp.asarray(toks[None])}, nb,
                              cache_dtype=jnp.float32)
    history = []          # per step: set of (layer, block)
    tok = int(jnp.argmax(logits[0]))
    for _ in range(steps):
        logits, state, info = M.decode_step(
            params, cfg, jnp.asarray([tok], jnp.int32), state,
            return_info=True)
        sel = set()
        for l, s in info["selected"].items():
            for b in np.asarray(s[0]).ravel():
                sel.add((int(l), int(b)))
        history.append(sel)
        tok = int(jnp.argmax(logits[0]))

    for w in (1, 2, 4, 8, 12, 16):
        ratios = []
        for t in range(w, len(history)):
            union = set()
            for s in history[t - w:t]:
                union |= s
            if history[t]:
                ratios.append(len(history[t] & union) / len(history[t]))
        emit("fig8", window=w, overlap=round(float(np.mean(ratios)), 4))


if __name__ == "__main__":
    main()
