"""Paper Fig. 4 + Fig. 14: fragmentation-aware transfer.

(a) Effective PCIe bandwidth of KV loading/saving vs block size: per-block
    memcpy vs fused FlashH2D/D2H (analytic transfer model, A100 constants —
    reproduces the paper's >20 GB/s vs <6 GB/s split).
(b) Fig. 14a: mean batch latency share of KV loading, memcpy vs FlashH2D.
(c) Fig. 14b: prefill latency normalized to compute: memcpy / GPU-direct /
    FlashD2H saving.
(d) Real-execution micro-bench: fused gather kernel (ONE launch) vs
    per-block copy loop on the host pool data plane (wall time, CPU).
(e) quant_tier: the REAL engine, fp vs int8 DRAM offload tier
    (``EngineConfig.offload_quant``) under 1-block-LRU eviction pressure —
    every selected block round-trips DRAM each iteration.  Reports the
    measured D2H+H2D wire bytes per tier, asserts equal blocks moved, and
    emits the per-block byte shrink (the ISSUE bar is >= 1.8x; these f32
    smoke pools shrink ~3.9x, a bf16 deployment ~2x — see the modeled
    ``model_*`` fields from ``costmodel.offload_block_bytes``).
"""
from __future__ import annotations

import os as _os
import sys as _sys

_R = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
_sys.path[:0] = [p for p in (_R, _os.path.join(_R, "src"))
                 if p not in _sys.path]

import time

import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.serving import costmodel as cm


def fig4_bandwidth() -> None:
    header("fig4_bandwidth: effective GB/s vs KV block size (A100 PCIe4)")
    hw = cm.A100_40G
    n_blocks = 256
    for kb in (4, 8, 16, 32, 64, 128):
        blk = kb * 1024
        emit("fig4", block_kb=kb,
             memcpy_gbps=round(cm.effective_bandwidth(hw, n_blocks, blk,
                                                      fused=False) / 1e9, 2),
             flash_gbps=round(cm.effective_bandwidth(hw, n_blocks, blk,
                                                     fused=True) / 1e9, 2))


def fig14a_loading_latency() -> None:
    header("fig14a: decode batch latency & KV loading share, "
           "memcpy vs FlashH2D (LWM-7B)")
    cfg = get_config("lwm-7b")
    mc = cm.ModelCost.from_config(cfg)
    hw = cm.A100_40G
    blk_per_head = 32 * mc.head_dim * 2 * 2                 # 16 KB
    miss_blocks = 24                                        # per req/layer/it
    for bs in (2, 4, 8, 16):
        t_cmp = cm.decode_time(hw, mc, bs, 2048)
        n_copies = bs * miss_blocks * mc.n_kv_heads * mc.num_layers
        t_memcpy = cm.memcpy_transfer_time(hw, n_copies, blk_per_head)
        t_flash = mc.num_layers * cm.fused_transfer_time(
            hw, bs * miss_blocks * mc.n_kv_heads * blk_per_head)
        emit("fig14a", batch_size=bs,
             compute_ms=round(t_cmp * 1e3, 2),
             memcpy_load_ms=round(t_memcpy * 1e3, 2),
             flash_load_ms=round(t_flash * 1e3, 2),
             memcpy_load_frac=round(t_memcpy / (t_memcpy + t_cmp), 3),
             speedup=round(t_memcpy / t_flash, 2))


def fig14b_saving_latency() -> None:
    header("fig14b: prefill latency normalized to compute, by saving method")
    cfg = get_config("lwm-7b")
    mc = cm.ModelCost.from_config(cfg)
    hw = cm.A100_40G
    prompt = 16384
    t_cmp = cm.prefill_time(hw, mc, prompt, prompt)
    save_bytes = prompt * mc.kv_bytes_per_token
    n_blocks = (prompt // 32) * mc.n_kv_heads * mc.num_layers
    blk = 32 * mc.head_dim * 2 * 2
    t_memcpy = cm.memcpy_transfer_time(hw, n_blocks, blk)
    # GPU-direct saving contends with compute: model as 30% compute slowdown
    t_gpu_direct = max(save_bytes / (hw.host_link_bw * hw.link_eff_fused),
                       0.3 * t_cmp)
    # FlashD2H: ONE contiguous copy, CPU scatters async — fully overlapped
    t_flash = cm.fused_transfer_time(hw, save_bytes)
    emit("fig14b", method="memcpy",
         norm_latency=round(max(t_cmp, t_memcpy) / t_cmp, 2))
    emit("fig14b", method="gpu_direct",
         norm_latency=round((t_cmp + t_gpu_direct) / t_cmp, 2))
    emit("fig14b", method="flash_d2h",
         norm_latency=round(max(t_cmp, t_flash) / t_cmp, 2))


def real_gather_microbench() -> None:
    header("real_gather: fused gather (1 launch) vs per-block copies "
           "(host pool data plane, wall time)")
    rng = np.random.default_rng(0)
    pool = rng.normal(size=(512, 32, 128)).astype(np.float32)
    idx = rng.choice(512, 64, replace=False)
    t0 = time.perf_counter()
    for _ in range(50):
        out = pool[idx]                       # fused gather
    t_fused = (time.perf_counter() - t0) / 50
    t0 = time.perf_counter()
    for _ in range(50):
        out2 = np.empty((64, 32, 128), np.float32)
        for j, b in enumerate(idx):           # per-block memcpy
            out2[j] = pool[b]
    t_loop = (time.perf_counter() - t0) / 50
    assert np.array_equal(out, out2)
    emit("real_gather", fused_us=round(t_fused * 1e6, 1),
         per_block_us=round(t_loop * 1e6, 1),
         speedup=round(t_loop / t_fused, 2))


def quant_tier_section() -> None:
    """Real-engine fp-vs-int8 offload tier comparison (see module
    docstring (e) for the emitted fields)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request

    header("quant_tier: D2H+H2D wire bytes, fp vs int8 offload tier "
           "(real engine, 1-block LRU eviction pressure)")
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rows = {}
    for quant in ("none", "int8"):
        eng = ServingEngine(params, cfg, EngineConfig(
            chunk_size=64, r_max=4, hbm_blocks_per_request=1,
            offload_quant=quant))
        rng = np.random.default_rng(3)
        for _ in range(3):
            eng.submit(Request(prompt_len=64, max_new_tokens=12),
                       tokens=rng.integers(4, cfg.vocab_size,
                                           64).astype(np.int32))
        eng.run()
        ts = eng.kv_mgr.total_stats()
        rows[quant] = ts
        g = eng.geom
        emit("quant_tier", tier=quant,
             h2d_bytes=ts.h2d_bytes, d2h_bytes=ts.d2h_bytes,
             h2d_blocks=ts.h2d_blocks, d2h_blocks=ts.d2h_blocks,
             wire_bytes=ts.h2d_bytes + ts.d2h_bytes,
             model_block_bytes=cm.offload_block_bytes(
                 g.num_kv_heads, g.head_dim, g.block_size,
                 kv_factor=g.kv_factor, dtype_bytes=g.dtype_bytes,
                 quant=quant),
             model_bytes_per_token=round(cm.offload_bytes_per_token(
                 g.num_kv_heads, g.head_dim, g.block_size,
                 kv_factor=g.kv_factor, dtype_bytes=g.dtype_bytes,
                 quant=quant), 2))
    fp, q8 = rows["none"], rows["int8"]
    # per-block normalization guards against block-count drift between the
    # lossy and lossless runs (selection could diverge after a token flip)
    per_blk_fp = (fp.h2d_bytes + fp.d2h_bytes) \
        / max(fp.h2d_blocks + fp.d2h_blocks, 1)
    per_blk_q8 = (q8.h2d_bytes + q8.d2h_bytes) \
        / max(q8.h2d_blocks + q8.d2h_blocks, 1)
    emit("quant_tier", tier="summary",
         equal_blocks_moved=(fp.h2d_blocks == q8.h2d_blocks
                             and fp.d2h_blocks == q8.d2h_blocks),
         byte_shrink_per_block=round(per_blk_fp / max(per_blk_q8, 1e-12),
                                     3),
         # deployment-dtype view: same shrink at the modeled bf16 tier
         model_shrink_bf16=round(
             cm.offload_block_bytes(8, 64, 32, quant="none")
             / cm.offload_block_bytes(8, 64, 32, quant="int8"), 3))


def main() -> None:
    fig4_bandwidth()
    fig14a_loading_latency()
    fig14b_saving_latency()
    real_gather_microbench()
    quant_tier_section()


if __name__ == "__main__":
    main()
