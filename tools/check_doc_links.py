#!/usr/bin/env python3
"""Check that relative links in the repo's markdown docs resolve.

    python tools/check_doc_links.py [files...]

With no arguments, checks README.md, docs/*.md, and benchmarks/README.md.
External (scheme://) and intra-page (#anchor) links are skipped; relative
links (including their optional #fragment-less path part) must exist on
disk.  Exit code 1 if any link is broken — CI runs this in the docs job.
"""
from __future__ import annotations

import os
import re
import sys
from glob import glob

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def check(path: str) -> list:
    broken = []
    text = open(path, encoding="utf-8").read()
    base = os.path.dirname(os.path.abspath(path))
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if "://" in target or target.startswith(("#", "mailto:")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            line = text[:m.start()].count("\n") + 1
            broken.append((path, line, target))
    return broken


def main() -> int:
    files = sys.argv[1:] or (
        [os.path.join(ROOT, "README.md")]
        + sorted(glob(os.path.join(ROOT, "docs", "*.md")))
        + [os.path.join(ROOT, "benchmarks", "README.md")])
    broken = []
    for f in files:
        if os.path.exists(f):
            broken += check(f)
        else:
            broken.append((f, 0, "<file missing>"))
    for path, line, target in broken:
        print(f"BROKEN {os.path.relpath(path, ROOT)}:{line}: {target}")
    checked = len(files)
    print(f"checked {checked} file(s); {len(broken)} broken link(s)")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
