"""Finding model + waiver application + report rendering for the
plane-contract analyzer."""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Tuple

from repro.core import plane_contract as pc


@dataclasses.dataclass
class Finding:
    rule: str
    file: str                       # repo-relative path
    line: int
    message: str
    check: str                      # "stage-protocol" | "retrace" | "sharding"
    waived: bool = False
    waive_reason: str = ""

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tag = f" [waived: {self.waive_reason}]" if self.waived else ""
        return (f"{self.file}:{self.line}: {self.rule} ({self.check}): "
                f"{self.message}{tag}")


def apply_waivers(findings: List[Finding], repo_root: Path) -> None:
    """Mark findings covered by an in-source
    ``# plane-contract: allow(<rule>) <reason>`` comment (same line or the
    line above) as waived."""
    cache: Dict[str, Dict[int, Tuple[str, str]]] = {}
    for f in findings:
        if f.file not in cache:
            path = repo_root / f.file
            try:
                cache[f.file] = pc.collect_waivers(
                    path.read_text(encoding="utf-8"))
            except OSError:
                cache[f.file] = {}
        reason = pc.waiver_for(cache[f.file], f.rule, f.line)
        if reason is not None:
            f.waived = True
            f.waive_reason = reason


def render_report(findings: List[Finding], checks: List[str]) -> str:
    lines = []
    unwaived = [f for f in findings if not f.waived]
    for f in findings:
        lines.append(f.render())
    lines.append(f"plane-contract: checks={','.join(checks)} "
                 f"findings={len(findings)} unwaived={len(unwaived)}")
    return "\n".join(lines)


def json_report(findings: List[Finding], checks: List[str],
                target: str) -> str:
    unwaived = [f for f in findings if not f.waived]
    return json.dumps({
        "target": target,
        "checks": checks,
        "findings": [f.to_dict() for f in findings],
        "counts": {"total": len(findings), "unwaived": len(unwaived)},
        "ok": not unwaived,
    }, indent=2)
