"""Pass 1 — stage-protocol checker.

Linearizes each contract driver's AST (splicing the engine's stage/group
callbacks in at their call sites) into a sequence of data-plane EFFECTS
(``plane_contract.EFFECT_OF_CALL``), then verifies the ordering and
fusion invariants of the driver's protocol on that static stage graph:

* restore-before-use      — a device restore may never follow the attend
                            launch of its (layer, group) window;
* writeback-before-drop   — any device drop / HBM layer evict must be
                            preceded by a FlashD2H save in the same or an
                            enclosing window, and an in-window drop must
                            carry the one-stage eviction ``protect=``;
* fused-transfer          — at most one fused FlashD2H save / H2D load /
                            restore per window; per-request (unfused)
                            saves are findings (waived only in the legacy
                            executors);
* ctx-lifetime            — the one-layer prefill ctx buffer is read only
                            inside the group callback window;
* launches-per-iteration  — no jitted stage launch inside a loop over
                            requests (the O(L) launch budget).

Purely syntactic: nothing is imported or executed.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core import plane_contract as pc

from .findings import Finding

_LOOPS = (ast.For, ast.AsyncFor, ast.While)
_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _parse(repo_root: Path, file: str,
           cache: Dict[str, ast.Module]) -> ast.Module:
    if file not in cache:
        cache[file] = ast.parse((repo_root / file).read_text(
            encoding="utf-8"), filename=file)
    return cache[file]


def find_def(tree: ast.Module, qualname: str) -> Optional[ast.AST]:
    """Locate a (possibly nested) def/class by dotted qualname."""
    parts = qualname.split(".")
    scope: ast.AST = tree
    for part in parts:
        nxt = None
        for node in ast.iter_child_nodes(scope):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)) and node.name == part:
                nxt = node
                break
        if nxt is None:
            return None
        scope = nxt
    return scope


def callee_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Subscript):        # fns._recurrent[kind](...)
        v = f.value
        if isinstance(v, ast.Attribute):
            return v.attr
        if isinstance(v, ast.Name):
            return v.id
    return None


def _expr_names(node: ast.AST) -> set:
    """Terminal Name ids and Attribute attrs in an expression — used to
    decide whether a loop iterates a contract batch iterable."""
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


@dataclasses.dataclass
class Effect:
    kind: str
    sub: str
    call: str
    file: str
    line: int
    stack: Tuple[int, ...]          # enclosing loop ids, outermost first
    batch: bool                     # inside a loop over requests
    in_callback: bool
    kwargs: Tuple[str, ...]


class _Linearizer:
    """Walks a driver body in source order collecting effects; loops push
    a window onto the stack; callback calls splice the callback's own
    linearized body at the call site."""

    def __init__(self, repo_root: Path, driver: pc.DriverSpec,
                 cache: Dict[str, ast.Module]):
        self.repo_root = repo_root
        self.driver = driver
        self.cache = cache
        self.effects: List[Effect] = []
        self.cb_bodies: Dict[str, Tuple[str, ast.AST]] = {}
        for cb in driver.callbacks:
            tree = _parse(repo_root, cb.file, cache)
            node = find_def(tree, cb.qualname)
            if node is not None:
                self.cb_bodies[cb.local_name] = (cb.file, node)

    def run(self) -> List[Effect]:
        tree = _parse(self.repo_root, self.driver.file, self.cache)
        node = find_def(tree, self.driver.qualname)
        if node is None:
            return []
        self._body(node.body, self.driver.file, (), False, False)
        return self.effects

    def _is_batch_loop(self, loop: ast.AST) -> bool:
        if not isinstance(loop, (ast.For, ast.AsyncFor)):
            return False
        return bool(_expr_names(loop.iter)
                    & set(self.driver.batch_iterables))

    def _body(self, stmts, file, stack, batch, in_cb) -> None:
        for stmt in stmts:
            self._stmt(stmt, file, stack, batch, in_cb)

    def _stmt(self, stmt, file, stack, batch, in_cb) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                          # runs at call time, not here
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._exprs(stmt.iter, file, stack, batch, in_cb)
            sub_stack = stack + (id(stmt),)
            sub_batch = batch or self._is_batch_loop(stmt)
            self._body(stmt.body, file, sub_stack, sub_batch, in_cb)
            self._body(stmt.orelse, file, stack, batch, in_cb)
            return
        if isinstance(stmt, ast.While):
            self._exprs(stmt.test, file, stack, batch, in_cb)
            sub_stack = stack + (id(stmt),)
            self._body(stmt.body, file, sub_stack, batch, in_cb)
            self._body(stmt.orelse, file, stack, batch, in_cb)
            return
        if isinstance(stmt, ast.If):
            self._exprs(stmt.test, file, stack, batch, in_cb)
            self._body(stmt.body, file, stack, batch, in_cb)
            self._body(stmt.orelse, file, stack, batch, in_cb)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._exprs(item.context_expr, file, stack, batch, in_cb)
            self._body(stmt.body, file, stack, batch, in_cb)
            return
        if isinstance(stmt, ast.Try):
            self._body(stmt.body, file, stack, batch, in_cb)
            for h in stmt.handlers:
                self._body(h.body, file, stack, batch, in_cb)
            self._body(stmt.orelse, file, stack, batch, in_cb)
            self._body(stmt.finalbody, file, stack, batch, in_cb)
            return
        self._exprs(stmt, file, stack, batch, in_cb)

    def _exprs(self, node, file, stack, batch, in_cb) -> None:
        """Collect effect calls inside one statement/expression, in field
        order, skipping nested function bodies."""
        if node is None or isinstance(node, _FUNCS):
            return
        if isinstance(node, ast.Call):
            # callback splice happens INSTEAD of recording an effect
            if (isinstance(node.func, ast.Name)
                    and node.func.id in self.cb_bodies):
                for arg in node.args:
                    self._exprs(arg, file, stack, batch, in_cb)
                cb_file, cb_node = self.cb_bodies[node.func.id]
                self._body(cb_node.body, cb_file, stack, batch, True)
                return
            # record nested effect calls (arguments) before the outer call
            for child in ast.iter_child_nodes(node):
                self._exprs(child, file, stack, batch, in_cb)
            name = callee_name(node)
            eff = pc.EFFECT_OF_CALL.get(name) if name else None
            if eff is not None:
                self.effects.append(Effect(
                    kind=eff[0], sub=eff[1], call=name, file=file,
                    line=node.lineno, stack=stack, batch=batch,
                    in_callback=in_cb,
                    kwargs=tuple(kw.arg for kw in node.keywords
                                 if kw.arg)))
            return
        for child in ast.iter_child_nodes(node):
            self._exprs(child, file, stack, batch, in_cb)


def _related(a: Tuple[int, ...], b: Tuple[int, ...]) -> bool:
    """True when one window stack encloses (is a prefix of) the other."""
    n = min(len(a), len(b))
    return a[:n] == b[:n]


def check_driver(repo_root: Path, driver: pc.DriverSpec,
                 cache: Dict[str, ast.Module]) -> List[Finding]:
    effects = _Linearizer(repo_root, driver, cache).run()
    rules = set(pc.PROTOCOL_RULES[driver.protocol])
    out: List[Finding] = []

    def flag(rule, eff, msg):
        out.append(Finding(rule=rule, file=eff.file, line=eff.line,
                           message=f"[{driver.name}] {msg}",
                           check="stage-protocol"))

    if pc.RULE_RESTORE_BEFORE_USE in rules:
        for i, e in enumerate(effects):
            if e.kind != "restore":
                continue
            for a in effects[:i]:
                if (a.kind == "launch" and a.sub == "attend"
                        and _related(a.stack, e.stack)):
                    flag(pc.RULE_RESTORE_BEFORE_USE, e,
                         f"restore ({e.call}) placed AFTER the attend "
                         f"launch at line {a.line} — restores must land "
                         f"between select and attend")
                    break

    if pc.RULE_WRITEBACK_BEFORE_DROP in rules:
        for i, e in enumerate(effects):
            if e.kind not in ("drop", "layer-evict"):
                continue
            has_wb = any(d.kind == "d2h" and _related(d.stack, e.stack)
                         for d in effects[:i])
            if not has_wb:
                flag(pc.RULE_WRITEBACK_BEFORE_DROP, e,
                     f"{e.call} with no preceding FlashD2H write-back in "
                     f"its window — dropped data would exist nowhere")
            if (e.kind == "drop" and e.stack
                    and driver.protocol in ("staged-decode", "hybrid-plane",
                                            "staged-decode-async",
                                            "hybrid-plane-async")
                    and "protect" not in e.kwargs):
                flag(pc.RULE_WRITEBACK_BEFORE_DROP, e,
                     f"in-window {e.call} without protect= — blocks "
                     f"selected by the imminent attend must be deferred "
                     f"one stage")

    if pc.RULE_FUSED_TRANSFER in rules:
        per_window: Dict[Tuple, Dict[str, int]] = {}
        for e in effects:
            if e.kind == "d2h" and e.sub == "unfused":
                flag(pc.RULE_FUSED_TRANSFER, e,
                     f"per-request {e.call} — the plane protocol requires "
                     f"ONE fused FlashD2H save per (layer, group)")
                continue
            if (e.kind == "restore" and e.sub == "unfused"
                    and driver.protocol != "legacy"):
                flag(pc.RULE_FUSED_TRANSFER, e,
                     f"per-request {e.call} — use the fused batch restore")
                continue
            if e.kind in ("d2h", "h2d", "restore"):
                seen = per_window.setdefault(e.stack, {})
                seen[e.kind] = seen.get(e.kind, 0) + 1
                if seen[e.kind] > 1:
                    flag(pc.RULE_FUSED_TRANSFER, e,
                         f"{seen[e.kind]} {e.kind} transfers in one "
                         f"(layer, group) window — transfers must fuse to "
                         f"one launch per window")

    if pc.RULE_CTX_LIFETIME in rules:
        for e in effects:
            if e.kind == "ctx-read" and not e.in_callback:
                flag(pc.RULE_CTX_LIFETIME, e,
                     f"{e.call} outside the group callback — the "
                     f"one-layer ctx buffer is overwritten by the next "
                     f"layer's launch")

    if pc.RULE_NO_SYNC_IN_DISPATCH_WINDOW in rules:
        # an async stage callback runs INSIDE the dispatch window: between
        # the driver's np.asarray(selected ids) — the one allowed per-layer
        # sync, which happens BEFORE the callback — and the attend/select
        # dispatch that follows it.  Any host-blocking device readback in
        # the callback re-serializes the pipeline the async mode exists to
        # overlap: explicit syncs (np.asarray / block_until_ready /
        # device_get) and the blocking readback helpers (sub "" — use the
        # *_async variants, which only dispatch and hand completion to the
        # HostStageWorker behind the per-layer fence).
        for e in effects:
            if not e.in_callback:
                continue
            if e.kind == "sync":
                if e.sub == "obs":
                    flag(pc.RULE_NO_SYNC_IN_DISPATCH_WINDOW, e,
                         f"blocking obs call ({e.call}) inside the async "
                         f"dispatch window — trace dumps and metric "
                         f"snapshots belong between iterations; guarded "
                         f"span emission is the only obs allowed here")
                else:
                    flag(pc.RULE_NO_SYNC_IN_DISPATCH_WINDOW, e,
                         f"host-blocking sync ({e.call}) inside the async "
                         f"dispatch window — the driver's selection sync is "
                         f"the only allowed per-layer block")
            elif e.kind in ("pool-read", "ctx-read") and e.sub == "":
                flag(pc.RULE_NO_SYNC_IN_DISPATCH_WINDOW, e,
                     f"blocking readback ({e.call}) inside the async "
                     f"dispatch window — use {e.call}_async and stage the "
                     f"conversion on the HostStageWorker")

    if pc.RULE_LAUNCHES in rules:
        for e in effects:
            if e.kind == "launch" and e.batch:
                flag(pc.RULE_LAUNCHES, e,
                     f"jitted launch ({e.call}) inside a per-request loop "
                     f"— launches must stay O(num_layers) per iteration")

    return out


def run(repo_root: Path, target: pc.AnalysisTarget) -> List[Finding]:
    cache: Dict[str, ast.Module] = {}
    out: List[Finding] = []
    for driver in target.drivers:
        out.extend(check_driver(repo_root, driver, cache))
    return out
