"""Pass 3 — sharding-leak detector.

Abstractly lowers every registered stage jit (the raw stage bodies +
abstract call signatures ``StageFns`` records) with ``jax.make_jaxpr``
under its PlaneMesh, then checks the jaxpr against the plane contract's
sharding rules (``plane_contract.sharding_rules``):

* collective-not-allowed — a communication primitive (psum, all_gather,
  ...) appears anywhere in the lowered stage that the contract does not
  list for that (stage, shard mode) — e.g. an accidental gather of the
  sharded pool;
* sharding-leak — a stage OUTPUT that the contract requires replicated
  can carry shard_map out-spec sharding into the caller (no
  ``PlaneMesh.replicate`` pin on the escape path).  The leak taint starts
  at shard_map outputs with non-empty out-specs, is cleared by a
  replicated ``sharding_constraint``, and propagates through every other
  equation; only the contract's ``sharded_out_paths`` (the pool cache a
  select returns) may reach the stage's outputs tainted.

Lowering is ABSTRACT (ShapeDtypeStructs in, jaxpr out): no FLOPs run, so
the whole pass is a few seconds on CPU.  The default target populates the
registries by running two one-token smoke engines (a GQA arch for head
sharding and an MLA arch for block sharding) on a 1-way model mesh —
shard_map over a trivial axis emits the same jaxpr structure as a real
multi-device mesh.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Sequence, Tuple

from repro.core import plane_contract as pc

from .findings import Finding

# (arch, prompts) for the default registry-populating smoke runs: one GQA
# model (head-mode pool sharding on a 1-way axis) and one MLA model
# (always block mode), so both sharded stage variants get lowered
_DEFAULT_BUILDS: Tuple[Tuple[str, Tuple[int, ...]], ...] = (
    ("qwen2-0.5b", (24, 40)),
    ("minicpm3-4b", (24, 40)),
)


def _rel(repo_root: Path, filename: str) -> str:
    try:
        return str(Path(filename).resolve().relative_to(repo_root.resolve()))
    except ValueError:
        return filename


def _fn_site(fn) -> Tuple[str, int]:
    code = getattr(fn, "__code__", None)
    if code is None:
        return "<unknown>", 0
    return code.co_filename, code.co_firstlineno


def _default_setup(arch):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config(arch)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)


def _run_smoke_engine(cfg, params, pm, prompts) -> None:
    import numpy as np
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.request import Request
    eng = ServingEngine(params, cfg, EngineConfig(
        chunk_size=64, r_max=2, mesh_spec=pm))
    rng = np.random.default_rng(0)
    for p in prompts:
        toks = rng.integers(4, cfg.vocab_size, p).astype(np.int32)
        eng.submit(Request(prompt_len=p, max_new_tokens=2), tokens=toks)
    eng.run()


def _collect_fns(cfg):
    """Registry entries keyed by this cfg (every registry keys repr(cfg)
    first)."""
    from repro.core import device_pool, prefill_plane
    r, out, seen = repr(cfg), [], set()
    for reg in (device_pool._STAGED_FNS, prefill_plane._PREFILL_FNS,
                prefill_plane._ADMIT_EMBED_FNS):
        for key, fns in reg.items():
            k0 = key[0] if isinstance(key, tuple) else key
            if k0 == r and id(fns) not in seen:
                seen.add(id(fns))
                out.append(fns)
    return out


def build_default_stages(get_setup=None) -> List[pc.StageLowering]:
    """Populate the stage registries with smoke workloads and return one
    StageLowering per (registered stage, recorded signature).  get_setup
    lets callers (tests) inject cached (cfg, params) per arch."""
    from repro.launch.plane_mesh import PlaneMesh
    pm = PlaneMesh.resolve(1)
    lowerings: List[pc.StageLowering] = []
    for arch, prompts in _DEFAULT_BUILDS:
        cfg, params = (get_setup or _default_setup)(arch)
        _run_smoke_engine(cfg, params, pm, prompts)
        for fns in _collect_fns(cfg):
            for stage, fn in sorted(fns.raw_fns.items()):
                args = fns.abstract_args.get(stage)
                if args is None:
                    continue            # registered but never launched
                mode = pc.stage_shard_mode(stage, cfg, pm)
                file, line = _fn_site(fn)
                lowerings.append(pc.StageLowering(
                    stage=f"{stage}[{arch}:{mode}]", fn=fn, args=args,
                    rules=pc.sharding_rules(stage, mode),
                    file=file, line=line))
    return lowerings


# -- jaxpr inspection -------------------------------------------------------


def _iter_sub_jaxprs(params: dict):
    for v in params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for item in vals:
            if hasattr(item, "eqns"):                   # Jaxpr
                yield item
            elif hasattr(item, "jaxpr"):                # ClosedJaxpr
                yield item.jaxpr


def _collect_collectives(jaxpr, found: set) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in pc.COLLECTIVE_PRIMS:
            found.add(name)
        for sub in _iter_sub_jaxprs(eqn.params):
            _collect_collectives(sub, found)


def _is_replicated_sharding(sharding) -> bool:
    spec = getattr(sharding, "spec", None)
    if spec is None:
        return False
    return all(entry is None for entry in tuple(spec))


def _tainted_outvars(jaxpr) -> set:
    """Indices of jaxpr outvars that can carry shard_map out-spec sharding
    (taint from sharded shard_map outputs, cleared by replicated
    sharding_constraints, propagated through everything else)."""
    tainted = set()

    def _vars(vs):
        return [v for v in vs if not hasattr(v, "val")]   # skip Literals

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "shard_map":
            out_names = eqn.params.get("out_names", ())
            for var, names in zip(eqn.outvars, out_names):
                if names:                       # non-empty spec => sharded
                    tainted.add(var)
        elif name == "sharding_constraint":
            if _is_replicated_sharding(eqn.params.get("sharding")):
                continue                        # explicit replicate: clean
            if any(v in tainted for v in _vars(eqn.invars)):
                tainted.update(eqn.outvars)
        else:
            if any(v in tainted for v in _vars(eqn.invars)):
                tainted.update(eqn.outvars)
    return {i for i, v in enumerate(jaxpr.outvars)
            if not hasattr(v, "val") and v in tainted}


def _out_paths(out_shape) -> List[str]:
    import jax
    leaves_with_paths, _ = jax.tree_util.tree_flatten_with_path(out_shape)
    return [jax.tree_util.keystr(path) for path, _ in leaves_with_paths]


def check_lowering(repo_root: Path, low: pc.StageLowering) -> List[Finding]:
    import jax
    file = _rel(repo_root, low.file)
    try:
        closed, out_shape = jax.make_jaxpr(
            low.fn, return_shape=True)(*low.args)
    except Exception as e:                      # noqa: BLE001 - reported
        return [Finding(
            rule=pc.RULE_SHARDING_LEAK, file=file, line=low.line,
            message=f"[{low.stage}] failed to lower for inspection: "
                    f"{type(e).__name__}: {e}", check="sharding")]
    out: List[Finding] = []
    found: set = set()
    _collect_collectives(closed.jaxpr, found)
    extra = found - low.rules.allowed_collectives
    if extra:
        allowed = (", ".join(sorted(low.rules.allowed_collectives))
                   or "none")
        out.append(Finding(
            rule=pc.RULE_COLLECTIVE, file=file, line=low.line,
            message=f"[{low.stage}] collective(s) "
                    f"{', '.join(sorted(extra))} in the lowered stage; "
                    f"contract allows: {allowed}", check="sharding"))
    paths = _out_paths(out_shape)
    for i in _tainted_outvars(closed.jaxpr):
        path = (paths[i] if i < len(paths) else f"<leaf {i}>") or "<root>"
        if any(tok in path for tok in low.rules.sharded_out_paths):
            continue                            # sharded by contract
        out.append(Finding(
            rule=pc.RULE_SHARDING_LEAK, file=file, line=low.line,
            message=f"[{low.stage}] output {path} can carry shard_map "
                    f"sharding into replicated callers — pin it with "
                    f"PlaneMesh.replicate", check="sharding"))
    return out


def _resolve_builder(repo_root: Path, spec: str):
    """'path/to/file.py:function' -> the build_stages callable."""
    import importlib.util
    file, _, func = spec.partition(":")
    path = repo_root / file
    mod_spec = importlib.util.spec_from_file_location(
        "plane_analysis_fixture", path)
    mod = importlib.util.module_from_spec(mod_spec)
    mod_spec.loader.exec_module(mod)
    return getattr(mod, func)


def run(repo_root: Path, target: pc.AnalysisTarget,
        get_setup=None) -> List[Finding]:
    if target.sharding is None:
        return []
    if target.sharding == "default":
        lowerings: Sequence[pc.StageLowering] = \
            build_default_stages(get_setup)
    else:
        lowerings = _resolve_builder(repo_root, target.sharding)()
    out: List[Finding] = []
    for low in lowerings:
        out.extend(check_lowering(repo_root, low))
    return out
