#!/usr/bin/env python
"""Plane-contract analyzer CLI.

    python tools/analysis/run.py                       # all passes, real tree
    python tools/analysis/run.py --check retrace       # one pass
    python tools/analysis/run.py --json report.json    # machine-readable
    python tools/analysis/run.py --fixture bad_double_d2h
    python tools/analysis/run.py --list-fixtures

Exit status is non-zero iff any finding is NOT covered by an in-source
``# plane-contract: allow(<rule>) <reason>`` waiver.  See
tools/analysis/README.md and docs/architecture.md §8.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[2]
for p in (str(REPO_ROOT), str(REPO_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import plane_contract as pc               # noqa: E402
from tools.analysis import (                              # noqa: E402
    findings as findings_mod,
    retrace_lint,
    sharding_leak,
    stage_protocol,
)

CHECKS = ("stage-protocol", "retrace", "sharding")


def analyze(target: pc.AnalysisTarget, checks=CHECKS, repo_root=REPO_ROOT,
            get_setup=None):
    """Run the selected passes over one target; returns findings with
    waivers applied."""
    found = []
    if "stage-protocol" in checks:
        found.extend(stage_protocol.run(repo_root, target))
    if "retrace" in checks:
        found.extend(retrace_lint.run(repo_root, target))
    if "sharding" in checks:
        found.extend(sharding_leak.run(repo_root, target,
                                       get_setup=get_setup))
    findings_mod.apply_waivers(found, repo_root)
    return found


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools/analysis/run.py",
        description="Static analyzer for the serving-plane contract.")
    ap.add_argument("--check", action="append", default=None,
                    help=f"pass(es) to run, comma-separable; default all "
                         f"({','.join(CHECKS)})")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="emit a JSON report to PATH (or stdout)")
    ap.add_argument("--fixture", default=None,
                    help="analyze a seeded-violation fixture instead of "
                         "the real tree")
    ap.add_argument("--list-fixtures", action="store_true")
    args = ap.parse_args(argv)

    from tools.analysis.fixtures import FIXTURES
    if args.list_fixtures:
        for name, (_, rule) in sorted(FIXTURES.items()):
            print(f"{name}: expects {rule or 'no findings'}")
        return 0

    checks = list(CHECKS)
    if args.check:
        checks = [c for part in args.check for c in part.split(",") if c]
        bad = [c for c in checks if c not in CHECKS]
        if bad:
            ap.error(f"unknown check(s) {bad}; choose from {CHECKS}")

    if args.fixture is not None:
        if args.fixture not in FIXTURES:
            ap.error(f"unknown fixture {args.fixture!r} "
                     f"(see --list-fixtures)")
        target = FIXTURES[args.fixture][0]
    else:
        target = pc.DEFAULT_TARGET

    found = analyze(target, checks=checks)
    print(findings_mod.render_report(found, checks))
    if args.json is not None:
        payload = findings_mod.json_report(found, checks, target.name)
        if args.json == "-":
            print(payload)
        else:
            Path(args.json).write_text(payload + "\n", encoding="utf-8")
    return 1 if any(not f.waived for f in found) else 0


if __name__ == "__main__":
    sys.exit(main())
