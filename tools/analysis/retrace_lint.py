"""Pass 2 — retrace-hazard lint.

Finds the jitted stage bodies in the plane modules (the function argument
of ``StageFns.wrap(stage, fn)`` / ``self.wrap(...)`` call sites, plus
direct ``jax.jit`` call sites and decorators) and lints each body for the
hazards that silently retrace or break under tracing:

* traced-branch    — Python ``if``/``while``/ternary branching on a traced
                     argument (``is None`` / ``isinstance`` tests are
                     static and allowed);
* tracer-coercion  — ``int()``/``float()``/``bool()``/``.item()`` applied
                     to a traced value (ConcretizationTypeError at trace
                     time, or a silent host sync);
* np-in-jit        — ``np.*`` calls on traced values inside a jit body
                     (constant-folds the tracer or raises; use ``jnp``).

Separately lints the stage-fns REGISTRY factories against their
``plane_contract.RegistrySpec``:

* unhashable-key      — a non-hashable config/mesh object placed directly
                        in a registry key tuple (must go through
                        ``repr()`` / ``.key()``);
* key-missing-field   — a shape-relevant factory parameter that never
                        reaches the key (stale fns served across configs).

Parameters with defaults (e.g. ``kind=kind`` closure pinning) and the
conventional static names (``self``/``cfg``/``kind``/``stage``) are
treated as static; everything else arriving at a jit body is traced.
Purely syntactic: nothing is imported or executed.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.core import plane_contract as pc

from .findings import Finding

_NP_ROOTS = ("np", "numpy")
_COERCIONS = ("int", "float", "bool")


def _parse(repo_root: Path, file: str) -> ast.Module:
    return ast.parse((repo_root / file).read_text(encoding="utf-8"),
                     filename=file)


def _params(fn) -> Tuple[List[str], Set[str]]:
    """(all param names, static param names) for a def/lambda."""
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    static = set(pc.STATIC_PARAM_NAMES) & set(names)
    # positional defaults align with the TAIL of posonlyargs+args
    pos = [p.arg for p in a.posonlyargs + a.args]
    for i, _ in enumerate(a.defaults):
        static.add(pos[len(pos) - len(a.defaults) + i])
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            static.add(p.arg)
    return names, static


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _is_static_test(test: ast.AST) -> bool:
    """Tests that resolve at trace time: ``x is None`` / ``x is not None``
    and ``isinstance(...)``."""
    if isinstance(test, ast.Compare):
        ok_ops = all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops)
        none_cmp = any(isinstance(c, ast.Constant) and c.value is None
                       for c in [test.left] + list(test.comparators))
        return ok_ops and none_cmp
    if (isinstance(test, ast.Call) and isinstance(test.func, ast.Name)
            and test.func.id in ("isinstance", "hasattr", "callable")):
        return True
    if isinstance(test, ast.BoolOp):
        return all(_is_static_test(v) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_static_test(test.operand)
    return False


def _np_root(node: ast.AST) -> Optional[str]:
    """'np' for calls rooted at the numpy module alias, else None."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name) and node.id in _NP_ROOTS:
        return node.id
    return None


def _obs_root(f: ast.Attribute) -> Optional[str]:
    """The obs-layer name a call is rooted at (``tracer.end(...)``,
    ``self.metrics.counter(..).inc()`` -> "tracer" / "metrics"), else
    None.  Matches by name against ``pc.OBS_ROOT_NAMES`` — the repo-wide
    convention that those identifiers mean the obs layer — so the check
    needs no type information."""
    node: ast.AST = f.value
    while True:
        if isinstance(node, ast.Attribute):
            if node.attr in pc.OBS_ROOT_NAMES:
                return node.attr
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            break
    if isinstance(node, ast.Name) and node.id in pc.OBS_ROOT_NAMES:
        return node.id
    return None


class _JitBodyLint:
    def __init__(self, file: str, stage: str, fn, findings: List[Finding]):
        self.file = file
        self.stage = stage
        self.findings = findings
        names, static = _params(fn)
        self.traced = {n for n in names if n not in static}
        if isinstance(fn, ast.Lambda):
            self._walk(fn.body)
        else:
            for stmt in fn.body:
                self._walk(stmt)

    def _flag(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            rule=rule, file=self.file, line=node.lineno,
            message=f"[jit:{self.stage}] {msg}", check="retrace"))

    def _touches_traced(self, node: ast.AST) -> bool:
        return bool(_names_in(node) & self.traced)

    def _walk(self, node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return                  # nested defs have their own params
        if isinstance(node, (ast.If, ast.While)):
            self._check_test(node.test)
        if isinstance(node, ast.IfExp):
            self._check_test(node.test)
        if isinstance(node, ast.Call):
            self._check_call(node)
        for child in ast.iter_child_nodes(node):
            self._walk(child)

    def _check_test(self, test: ast.AST) -> None:
        if self._touches_traced(test) and not _is_static_test(test):
            self._flag(pc.RULE_TRACED_BRANCH, test,
                       "Python branch on a traced value — the branch is "
                       "taken at TRACE time and baked into the jaxpr "
                       "(use jnp.where / lax.cond)")

    def _check_call(self, call: ast.Call) -> None:
        f = call.func
        if (isinstance(f, ast.Name) and f.id in _COERCIONS
                and any(self._touches_traced(a) for a in call.args)):
            self._flag(pc.RULE_TRACER_COERCION, call,
                       f"{f.id}() on a traced value — concretizes the "
                       f"tracer (ConcretizationTypeError or a hidden "
                       f"device sync)")
        if (isinstance(f, ast.Attribute) and f.attr == "item"
                and self._touches_traced(f.value)):
            self._flag(pc.RULE_TRACER_COERCION, call,
                       ".item() on a traced value inside a jit body")
        if isinstance(f, ast.Attribute) and _np_root(f) \
                and self._touches_traced(call):
            self._flag(pc.RULE_NP_IN_JIT, call,
                       f"np.{f.attr}() on a traced value inside a jit "
                       f"body — numpy constant-folds tracers or raises; "
                       f"use jnp")
        if isinstance(f, ast.Attribute) and _obs_root(f):
            self._flag(pc.RULE_OBS_IN_JIT, call,
                       f"obs call ({_obs_root(f)}.{f.attr}(...)) inside a "
                       f"jit body — a host side effect here fires once at "
                       f"TRACE time and never again (spans vanish, "
                       f"counters undercount); instrument the driver "
                       f"around the stage launch instead")


def _iter_jit_bodies(tree: ast.Module):
    """Yield (stage_label, fn_node) for every jit body in a module: wrap()
    call sites (arg 1), jax.jit call sites (arg 0), jax.jit decorators."""
    defs: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)
    seen = set()

    def _resolve(arg):
        if isinstance(arg, ast.Lambda):
            return arg
        if isinstance(arg, ast.Name):
            return defs.get(arg.id)
        return None

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            is_wrap = ((isinstance(f, ast.Attribute) and f.attr == "wrap")
                       or (isinstance(f, ast.Name) and f.id == "wrap"))
            if is_wrap and len(node.args) >= 2:
                stage = (node.args[0].value
                         if isinstance(node.args[0], ast.Constant)
                         else "?")
                fn = _resolve(node.args[1])
                if fn is not None and id(fn) not in seen:
                    seen.add(id(fn))
                    yield str(stage), fn
            elif (isinstance(f, ast.Attribute) and f.attr == "jit"
                  and isinstance(f.value, ast.Name)
                  and f.value.id == "jax" and node.args):
                fn = _resolve(node.args[0])
                if fn is not None and id(fn) not in seen:
                    seen.add(id(fn))
                    yield getattr(fn, "name", "<lambda>"), fn
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dec.func if isinstance(dec, ast.Call) else dec
                if isinstance(d, ast.Attribute) and d.attr == "jit":
                    if id(node) not in seen:
                        seen.add(id(node))
                        yield node.name, node


def _check_registry(repo_root: Path, spec: pc.RegistrySpec,
                    tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    fn = None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == spec.factory:
            fn = node
            break
    if fn is None:
        return out
    key_exprs = [stmt.value for stmt in ast.walk(fn)
                 if isinstance(stmt, ast.Assign)
                 and any(isinstance(t, ast.Name) and t.id == "key"
                         for t in stmt.targets)]
    if not key_exprs:
        out.append(Finding(
            rule=pc.RULE_KEY_MISSING_FIELD, file=spec.file, line=fn.lineno,
            message=f"registry factory {spec.factory} has no `key = ...` "
                    f"assignment to check", check="retrace"))
        return out
    for key in key_exprs:
        names = _names_in(key)
        for p in spec.required_params:
            if p not in names:
                out.append(Finding(
                    rule=pc.RULE_KEY_MISSING_FIELD, file=spec.file,
                    line=key.lineno,
                    message=f"registry key of {spec.factory} omits "
                            f"shape-relevant parameter {p!r} — a cached "
                            f"stage jit would be served across different "
                            f"{p} values", check="retrace"))
        if isinstance(key, ast.Tuple):
            for elt in key.elts:
                if isinstance(elt, ast.Name) \
                        and elt.id in spec.wrap_required:
                    out.append(Finding(
                        rule=pc.RULE_UNHASHABLE_KEY, file=spec.file,
                        line=elt.lineno,
                        message=f"bare {elt.id!r} in the registry key of "
                                f"{spec.factory} — not hashable / not "
                                f"value-stable; wrap it (repr(cfg), "
                                f"plane_mesh.key())", check="retrace"))
    return out


def run(repo_root: Path, target: pc.AnalysisTarget) -> List[Finding]:
    findings: List[Finding] = []
    for file in target.jit_files:
        tree = _parse(repo_root, file)
        for stage, fn in _iter_jit_bodies(tree):
            _JitBodyLint(file, stage, fn, findings)
    reg_trees: Dict[str, ast.Module] = {}
    for spec in target.registries:
        if spec.file not in reg_trees:
            reg_trees[spec.file] = _parse(repo_root, spec.file)
        findings.extend(_check_registry(repo_root, spec,
                                        reg_trees[spec.file]))
    return findings
