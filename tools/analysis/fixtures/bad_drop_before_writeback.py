"""Seeded violation: blocks are physically dropped before any FlashD2H
write-back exists — writeback-before-drop.  Analyzed as source only;
never imported."""


class BadPlane:
    def step(self, params, fns, host):
        x = fns.embed(params, None)
        for i in range(4):
            sel = fns.select(params, x)
            host.drop_blocks(i, sel, protect=(i, sel))   # nothing saved yet
            host.save_new_tokens_fused(i, sel)
            host.load_blocks_fused(i, sel)
            host.restore_blocks_fused(i, sel)
            x = fns.attend(params, x, sel)
        return fns.logits(params, x)
