"""Seeded violation: int() applied to a traced value inside a jitted
stage body — tracer-coercion (ConcretizationTypeError, or a silent host
sync under jit-of-concrete).  Analyzed as source only; never imported."""


def build(wrap):
    return wrap("attend",
                lambda p, x, n: p["w"][:int(n)] @ x)
