"""Seeded violation: a Python branch on a traced value inside a jitted
stage body — traced-branch (the branch is resolved at trace time and
baked into the jaxpr).  Analyzed as source only; never imported."""


def build(wrap):
    return wrap("select",
                lambda p, x, mask: p["w"] @ x if mask else x)
