"""Seeded violation: the registry cache key omits a shape-relevant factory
parameter — key-missing-field (a stage jit compiled for one attn_impl
would be served for every other one).  Analyzed as source only; never
imported."""

_REG = {}


def fns_for(cfg, attn_impl):
    key = (repr(cfg),)                  # attn_impl never reaches the key
    if key not in _REG:
        _REG[key] = object()
    return _REG[key]
