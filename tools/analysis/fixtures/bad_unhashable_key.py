"""Seeded violation: the config object sits BARE in a registry cache key
— unhashable-key (dataclass configs with array-valued fields are not
reliably hashable, and identity-keyed entries leak one compile cache per
engine; key repr(cfg) instead).  Analyzed as source only; never
imported."""

_REG = {}


def fns_for(cfg, plane_mesh):
    key = (cfg, None if plane_mesh is None else plane_mesh.key())
    if key not in _REG:
        _REG[key] = object()
    return _REG[key]
