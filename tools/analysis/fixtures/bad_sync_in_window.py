"""Seeded violation: a host-blocking readback inside an ASYNC dispatch
window — ``stage_dispatch="async"`` promises the driver's np.asarray of
the selection tensor is the ONLY per-layer block on the dispatch thread.
The callback below converts the freshly appended KV stripe with the
BLOCKING ``new_token_kv`` instead of dispatching ``new_token_kv_async``
and handing the conversion to the HostStageWorker, re-serializing
attend(l) / select(l+1) behind the transfer — exactly the pipeline the
async mode exists to overlap.  Analyzed as source only; never imported."""


def async_stage_cb(plane, host, worker, i, sel, prev):
    # BAD: blocking stripe readback on the dispatch thread (should be
    # new_token_kv_async + a worker job fenced before the layer's gather)
    kv = plane.new_token_kv(prev, layers=[i])
    worker.submit(i, kv)
    missing = host.access_layer(i, sel)
    if missing:
        worker.fence(i)
        payloads = host.load_blocks_fused(i, missing)
        plane.restore_blocks_fused(i, payloads, before_use=True)


class BadAsyncPlane:
    def step_staged(self, params, fns, plane, host, worker, stage_cb):
        x = fns.embed(params, None)
        for i in range(4):
            sel = fns.select(params, x)
            stage_cb(plane, host, worker, i, sel, None)
            x = fns.attend(params, x, sel)
        return fns.logits(params, x)
