"""Seeded violation: a psum inside a head-mode attend stage —
collective-not-allowed (the contract says head-mode decode attention is
communication-free; a collective there means pool data is crossing the
mesh).  ``build_stages`` is executed by the sharding pass; lowering is
abstract, so a 1-device mesh suffices."""
from __future__ import annotations


def build_stages():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import plane_contract as pc
    from repro.models.common import shard_map_compat

    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))

    def attend(x):
        body = shard_map_compat(
            lambda x: jax.lax.psum(x, "model"),     # contract: no comm
            mesh=mesh, in_specs=P(), out_specs=P())
        return body(x)

    args = (jax.ShapeDtypeStruct((8, 16), jnp.float32),)
    return [pc.StageLowering(
        stage="attend[fixture:heads]", fn=attend, args=args,
        rules=pc.sharding_rules("attend", "heads"),
        file=__file__, line=21)]
