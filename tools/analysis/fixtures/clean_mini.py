"""A violation-free mini-plane exercising ALL THREE analyzer passes —
the analyzer must return zero findings here.

* ``GoodPlane.step`` follows the staged-decode protocol to the letter;
* ``build``'s jit body only branches on ``is None`` (static);
* ``fns_for`` keys repr(cfg) + plane_mesh.key() and covers both params;
* ``build_stages`` pins its shard_map output replicated before returning.
"""
from __future__ import annotations

_REG = {}


class GoodPlane:
    def step(self, params, fns, host, token_by_req):
        x = fns.embed(params, None)
        for i in range(2):
            sel = fns.select(params, x)
            host.save_new_tokens_fused(i, sel)
            host.access_layer(i)
            host.load_blocks_fused(i, sel)
            host.restore_blocks_fused(i, sel)
            host._drop_pending_evictions(i, protect=(i, sel))
            x = fns.attend(params, x, sel)
        return fns.logits(params, x)


def build(wrap):
    return wrap("select",
                lambda p, x, ctx:
                p["w"] @ x if ctx is None else p["w"] @ (x + ctx))


def fns_for(cfg, plane_mesh):
    key = (repr(cfg), None if plane_mesh is None else plane_mesh.key())
    if key not in _REG:
        _REG[key] = object()
    return _REG[key]


def build_stages():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core import plane_contract as pc
    from repro.models.common import shard_map_compat

    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))

    def attend(x):
        body = shard_map_compat(
            lambda x: x * 2.0,
            mesh=mesh, in_specs=P("model"), out_specs=P("model"))
        rep = NamedSharding(mesh, P())
        return jax.lax.with_sharding_constraint(body(x), rep)

    args = (jax.ShapeDtypeStruct((8, 16), jnp.float32),)
    return [pc.StageLowering(
        stage="attend[fixture:heads]", fn=attend, args=args,
        rules=pc.sharding_rules("attend", "heads"),
        file=__file__, line=52)]
