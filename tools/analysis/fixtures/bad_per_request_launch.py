"""Seeded violation: a jitted stage launch inside a loop over requests —
launches-per-iteration (the O(L) budget becomes O(L * batch)).  Analyzed
as source only; never imported."""


class BadGroup:
    def run_group(self, params, rids):
        outs = []
        for rid in rids:
            outs.append(self.fns.attn(params, rid))     # per-request launch
        return outs
