"""Seeded violation: a quantized-tier driver that lands its H2D payload
TWICE per (layer, group) window — once via the fp restore and once via
the fused dequant-restore (``dequantize_scatter_blocks``, which counts as
a restore like ``restore_blocks_fused``).  The fused (de)quant kernels
themselves (``quantize_blocks`` fused into the save, kind "quant") do NOT
count as extra transfers — only the duplicated restore flags.  Analyzed
as source only; never imported."""


class BadPlane:
    def step(self, params, fns, host, pool):
        x = fns.embed(params, None)
        for i in range(4):
            sel = fns.select(params, x)
            q, scales = host.quantize_blocks(sel)       # fused into the save
            host.save_new_tokens_fused(i, (q, scales))
            host.load_blocks_fused(i, sel)
            host.restore_blocks_fused(i, sel)
            host.dequantize_scatter_blocks(pool, q, scales, sel)  # 2nd restore
            x = fns.attend(params, x, sel)
        return fns.logits(params, x)
