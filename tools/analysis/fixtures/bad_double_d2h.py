"""Seeded violation: two FlashD2H saves in one (layer, group) window —
fused-transfer requires exactly one fused launch per window.  Analyzed as
source only; never imported."""


class BadPlane:
    def step(self, params, fns, host):
        x = fns.embed(params, None)
        for i in range(4):
            sel = fns.select(params, x)
            host.save_new_tokens_fused(i, sel)
            host.save_new_tokens_fused(i, sel)      # second save, same window
            host.load_blocks_fused(i, sel)
            host.restore_blocks_fused(i, sel)
            x = fns.attend(params, x, sel)
        return fns.logits(params, x)
