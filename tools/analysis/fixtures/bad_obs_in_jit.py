"""Seeded violation: a tracer span emitted inside a jitted stage body.

The obs layer is host-side — a ``tracer.end(...)`` here runs once while
jax traces the function and never again, so the span silently vanishes
from every subsequent launch (and a counter would undercount by
iterations-1).  Instrumentation belongs in the DRIVER, around the stage
launch (see ``DevicePoolPlane.step_staged``).
"""


def build(wrap, tracer):
    def attend(p, x):
        h = x @ p["w"]
        tracer.end("attend", "stage", 0.0)
        return h

    return wrap("attend", attend)
