"""Seeded violation: a numpy call on a traced value inside a jitted stage
body — np-in-jit (numpy either raises on tracers or constant-folds a
stale value into the jaxpr; use jnp).  Analyzed as source only; never
imported."""
import numpy as np


def build(wrap):
    return wrap("logits",
                lambda p, x: np.maximum(x, 0.0) + p["b"])
