"""Seeded violation: the fused restore lands AFTER the attend launch that
needs those blocks resident — restore-before-use.  Analyzed as source
only; never imported."""


class BadPlane:
    def step(self, params, fns, host):
        x = fns.embed(params, None)
        for i in range(4):
            sel = fns.select(params, x)
            host.save_new_tokens_fused(i, sel)
            host.load_blocks_fused(i, sel)
            x = fns.attend(params, x, sel)
            host.restore_blocks_fused(i, sel)       # too late
        return fns.logits(params, x)
