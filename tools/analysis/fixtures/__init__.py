"""Seeded-violation fixtures for the plane-contract analyzer.

Each fixture is a minimal mini-plane carrying EXACTLY ONE violation of one
rule (plus ``clean_mini``, which exercises all three passes and must come
back empty).  ``FIXTURES`` maps name -> (AnalysisTarget, expected rule);
``tests/test_plane_analysis.py`` asserts each target yields findings of
precisely its expected rule, and the CLI exposes them via ``--fixture``.

The AST-pass fixtures (drivers, jit bodies, registries) are analyzed as
SOURCE only and never imported; only the ``build_stages`` sharding
fixtures execute (abstract lowering on a 1-device mesh).
"""
from __future__ import annotations

from repro.core import plane_contract as pc

_FX = "tools/analysis/fixtures"


def _driver_target(name, fname, qualname, protocol, callbacks=(),
                   batch=()) -> pc.AnalysisTarget:
    return pc.AnalysisTarget(
        name=name,
        drivers=(pc.DriverSpec(
            name=name, file=f"{_FX}/{fname}", qualname=qualname,
            protocol=protocol, callbacks=callbacks,
            batch_iterables=batch),))


def _jit_target(name, fname) -> pc.AnalysisTarget:
    return pc.AnalysisTarget(name=name, jit_files=(f"{_FX}/{fname}",))


def _registry_target(name, fname, factory, required,
                     wrap_required) -> pc.AnalysisTarget:
    return pc.AnalysisTarget(
        name=name,
        registries=(pc.RegistrySpec(f"{_FX}/{fname}", factory, required,
                                    wrap_required),))


def _sharding_target(name, fname) -> pc.AnalysisTarget:
    return pc.AnalysisTarget(name=name,
                             sharding=f"{_FX}/{fname}:build_stages")


FIXTURES = {
    # pass 1 — stage protocol
    "bad_reordered_restore": (
        _driver_target("bad_reordered_restore",
                       "bad_reordered_restore.py", "BadPlane.step",
                       "staged-decode"),
        pc.RULE_RESTORE_BEFORE_USE),
    "bad_drop_before_writeback": (
        _driver_target("bad_drop_before_writeback",
                       "bad_drop_before_writeback.py", "BadPlane.step",
                       "staged-decode"),
        pc.RULE_WRITEBACK_BEFORE_DROP),
    "bad_double_d2h": (
        _driver_target("bad_double_d2h", "bad_double_d2h.py",
                       "BadPlane.step", "staged-decode"),
        pc.RULE_FUSED_TRANSFER),
    "bad_quant_double_restore": (
        _driver_target("bad_quant_double_restore",
                       "bad_quant_double_restore.py", "BadPlane.step",
                       "staged-decode"),
        pc.RULE_FUSED_TRANSFER),
    "bad_mixed_double_stage": (
        _driver_target("bad_mixed_double_stage",
                       "bad_mixed_double_stage.py",
                       "BadHybrid.run_iteration", "hybrid-plane",
                       callbacks=(pc.CallbackSpec(
                           "layer_cb", f"{_FX}/bad_mixed_double_stage.py",
                           "mixed_layer_cb"),)),
        pc.RULE_FUSED_TRANSFER),
    "bad_ctx_after_window": (
        _driver_target("bad_ctx_after_window", "bad_ctx_after_window.py",
                       "BadPrefill.run_iteration", "prefill-plane",
                       callbacks=(pc.CallbackSpec(
                           "group_cb", f"{_FX}/bad_ctx_after_window.py",
                           "good_group_cb"),)),
        pc.RULE_CTX_LIFETIME),
    "bad_sync_in_window": (
        _driver_target("bad_sync_in_window", "bad_sync_in_window.py",
                       "BadAsyncPlane.step_staged", "staged-decode-async",
                       callbacks=(pc.CallbackSpec(
                           "stage_cb", f"{_FX}/bad_sync_in_window.py",
                           "async_stage_cb"),)),
        pc.RULE_NO_SYNC_IN_DISPATCH_WINDOW),
    "bad_per_request_launch": (
        _driver_target("bad_per_request_launch",
                       "bad_per_request_launch.py", "BadGroup.run_group",
                       "prefill-group", batch=("rids",)),
        pc.RULE_LAUNCHES),
    # pass 2 — retrace hazards
    "bad_traced_branch": (
        _jit_target("bad_traced_branch", "bad_traced_branch.py"),
        pc.RULE_TRACED_BRANCH),
    "bad_tracer_coercion": (
        _jit_target("bad_tracer_coercion", "bad_tracer_coercion.py"),
        pc.RULE_TRACER_COERCION),
    "bad_np_in_jit": (
        _jit_target("bad_np_in_jit", "bad_np_in_jit.py"),
        pc.RULE_NP_IN_JIT),
    "bad_obs_in_jit": (
        _jit_target("bad_obs_in_jit", "bad_obs_in_jit.py"),
        pc.RULE_OBS_IN_JIT),
    "bad_unhashable_key": (
        _registry_target("bad_unhashable_key", "bad_unhashable_key.py",
                         "fns_for", ("cfg", "plane_mesh"),
                         ("cfg", "plane_mesh")),
        pc.RULE_UNHASHABLE_KEY),
    "bad_key_missing_field": (
        _registry_target("bad_key_missing_field",
                         "bad_key_missing_field.py", "fns_for",
                         ("cfg", "attn_impl"), ("cfg",)),
        pc.RULE_KEY_MISSING_FIELD),
    # pass 3 — sharding
    "bad_collective": (
        _sharding_target("bad_collective", "bad_collective.py"),
        pc.RULE_COLLECTIVE),
    "bad_sharding_leak": (
        _sharding_target("bad_sharding_leak", "bad_sharding_leak.py"),
        pc.RULE_SHARDING_LEAK),
    # all three passes, zero findings
    "clean_mini": (
        pc.AnalysisTarget(
            name="clean_mini",
            drivers=(pc.DriverSpec(
                name="clean_mini", file=f"{_FX}/clean_mini.py",
                qualname="GoodPlane.step", protocol="staged-decode",
                batch_iterables=("token_by_req",)),),
            registries=(pc.RegistrySpec(
                f"{_FX}/clean_mini.py", "fns_for",
                ("cfg", "plane_mesh"), ("cfg", "plane_mesh")),),
            jit_files=(f"{_FX}/clean_mini.py",),
            sharding=f"{_FX}/clean_mini.py:build_stages"),
        None),
}
