"""Seeded violation: the one-layer prefill ctx buffer is read OUTSIDE the
group callback, after the next group may have overwritten it —
ctx-lifetime.  The callback itself is well-formed (ctx read, fused D2H,
then the HBM layer evict).  Analyzed as source only; never imported."""


def good_group_cb(g, plane, host, cache):
    k, v = plane.read_group_kv(g)
    host.save_new_tokens_fused(g, k, v)
    cache.drop_layer(g)


class BadPrefill:
    def run_iteration(self, params, group_cb):
        while True:
            g = self._run_group(params)
            if g is None:
                break
            group_cb(g)
            stale = self.plane.read_group_kv(g)     # ctx already recycled
            self.keep.append(stale)
        return self.fns.finalize(params)
