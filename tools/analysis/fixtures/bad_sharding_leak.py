"""Seeded violation: a shard_map output with a sharded out-spec escapes
the stage without a ``PlaneMesh.replicate`` pin — sharding-leak (the
sharding would propagate into the next stage's jit and GSPMD-partition
replicated code).  ``build_stages`` is executed by the sharding pass;
lowering is abstract, so a 1-device mesh suffices."""
from __future__ import annotations


def build_stages():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import plane_contract as pc
    from repro.models.common import shard_map_compat

    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))

    def attend(x):
        body = shard_map_compat(
            lambda x: x * 2.0,
            mesh=mesh, in_specs=P("model"), out_specs=P("model"))
        return body(x) + 1.0                    # leaks the sharded spec

    args = (jax.ShapeDtypeStruct((8, 16), jnp.float32),)
    return [pc.StageLowering(
        stage="attend[fixture:heads]", fn=attend, args=args,
        rules=pc.sharding_rules("attend", "heads"),
        file=__file__, line=20)]
