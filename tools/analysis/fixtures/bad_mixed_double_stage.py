"""Seeded violation: a SECOND per-layer host stage in a mixed iteration —
the hybrid-plane protocol fuses decode write-back and the layer's fresh
prefill KV into ONE FlashD2H save (and at most one FlashH2D load +
restore round) per layer window; running the host stage twice doubles
every transfer.  Analyzed as source only; never imported."""


def mixed_layer_cb(host, i, sel):
    # the one per-layer host stage: merged save, merged load, restore
    host.save_new_tokens_fused(i, sel)
    host.load_blocks_fused(i, sel)
    host.restore_blocks_fused(i, sel, before_use=True)


class BadHybrid:
    def run_iteration(self, params, fns, host, layer_cb):
        x = fns.embed(params, None)
        for i in range(4):
            sel = fns.select(params, x)
            layer_cb(host, i, sel)
            layer_cb(host, i, sel)    # second host stage, same layer window
            x = fns.attend(params, x, sel)
        return fns.logits(params, x)
