"""Plane-contract static analyzer (see tools/analysis/README.md)."""
