"""End-to-end serving driver (the paper's kind): serve a small model with
BATCHED requests from a synthetic LongBench-like trace, comparing the
SparseServe configuration against the chunked-prefill baseline on the real
engine, then replaying the same trace at paper scale (LWM-7B) on the
discrete-event simulator.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request
from repro.serving.simulator import SYSTEMS, ServingSimulator, SimConfig
from repro.serving.trace import TraceConfig, generate_trace


def real_engine_comparison():
    print("=== real engine (qwen2-0.5b smoke, 6 requests) ===")
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    for mode in ("chunked", "layer_segmented"):
        eng = ServingEngine(params, cfg, EngineConfig(
            prefill_mode=mode, chunk_size=64, r_max=4,
            hbm_blocks_per_request=24))
        t = 0.0
        for _ in range(6):
            t += rng.exponential(0.01)
            eng.submit(Request(prompt_len=int(rng.integers(96, 256)),
                               max_new_tokens=6, arrival_time=t))
        m = eng.run()
        ts = eng.transfer_stats()
        print(f"{mode:16s} ttft={m.mean_ttft*1e3:7.2f}ms "
              f"tbt={m.mean_tbt*1e3:6.2f}ms tok/s={m.token_throughput:7.1f} "
              f"prefill_hbm_peak={eng.prefill_hbm_peak_tokens} token-layers "
              f"hit_rate={ts.hits/max(ts.hits+ts.misses,1):.2f}")


def paper_scale_simulation():
    print("\n=== paper scale (LWM-7B, A100 cost model, 0.25 req/s) ===")
    cfg = get_config("lwm-7b")
    trace_cfg = TraceConfig(request_rate=0.25, num_requests=32, seed=7)
    for name in ("vllm", "vllm-s", "vllm-so", "sparseserve"):
        sim = ServingSimulator(cfg, SYSTEMS[name], sim=SimConfig())
        m = sim.run(generate_trace(trace_cfg))
        print(f"{name:12s} ttft={m.mean_ttft:7.2f}s "
              f"tbt={m.mean_tbt*1e3:7.1f}ms tok/s={m.token_throughput:7.1f} "
              f"finished={m.num_finished}")


if __name__ == "__main__":
    real_engine_comparison()
    paper_scale_simulation()
