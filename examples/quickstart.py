"""Quickstart: the SparseServe pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Build a small model (qwen2-0.5b smoke variant).
2. Prefill a long-ish prompt -> paged KV pool + cuboid block metadata.
3. Decode with dynamic sparse attention (select-then-compute).
4. Show what the DSA selected and what the hierarchical KV cache did.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request


def main():
    cfg = get_smoke_config("qwen2-0.5b")
    print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
          f"heads={cfg.num_heads}/{cfg.num_kv_heads}kv")
    print(f"DSA: block_size={cfg.dsa.block_size} "
          f"token_budget={cfg.dsa.token_budget} metadata={cfg.dsa.metadata}")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    # --- direct model API ---------------------------------------------
    prompt = np.random.default_rng(0).integers(4, cfg.vocab_size, 192)
    logits, state = M.prefill(params, cfg,
                              {"tokens": jnp.asarray(prompt[None])},
                              num_blocks=8, cache_dtype=jnp.float32)
    tok = int(jnp.argmax(logits[0]))
    print(f"\nprefill(192 tokens) -> first token {tok}")
    for step in range(4):
        logits, state, info = M.decode_step(
            params, cfg, jnp.asarray([tok], jnp.int32), state,
            return_info=True)
        tok = int(jnp.argmax(logits[0]))
        sel0 = sorted(set(np.asarray(info["selected"][0][0]).ravel().tolist()))
        print(f"decode step {step}: token={tok:6d} "
              f"layer0 selected blocks={sel0}")

    # --- serving engine ------------------------------------------------
    print("\nserving engine (layer-segmented prefill + WS control):")
    eng = ServingEngine(params, cfg, EngineConfig(hbm_blocks_per_request=16))
    for _ in range(3):
        eng.submit(Request(prompt_len=192, max_new_tokens=6))
    metrics = eng.run()
    ts = eng.transfer_stats()
    print(f"finished={metrics.num_finished} in {eng.iterations} iterations")
    print(f"FlashD2H saves: {ts.d2h_calls} contiguous copies, "
          f"{ts.d2h_blocks} blocks scattered on host")
    print(f"FlashH2D loads: {ts.h2d_blocks} blocks fused-gathered; "
          f"cache hits={ts.hits} misses={ts.misses}")


if __name__ == "__main__":
    main()
